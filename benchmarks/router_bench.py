"""Router benchmark: the real multi-process cluster runtime, 1P+1D vs
2P×2D, on a tiny model — measures what the load-aware router actually
buys (and costs) with live OS processes and shared-memory KV handoff:

  * requests/s (wall-clock, parent-measured)
  * TTFT p50/p95 (request arrival → first decoded token)
  * per-instance utilization imbalance ((max−min)/mean dispatch counts —
    0.0 means the router spread work perfectly)

Writes ``BENCH_router.json`` at the repo root (CI uploads it as an
artifact). The model is intentionally small: the point is the routing
and process topology, not the FLOPs.

  PYTHONPATH=src python -m benchmarks.router_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.engine import VendorProfile
from repro.serving.multiproc import ClusterRuntime, ClusterSpec, EngineSpec
from repro.serving.multiproc.report import imbalance, percentile, ttfts_s
from repro.serving.request import Request

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_router.json"

# tiny on purpose: real processes + real shm handoff, minimal FLOPs
CFG = ModelConfig(name="router-bench-tiny", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                  d_ff=128, vocab_size=512, param_dtype="float32",
                  compute_dtype="float32")
VENDOR_P = VendorProfile("benchB", block_size=8, layout="nhbd",
                         kv_dtype="float32", tp=2, hardware="gpu-b")
VENDOR_D = VendorProfile("benchA", block_size=4, layout="nbhd",
                         kv_dtype="float32", tp=1, hardware="gpu-a")


def build_requests(n: int, max_new: int):
    rng = np.random.default_rng(7)
    return [Request(req_id=f"bench-{i:03d}",
                    prompt=rng.integers(0, CFG.vocab_size,
                                        int(rng.integers(8, 24))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _cluster(n_p: int, n_d: int) -> ClusterSpec:
    mk = lambda name, vendor, role: EngineSpec(
        name, CFG, vendor, params_seed=0, num_blocks=128, max_batch=4,
        max_seq_len=64, role=role)
    return ClusterSpec(
        p=tuple(mk(f"P{i}", VENDOR_P, "prefill") for i in range(n_p)),
        d=tuple(mk(f"D{i}", VENDOR_D, "decode") for i in range(n_d)))


def run_topology(n_p: int, n_d: int, n_requests: int, max_new: int) -> dict:
    reqs = build_requests(n_requests, max_new)
    # spawn first so the measurement is serving, not worker startup
    # (each spawned worker pays a full jax import on this container),
    # and warm every instance through the router with the *same length
    # mixture as the measured workload* (same seed → identical prompt
    # lengths) so every chunk-shape jit program compiles untimed. A
    # fixed-length warmup left most shapes cold: per-process
    # recompilation then dominated the timed window and scaling the
    # process count scaled the compile bill, not the throughput.
    rt = ClusterRuntime(_cluster(n_p, n_d), prefill_chunk=8)
    try:
        rt.start()
        warmup = build_requests(n_requests, 2)
        for i, w in enumerate(warmup):
            w.req_id = f"warm-{i:03d}"
        rt.serve(warmup, max_wall_s=600.0)
        warm_finished = rt.stats.finished
        warm_p = dict(rt.stats.p_dispatches)
        warm_d = dict(rt.stats.d_dispatches)
        t0 = time.perf_counter()
        tokens = rt.serve(reqs, max_wall_s=600.0)
        wall = time.perf_counter() - t0
    finally:
        rt.shutdown()
    finished = rt.stats.finished - warm_finished
    if finished != len(reqs):
        raise RuntimeError(f"{n_p}P{n_d}D run lost requests: "
                           f"{finished}/{len(reqs)} finished")
    p_disp = {k: v - warm_p.get(k, 0)
              for k, v in rt.stats.p_dispatches.items()}
    d_disp = {k: v - warm_d.get(k, 0)
              for k, v in rt.stats.d_dispatches.items()}
    tt = ttfts_s(reqs)
    return {
        "topology": f"{n_p}P{n_d}D",
        "requests": len(reqs),
        "finished": finished,
        "wall_s": round(wall, 3),
        "requests_per_s": round(len(reqs) / wall, 3),
        "tokens_per_s": round(sum(len(t) for t in tokens.values()) / wall, 1),
        "ttft_p50_s": round(percentile(tt, 50), 4),
        "ttft_p95_s": round(percentile(tt, 95), 4),
        "p_dispatches": p_disp,
        "d_dispatches": d_disp,
        "p_imbalance": round(imbalance(p_disp), 3),
        "d_imbalance": round(imbalance(d_disp), 3),
        "requeues": rt.stats.requeues,
        "streamed_chunks": rt.transfer_stats.chunks,
    }


def main(out: pathlib.Path = DEFAULT_OUT, n_requests: int = 16,
         max_new: int = 8) -> dict:
    results = {}
    for n_p, n_d in ((1, 1), (2, 2)):
        label = f"{n_p}P{n_d}D"
        print(f"== {label}: {n_requests} requests × {max_new} new tokens ==")
        r = run_topology(n_p, n_d, n_requests, max_new)
        results[label] = r
        print(f"  {r['requests_per_s']:.2f} req/s, "
              f"ttft p50 {r['ttft_p50_s'] * 1e3:.0f} ms / "
              f"p95 {r['ttft_p95_s'] * 1e3:.0f} ms, "
              f"imbalance P {r['p_imbalance']:.2f} D {r['d_imbalance']:.2f}")
    doc = {
        "benchmark": "router",
        "model": CFG.name,
        "config": {"requests": n_requests, "max_new": max_new,
                   "prefill_chunk": 8},
        "topologies": results,
        # the 2P2D ≥ 1P1D regression this bench exposed, and its fix:
        # redundant per-process jit compilation (not dispatch) scaled with
        # the process count on small hosts. Fixed by (a) a host-shared
        # persistent XLA compilation cache across workers, (b) re-page
        # programs keyed on in-page offset instead of absolute chunk
        # start, (c) distribution-covering warmup. Numbers below are the
        # pre-fix run kept for comparison.
        "before_fix": {"1P1D": {"wall_s": 19.521, "requests_per_s": 0.82},
                       "2P2D": {"wall_s": 36.839, "requests_per_s": 0.434}},
    }
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fast", action="store_true",
                    help="smaller request count (CI smoke)")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    n = 8 if args.fast else args.requests
    main(out=args.out, n_requests=n, max_new=args.max_new)
