"""Paper §IV — the joint-optimization output: parallel strategy + P:D
instance allocation per workload, on the paper's GPU pair and on TPU v5e.
"""
from __future__ import annotations

from repro.configs.base import get_config
from repro.core.planner.hardware import GPU_A, GPU_B, TPU_V5E
from repro.core.planner.optimizer import plan_deployment
from repro.core.planner.workload import Workload

WORKLOADS = [
    Workload(qps=2.0, input_len=256, output_len=256),
    Workload(qps=2.0, input_len=1024, output_len=1024),
    Workload(qps=3.0, input_len=512, output_len=1024),
    Workload(qps=8.0, input_len=1024, output_len=512),
]


def main() -> list:
    rows = []
    for model in ("llama2-7b", "qwen3-4b", "phi3-medium-14b"):
        cfg = get_config(model)
        for p_hw, d_hw, label in ((GPU_B, GPU_A, "B→A"),
                                  (TPU_V5E, TPU_V5E, "v5e")):
            print(f"== {model} on {label} ==")
            for wl in WORKLOADS:
                try:
                    plan = plan_deployment(cfg, wl, p_hw=p_hw, d_hw=d_hw)
                except ValueError as e:
                    print(f"{wl.label():22s} INFEASIBLE ({str(e)[:60]})")
                    continue
                print(f"{wl.label():22s} {plan.ratio():7s} "
                      f"P={plan.prefill.strategy.label():14s} "
                      f"D={plan.decode.strategy.label():14s} "
                      f"batch={plan.decode.batch:4d} "
                      f"cost={plan.cost_per_hour:7.1f}$/h "
                      f"qps_cap={plan.qps_capacity:6.2f}")
                assert plan.qps_capacity >= wl.qps * 0.99
                rows.append((model, label, wl.label(), plan.ratio(),
                             plan.cost_per_hour))
    return rows


if __name__ == "__main__":
    main()
