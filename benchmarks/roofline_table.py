"""Render the §Roofline table from the dry-run records
(results/dryrun.jsonl — produced by `python -m repro.launch.dryrun --all
--mesh both --probes`)."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.jsonl")


def load(path: str = RESULTS) -> List[dict]:
    if not os.path.exists(path):
        return []
    recs: Dict[tuple, dict] = {}
    with open(path) as fh:
        for line in fh:
            r = json.loads(line)
            if r.get("kind") == "handoff":
                continue
            key = (r["arch"], r["shape"], r["mesh"])
            # field-wise merge: later records refresh only what they carry
            merged = recs.get(key, {})
            merged.update({k: v for k, v in r.items()
                           if v not in (None, {}, [])})
            recs[key] = merged
    return list(recs.values())


def fmt_row(r: dict) -> str:
    rl = r.get("roofline") or {}
    if r.get("skip"):
        return (f"| {r['arch']} | {r['shape']} | skip | — | — | — | — | — | "
                f"{r['skip'].split(':')[0]} |")
    if not rl or "seconds" not in rl:
        return (f"| {r['arch']} | {r['shape']} | {r['mode']} | — | — | — | "
                f"— | — | compiled |")
    s = rl["seconds"]
    return (f"| {r['arch']} | {r['shape']} | {r['mode']} "
            f"| {s['compute']*1e3:.1f} | {s['memory']*1e3:.1f} "
            f"| {s['collective']*1e3:.1f} | {rl['dominant']} "
            f"| {rl['useful_ratio']:.2f} "
            f"| mem {r['memory_analysis'].get('total_minus_aliased', 0)/2**30:.1f} GiB |")


def main(path: str = RESULTS) -> None:
    recs = [r for r in load(path) if r["mesh"] == "single"]
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    if not recs:
        print("(no dry-run records yet — run repro.launch.dryrun --all "
              "--probes first)")
        return
    print("| arch | shape | mode | compute ms | memory ms | collective ms "
          "| bound | useful | fits |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(fmt_row(r))
    done = [r for r in recs if (r.get("roofline") or {}).get("seconds")]
    if done:
        n_dom: Dict[str, int] = {}
        for r in done:
            n_dom[r["roofline"]["dominant"]] = \
                n_dom.get(r["roofline"]["dominant"], 0) + 1
        print(f"\nbottleneck census over {len(done)} analyzed cells: {n_dom}")


if __name__ == "__main__":
    main()
