"""Per-family prefill benchmark and capability matrix.

For every tiny model family the repo serves (dense, biased/qk-norm,
sliding, MLA, MoE, hybrid recurrent, pure SSM, enc-dec audio, VLM):

  * **prefill tok/s** — chunked incremental prefill compute through the
    streamed P→D handoff (the capability-declared path every family now
    supports), measured over the engine's own compute clock.
  * **integrated vs disagg TTFT** — the same mixed load (one decoding
    request, then a burst of prefills) served by one ``role="both"``
    engine vs a disaggregated P+D pair: mean TTFT of the burst, the
    delta, and the integrated engine's measured
    ``contention_stall_seconds`` (≈0 for disagg by construction).

Writes ``BENCH_families.json`` at the repo root (CI uploads it).
``--matrix`` prints the README's family × capability table, generated
from ``ModelConfig.prefill_capabilities()`` — regenerate it after any
capability change:

  PYTHONPATH=src python -m benchmarks.family_bench [--fast] [--matrix]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

import jax

from repro.configs.base import (ATTN, RECURRENT, FrontendConfig, MLAConfig,
                                ModelConfig, MoEConfig, RecurrentConfig,
                                SSMConfig)
from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_families.json"


def _tiny(name, **kw) -> ModelConfig:
    base = dict(name=name, family="dense", num_layers=3, d_model=64,
                num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                vocab_size=128, param_dtype="float32",
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = {
    "dense": _tiny("dense"),
    "dense-bias-qknorm": _tiny("dense-bias-qknorm", qkv_bias=True,
                               qk_norm=True, num_kv_heads=2),
    "sliding": _tiny("sliding", attention_kind="sliding", sliding_window=8),
    "mla": _tiny("mla", attention_kind="mla",
                 mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                               qk_rope_head_dim=8, v_head_dim=16)),
    "moe": _tiny("moe", family="moe",
                 moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2,
                               d_ff_expert=32, first_dense_layers=1)),
    "hybrid": _tiny("hybrid", family="hybrid", attention_kind="sliding",
                    sliding_window=8, num_layers=5,
                    recurrent=RecurrentConfig(
                        lru_width=64, d_conv=4,
                        block_pattern=(RECURRENT, RECURRENT, ATTN))),
    "ssm": _tiny("ssm", family="ssm", attention_kind="none", num_kv_heads=0,
                 d_ff=0, num_heads=8,
                 ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4,
                               chunk_size=4)),
    "encdec": _tiny("encdec", family="audio", encoder_layers=2,
                    frontend=FrontendConfig(kind="audio")),
    "vlm": _tiny("vlm", family="vlm", num_kv_heads=2,
                 frontend=FrontendConfig(kind="vision", num_patches=4)),
}

CAP_COLUMNS = ("incremental", "resumable", "prefix_cache",
               "encoder_preamble", "kv_on_wire", "latent_kv", "window")


def capability_matrix() -> str:
    """README table, generated from ``prefill_capabilities()``."""
    head = "| family | " + " | ".join(CAP_COLUMNS) + " |"
    sep = "|---" * (len(CAP_COLUMNS) + 1) + "|"
    rows = [head, sep]
    for name, cfg in FAMILIES.items():
        caps = cfg.prefill_capabilities()
        cells = []
        for col in CAP_COLUMNS:
            v = getattr(caps, col)
            cells.append(str(v) if col == "window" else ("✓" if v else "–"))
        rows.append(f"| {name} | " + " | ".join(cells) + " |")
    return "\n".join(rows)


def _req(cfg, plen, rid="r0", max_new=4, seed=3):
    rng = np.random.default_rng(seed)
    r = Request(req_id=rid,
                prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                max_new_tokens=max_new)
    if cfg.is_enc_dec:
        r.frames = rng.normal(size=(10, cfg.d_model)).astype(np.float32)
    if cfg.frontend.kind == "vision":
        r.patches = rng.normal(size=(cfg.frontend.num_patches,
                                     cfg.d_model)).astype(np.float32)
    return r


def _mem(cfg):
    return 10 if cfg.is_enc_dec else 0


def _pair(cfg, params, role_p="prefill", role_d="decode"):
    vp = VendorProfile("benchB", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    vd = VendorProfile("benchA", block_size=4, layout="nbhd",
                       kv_dtype="float32")
    mem = _mem(cfg)
    p = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
               max_seq_len=64, mem_len=mem, role=role_p)
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, mem_len=mem, role=role_d)
    return p, d


def bench_prefill(cfg, params, plen=48, chunk=8, repeats=3) -> dict:
    """Chunked incremental prefill tok/s through the streamed handoff
    (first iteration includes jit compilation and is discarded)."""
    best = 0.0
    for i in range(repeats + 1):
        p, d = _pair(cfg, params)
        pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
        before = p.stats.prefill_seconds
        meta = pipe.handoff_streamed(_req(cfg, plen=plen, seed=i), p, d,
                                     chunk_tokens=chunk)
        compute_s = p.stats.prefill_seconds - before
        if i == 0:
            continue                      # warmup: jit compile
        best = max(best, meta["seq_len"] / max(compute_s, 1e-9))
    return {"prefill_tok_s": best, "chunk_tokens": chunk, "prompt_len": plen}


def bench_ttft(cfg, params, mode: str) -> dict:
    """Mean burst TTFT under mixed load for one topology."""
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1)
    if mode == "integrated":
        vd = VendorProfile("benchA", block_size=4, layout="nbhd",
                           kv_dtype="float32")
        eng = Engine("I0", cfg, params, vd, num_blocks=64, max_batch=4,
                     max_seq_len=64, mem_len=_mem(cfg), role="both")
        sched.add_instance(eng)
        engines = [eng]
    else:
        p, d = _pair(cfg, params)
        sched.add_instance(p)
        sched.add_instance(d)
        engines = [p, d]
    warm = _req(cfg, plen=8, rid="warm", max_new=16, seed=1)
    burst = [_req(cfg, plen=24, rid=f"b{i}", max_new=2, seed=10 + i)
             for i in range(3)]
    sched.submit(warm)
    for _ in range(4):
        sched.step()
    submit_t = time.perf_counter()
    for r in burst:
        sched.submit(r)
    first: dict = {}
    for _ in range(600):
        for r, _tok in sched.step():
            if r.req_id.startswith("b") and r.req_id not in first:
                first[r.req_id] = time.perf_counter() - submit_t
        if sched.stats.finished == 1 + len(burst):
            break
    ttfts = [first[r.req_id] for r in burst if r.req_id in first]
    return {"ttft_mean_s": float(np.mean(ttfts)) if ttfts else float("nan"),
            "contention_stall_s": sum(e.stats.contention_stall_seconds
                                      for e in engines)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="fewer repeats; TTFT comparison on dense only")
    ap.add_argument("--matrix", action="store_true",
                    help="print the capability matrix markdown and exit")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.matrix:
        print(capability_matrix())
        return

    ttft_fams = ["dense"] if args.fast else list(FAMILIES)
    repeats = 1 if args.fast else 3
    result: dict = {}
    for name, cfg in FAMILIES.items():
        params = M.init_params(jax.random.key(1), cfg)
        caps = cfg.prefill_capabilities()
        entry = {"capabilities": {c: getattr(caps, c) for c in CAP_COLUMNS}}
        entry.update(bench_prefill(cfg, params, repeats=repeats))
        if name in ttft_fams:
            integ = bench_ttft(cfg, params, "integrated")
            disagg = bench_ttft(cfg, params, "disagg")
            entry["ttft_integrated_s"] = integ["ttft_mean_s"]
            entry["ttft_disagg_s"] = disagg["ttft_mean_s"]
            entry["ttft_delta_s"] = \
                integ["ttft_mean_s"] - disagg["ttft_mean_s"]
            entry["contention_stall_integrated_s"] = \
                integ["contention_stall_s"]
            entry["contention_stall_disagg_s"] = disagg["contention_stall_s"]
        result[name] = entry
        print(f"{name:18s} {entry['prefill_tok_s']:10.0f} tok/s"
              + (f"  ttft Δ {entry['ttft_delta_s'] * 1e3:+.1f} ms"
                 if "ttft_delta_s" in entry else ""))
    args.out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
