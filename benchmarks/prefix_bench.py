"""Prefix-cache benchmark: a shared-system-prompt workload through the
single-process P/D serving loop, cache off vs on — measures what the
shared-prefix KV subsystem actually buys:

  * TTFT p50/p95 (request submit → first decoded token)
  * wire bytes (KV actually moved P→D over the connector)
  * prefill compute tokens (P-side forward tokens; cached replay skips)
  * hit accounting (``TransferStats.prefix_hit_tokens`` / ``bytes_saved``)

Every request shares one system prefix and appends a short unique tail —
the workload the cache targets (N agents, one system prompt). Requests
are served *sequentially* so each TTFT is an isolated prefill, not a
batching artifact. Token parity cached-vs-cold is asserted, not assumed.

Writes ``BENCH_prefix.json`` at the repo root (CI uploads it as an
artifact). The model is intentionally small: the point is the cache
path, not the FLOPs.

  PYTHONPATH=src python -m benchmarks.prefix_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.serving.engine import Engine, VendorProfile
from repro.serving.multiproc.report import percentile
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_prefix.json"

# tiny on purpose: real chunked prefill + wire handoff, minimal FLOPs
CFG = ModelConfig(name="prefix-bench-tiny", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                  d_ff=128, vocab_size=512, param_dtype="float32",
                  compute_dtype="float32")
VENDOR_P = VendorProfile("benchB", block_size=8, layout="nhbd",
                         kv_dtype="float32", tp=2, hardware="gpu-b")
VENDOR_D = VendorProfile("benchA", block_size=4, layout="nbhd",
                         kv_dtype="float32", tp=1, hardware="gpu-a")
SYSTEM_PROMPT_TOKENS = 48
TAIL_TOKENS = 8
CHUNK = 8


def build_requests(n: int, max_new: int):
    """One shared system prefix, a unique tail per request."""
    rng = np.random.default_rng(7)
    system = rng.integers(0, CFG.vocab_size,
                          SYSTEM_PROMPT_TOKENS).astype(np.int32)
    return [Request(req_id=f"bench-{i:03d}",
                    prompt=np.concatenate(
                        [system,
                         rng.integers(0, CFG.vocab_size,
                                      TAIL_TOKENS).astype(np.int32)]),
                    max_new_tokens=max_new)
            for i in range(n)]


def _scheduler(prefix_cache: bool):
    import jax

    from repro.models import model as M
    params = M.init_params(jax.random.key(0), CFG)
    mk = lambda name, vendor, role: Engine(
        name, CFG, params, vendor, num_blocks=128, max_batch=4,
        max_seq_len=128, role=role, prefix_cache=prefix_cache)
    sched = GlobalScheduler(DisaggPipeline(TransferEngine(),
                                           WireFormat("raw", "float32")),
                            prefill_chunk=CHUNK)
    sched.add_instance(mk("P0", VENDOR_P, "prefill"))
    sched.add_instance(mk("D0", VENDOR_D, "decode"))
    return sched


def run_mode(prefix_cache: bool, n_requests: int, max_new: int) -> dict:
    sched = _scheduler(prefix_cache)
    # warm the jit caches outside the timed window (same shapes as the run)
    rng = np.random.default_rng(99)
    warm = Request(req_id="warm",
                   prompt=rng.integers(
                       0, CFG.vocab_size,
                       SYSTEM_PROMPT_TOKENS + TAIL_TOKENS).astype(np.int32),
                   max_new_tokens=max_new)
    sched.submit(warm)
    for _ in range(500):
        if warm.state.name in ("FINISHED", "FAILED"):
            break
        sched.step()
    for e in list(sched.p_pool.values()) + list(sched.d_pool.values()):
        if e.prefix_store is not None:
            e.prefix_store.evict(len(e.prefix_store))
        if e.host_prefix_store is not None:
            e.host_prefix_store.reset()
    stats0 = sched.pipeline.transfer.stats
    bytes0 = stats0.bytes_moved
    p0_tokens = sched.p_pool["P0"].stats.prefill_tokens

    reqs = build_requests(n_requests, max_new)
    ttfts = []
    t_run0 = time.perf_counter()
    for r in reqs:
        t0 = time.perf_counter()
        sched.submit(r)
        for _ in range(2000):
            if r.first_token_time is not None or r.state.name == "FAILED":
                break
            sched.step()
        ttfts.append(time.perf_counter() - t0)
        while r.state.name not in ("FINISHED", "FAILED"):
            sched.step()
    wall = time.perf_counter() - t_run0
    if sum(1 for r in reqs if r.state.name == "FINISHED") != len(reqs):
        raise RuntimeError("benchmark run lost requests")

    out = {
        "prefix_cache": prefix_cache,
        "requests": len(reqs),
        "wall_s": round(wall, 3),
        "ttft_p50_s": round(percentile(ttfts, 50), 5),
        "ttft_p95_s": round(percentile(ttfts, 95), 5),
        "wire_bytes": stats0.bytes_moved - bytes0,
        "prefill_tokens":
            sched.p_pool["P0"].stats.prefill_tokens - p0_tokens,
        "prefix_hit_tokens": stats0.prefix_hit_tokens,
        "bytes_saved": stats0.bytes_saved,
    }
    tokens = {r.req_id: list(r.output_tokens) for r in reqs}
    return out, tokens


def main(out: pathlib.Path = DEFAULT_OUT, n_requests: int = 12,
         max_new: int = 8) -> dict:
    results = {}
    reference = None
    for prefix_cache in (False, True):
        label = "cached" if prefix_cache else "cold"
        print(f"== {label}: {n_requests} requests sharing a "
              f"{SYSTEM_PROMPT_TOKENS}-token system prompt ==")
        r, tokens = run_mode(prefix_cache, n_requests, max_new)
        if reference is None:
            reference = tokens
        elif tokens != reference:
            raise RuntimeError("cached run diverged from cold run")
        results[label] = r
        print(f"  ttft p50 {r['ttft_p50_s'] * 1e3:.1f} ms / "
              f"p95 {r['ttft_p95_s'] * 1e3:.1f} ms, "
              f"wire {r['wire_bytes']} B, "
              f"prefill {r['prefill_tokens']} tok, "
              f"hit {r['prefix_hit_tokens']} tok")
    doc = {
        "benchmark": "prefix_cache",
        "model": CFG.name,
        "config": {"requests": n_requests, "max_new": max_new,
                   "system_prompt_tokens": SYSTEM_PROMPT_TOKENS,
                   "tail_tokens": TAIL_TOKENS, "prefill_chunk": CHUNK},
        "token_parity": True,
        "modes": results,
        "wire_bytes_saved_ratio": round(
            1.0 - results["cached"]["wire_bytes"]
            / max(results["cold"]["wire_bytes"], 1), 3),
    }
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fast", action="store_true",
                    help="smaller request count (CI smoke)")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    n = 6 if args.fast else args.requests
    main(out=args.out, n_requests=n, max_new=args.max_new)
