"""Benchmark driver: one module per paper table/figure + kernel micro +
the roofline table. ``python -m benchmarks.run [--fast]``."""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shorter simulated duration")
    args = ap.parse_args()
    dur = 40.0 if args.fast else 120.0

    from benchmarks import (fig6_context_lengths, fig7_fig8_pd_ratio,
                            fig9_fig10_hetero, kernels_micro, planner_table,
                            roofline_table)

    t0 = time.time()
    fig6_context_lengths.main(duration=dur)
    print()
    fig7_fig8_pd_ratio.main(duration=dur)
    print()
    fig9_fig10_hetero.main(duration=dur)
    print()
    planner_table.main()
    print()
    kernels_micro.main()
    print()
    print("== roofline table (from dry-run records) ==")
    roofline_table.main()
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
