"""Open-loop load benchmark: seeded Poisson traffic against the live
multi-process cluster runtime, with SLO-aware admission control and the
cluster-backed autoscaler ticking during the run.

Unlike ``router_bench`` (closed-loop batch replay), arrivals here follow
a fixed schedule that does not wait for the server: TTFT measures from
each request's *scheduled* arrival, queue buildup lands on the latency
percentiles, and requests beyond the cluster's measured headroom are
shed at the door. Reports goodput (finished under SLO per second) and
TTFT/TPOT p50/p95/p99 plus admission-shed and autoscale-action counts.

Writes ``BENCH_load.json`` at the repo root (CI uploads it as an
artifact). The model is intentionally tiny — the subject is open-loop
dynamics, not FLOPs.

  PYTHONPATH=src python -m benchmarks.load_bench [--duration 8] [--rate 2]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs.base import ModelConfig
from repro.core.autoscale import (AutoscalerConfig, ClusterLoadSource,
                                  PDAutoscaler)
from repro.serving.engine import VendorProfile
from repro.serving.loadgen import (build_workload, poisson_arrivals,
                                   run_open_loop, WorkloadConfig)
from repro.serving.multiproc import ClusterRuntime, ClusterSpec, EngineSpec
from repro.serving.multiproc.report import slo_section
from repro.serving.router import AdmissionConfig

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_load.json"

CFG = ModelConfig(name="load-bench-tiny", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                  d_ff=128, vocab_size=512, param_dtype="float32",
                  compute_dtype="float32")
VENDOR_P = VendorProfile("benchB", block_size=8, layout="nhbd",
                         kv_dtype="float32", tp=2, hardware="gpu-b")
VENDOR_D = VendorProfile("benchA", block_size=4, layout="nbhd",
                         kv_dtype="float32", tp=1, hardware="gpu-a")

SLO_TTFT_S = 2.0
SLO_TPOT_S = 0.5


def _espec(name: str, vendor: VendorProfile, role: str) -> EngineSpec:
    return EngineSpec(name, CFG, vendor, params_seed=0, num_blocks=128,
                      max_batch=4, max_seq_len=64, role=role)


def main(out: pathlib.Path = DEFAULT_OUT, duration_s: float = 8.0,
         rate_rps: float = 2.0, seed: int = 7, arrivals: str = "poisson",
         autoscale: bool = True) -> dict:
    from repro.serving.loadgen import bursty_arrivals
    gen = poisson_arrivals if arrivals == "poisson" else bursty_arrivals
    offsets = gen(rate_rps, duration_s, seed)
    wl_cfg = WorkloadConfig(vocab_size=CFG.vocab_size, prompt_min=4,
                            prompt_max=32, output_min=2, output_max=12)
    workload = build_workload(offsets, wl_cfg, seed=seed)

    admission = AdmissionConfig(max_queue_depth=8, slo_ttft_s=SLO_TTFT_S,
                                headroom=1.0)
    cluster = ClusterSpec(p=(_espec("P0", VENDOR_P, "prefill"),),
                          d=(_espec("D0", VENDOR_D, "decode"),))
    rt = ClusterRuntime(cluster, prefill_chunk=8, admission=admission)
    scaler = None
    try:
        rt.start()
        # untimed warmup through the same length mixture so first-use jit
        # compilation doesn't masquerade as queueing delay
        warm = build_workload([0.0, 0.0, 0.0], wl_cfg, seed=seed + 1,
                              id_prefix="warm")
        for it in warm:
            it.request.max_new_tokens = 2
        rt.serve([it.request for it in warm], max_wall_s=600.0)
        rt.reset_latency_measurements()   # warmup TTFTs are not the system
        if autoscale:
            scaler = PDAutoscaler(
                ClusterLoadSource(rt),
                p_factory=lambda name: _espec(name, VENDOR_P, "prefill"),
                d_factory=lambda name: _espec(name, VENDOR_D, "decode"),
                baseline_p=1, baseline_d=1,
                config=AutoscalerConfig(slo_ttft_s=SLO_TTFT_S,
                                        slo_tpot_s=SLO_TPOT_S,
                                        cooldown_ticks=8, max_p=2, max_d=2))
        res = run_open_loop(rt, workload, autoscaler=scaler,
                            autoscale_every_s=0.25,
                            max_wall_s=duration_s + 600.0)
    finally:
        rt.shutdown()

    served = [it.request for it in workload]
    doc = {
        "benchmark": "load",
        "model": CFG.name,
        "config": {"arrivals": arrivals, "rate_rps": rate_rps,
                   "duration_s": duration_s, "seed": seed,
                   "admission": {"max_queue_depth": admission.max_queue_depth,
                                 "slo_ttft_s": admission.slo_ttft_s},
                   "autoscale": autoscale},
        "result": res.as_dict(),
        "latency": slo_section(served, res.wall_s, slo_ttft_s=SLO_TTFT_S,
                               slo_tpot_s=SLO_TPOT_S),
        "runtime": {"shed": rt.stats.shed, "finished": rt.stats.finished,
                    "failed": rt.stats.failed, "requeues": rt.stats.requeues,
                    "autoscaler": None if scaler is None else
                    {"grew_p": scaler.stats.grew_p,
                     "grew_d": scaler.stats.grew_d,
                     "drained": scaler.stats.drained}},
    }
    out.write_text(json.dumps(doc, indent=2) + "\n")
    lat = doc["latency"]
    print(f"offered {res.offered}  admitted {res.admitted}  shed {res.shed}"
          f"  finished {res.finished}  wall {res.wall_s:.1f}s")
    print(f"goodput {lat.get('goodput_rps', 0.0):.2f} req/s under SLO  "
          f"ttft p50/p95/p99 {lat['ttft_p50_s']:.3f}/"
          f"{lat['ttft_p95_s']:.3f}/{lat['ttft_p99_s']:.3f} s  "
          f"tpot p50/p95/p99 {lat['tpot_p50_s']:.3f}/"
          f"{lat['tpot_p95_s']:.3f}/{lat['tpot_p99_s']:.3f} s")
    if res.autoscale_actions:
        print("autoscale:", ", ".join(res.autoscale_actions))
    print(f"wrote {out}")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=8.0,
                    help="arrival-schedule length in seconds")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="mean offered load, requests/s")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--arrivals", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--no-autoscale", action="store_true")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    main(out=args.out, duration_s=args.duration, rate_rps=args.rate,
         seed=args.seed, arrivals=args.arrivals,
         autoscale=not args.no_autoscale)
