"""Paper Figs. 9-10 — heterogeneous P-D disaggregated vs P-D integrated.

Cost-fair comparison: the same {GPU B, GPU A} hardware pair serves both
ways. The paper reports +17% (512+1024 QPS3) and +30% (1024+1024 QPS2)
throughput for disaggregation, and TTFT meeting the SLO only in the
disaggregated deployment. We check the directional claims and report the
measured gains.

``--measured-handoff`` additionally runs the *real* two-process runtime
(P and D engines in separate OS processes, KV over shared-memory
segments) on a tiny model and reports measured wall-clock cross-process
handoff: how much wire time was genuinely hidden under prefill compute —
``TransferStats.wall_overlap_seconds`` — as opposed to the simulator's
modeled overlap above.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.planner.workload import FIG9, FIG10

from benchmarks.common import row, run


def measured_two_process_handoff(requests: int = 4, max_new: int = 8) -> dict:
    """Serve a tiny model through the two-process runtime and report the
    wall-clock handoff the launcher measured across the process boundary."""
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.serving.engine import VendorProfile
    from repro.serving.multiproc import EngineSpec, serve_two_process
    from repro.serving.request import Request

    cfg = ModelConfig(name="bench-tiny", family="dense", num_layers=4,
                      d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
                      d_ff=256, vocab_size=512, param_dtype="float32",
                      compute_dtype="float32")
    p_spec = EngineSpec("P0", cfg,
                        VendorProfile("vendorB", block_size=8, layout="nhbd",
                                      kv_dtype="float32", tp=2),
                        num_blocks=128, max_batch=4, max_seq_len=128,
                        role="prefill")
    d_spec = EngineSpec("D0", cfg,
                        VendorProfile("vendorA", block_size=4, layout="nbhd",
                                      kv_dtype="float32", tp=1),
                        num_blocks=128, max_batch=4, max_seq_len=128,
                        role="decode")
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=f"req-{i}",
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(24, 64))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(requests)]
    tokens, rt = serve_two_process(p_spec, d_spec, reqs, prefill_chunk=8,
                                   max_wall_s=600.0)
    ts = rt.transfer_stats
    assert rt.stats.finished == len(reqs), "measured-handoff run lost requests"
    frac = ts.wall_overlap_seconds / ts.wall_handoff_seconds \
        if ts.wall_handoff_seconds else 0.0
    print("== measured cross-process handoff (two-process runtime) ==")
    print(f"  {rt.stats.finished} requests, "
          f"{sum(len(t) for t in tokens.values())} tokens, "
          f"{ts.chunks} chunks / {ts.bytes_moved/1e6:.1f} MB over shm")
    print(f"  wall handoff {ts.wall_handoff_seconds*1e3:.1f} ms, "
          f"measured overlap {ts.wall_overlap_seconds*1e3:.1f} ms "
          f"({frac*100:.0f}% of wire time hidden under prefill compute)")
    return {"requests": rt.stats.finished,
            "chunks": ts.chunks, "bytes_moved": ts.bytes_moved,
            "wall_handoff_s": ts.wall_handoff_seconds,
            "wall_overlap_s": ts.wall_overlap_seconds,
            "overlap_fraction": frac}


def main(duration: float = 120.0, measured_handoff: bool = False) -> dict:
    out = {}
    for name, wl, paper_gain in (("Fig. 9 (512+1024 QPS3)", FIG9, 0.17),
                                 ("Fig. 10 (1024+1024 QPS2)", FIG10, 0.30)):
        print(f"== {name}: disaggregated vs integrated ==")
        r_dis = run(wl, duration_s=duration)
        r_int = run(wl, mode="integrated", duration_s=duration)
        gain = (r_dis.throughput_tok_s() - r_int.throughput_tok_s()) \
            / r_int.throughput_tok_s()
        print(row("disaggregated (B→A)", r_dis))
        print(row("integrated (B,A)", r_int))
        print(f"  throughput gain {gain*100:+.0f}% "
              f"(paper reports {paper_gain*100:+.0f}%)")
        slo_dis = r_dis.ttft_mean() <= wl.slo_ttft_s
        slo_int_viol = r_int.tpot_mean() > r_dis.tpot_mean() * 1.5
        checks = {
            "disagg throughput >= paper's gain": gain >= paper_gain,
            "disagg TTFT within SLO": slo_dis,
            "integrated decode interference (TPOT blows up)": slo_int_viol,
        }
        for k, v in checks.items():
            print(f"  [{'ok' if v else 'X'}] {k}")
        assert all(checks.values()), checks
        out[name] = {"gain": gain, "dis": r_dis.summary(),
                     "int": r_int.summary()}
    if measured_handoff:
        print()
        out["measured_two_process_handoff"] = measured_two_process_handoff()
    out["duration_s"] = duration
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=120.0,
                    help="simulated seconds per comparison")
    ap.add_argument("--measured-handoff", action="store_true",
                    help="also serve a tiny model through the two-process "
                         "runtime and report measured (wall-clock) "
                         "cross-process handoff overlap")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the results as JSON (CI perf-trajectory "
                         "artifact)")
    args = ap.parse_args()
    results = main(duration=args.duration,
                   measured_handoff=args.measured_handoff)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"results written to {args.json}")
