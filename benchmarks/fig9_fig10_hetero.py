"""Paper Figs. 9-10 — heterogeneous P-D disaggregated vs P-D integrated.

Cost-fair comparison: the same {GPU B, GPU A} hardware pair serves both
ways. The paper reports +17% (512+1024 QPS3) and +30% (1024+1024 QPS2)
throughput for disaggregation, and TTFT meeting the SLO only in the
disaggregated deployment. We check the directional claims and report the
measured gains.
"""
from __future__ import annotations

from repro.core.planner.workload import FIG9, FIG10

from benchmarks.common import row, run


def main(duration: float = 120.0) -> dict:
    out = {}
    for name, wl, paper_gain in (("Fig. 9 (512+1024 QPS3)", FIG9, 0.17),
                                 ("Fig. 10 (1024+1024 QPS2)", FIG10, 0.30)):
        print(f"== {name}: disaggregated vs integrated ==")
        r_dis = run(wl, duration_s=duration)
        r_int = run(wl, mode="integrated", duration_s=duration)
        gain = (r_dis.throughput_tok_s() - r_int.throughput_tok_s()) \
            / r_int.throughput_tok_s()
        print(row("disaggregated (B→A)", r_dis))
        print(row("integrated (B,A)", r_int))
        print(f"  throughput gain {gain*100:+.0f}% "
              f"(paper reports {paper_gain*100:+.0f}%)")
        slo_dis = r_dis.ttft_mean() <= wl.slo_ttft_s
        slo_int_viol = r_int.tpot_mean() > r_dis.tpot_mean() * 1.5
        checks = {
            "disagg throughput >= paper's gain": gain >= paper_gain,
            "disagg TTFT within SLO": slo_dis,
            "integrated decode interference (TPOT blows up)": slo_int_viol,
        }
        for k, v in checks.items():
            print(f"  [{'ok' if v else 'X'}] {k}")
        assert all(checks.values()), checks
        out[name] = {"gain": gain, "dis": r_dis.summary(),
                     "int": r_int.summary()}
    return out


if __name__ == "__main__":
    main()
