"""Shared benchmark helpers: the paper's experimental platform (GPU A for
decode, GPU B for prefill, Llama2-7B) driven through the event simulator."""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.configs.base import get_config
from repro.core.planner.events import (SimResult, kv_wire_bytes_per_token,
                                       simulate)
from repro.core.planner.hardware import GPU_A, GPU_B
from repro.core.planner.simulator import (FrameworkModel, InstanceModel,
                                          ParallelStrategy,
                                          connector_chunk_tokens)
from repro.core.planner.workload import Workload
from repro.core.transport import make_connector

CFG = get_config("llama2-7b")


def connector_caps(connector: Optional[str], bandwidth_gbps: float = 25.0):
    """capabilities() of the named KV-transport backend (None → None:
    the simulator falls back to its bare transfer_gbps constant)."""
    if connector is None:
        return None
    return make_connector(connector,
                          bandwidth_gbps=bandwidth_gbps).capabilities()


def wire_bytes_per_token() -> int:
    """Canonical per-token KV wire bytes of the benchmark model (bf16)."""
    return kv_wire_bytes_per_token(CFG)


def models(chunked_prefill: bool = False,
           prefill_chunk_tokens: int = 512):
    """(P on GPU B — compute-strong, D on GPU A — HBM-strong)."""
    fw = FrameworkModel(chunked_prefill=chunked_prefill,
                        prefill_chunk_tokens=prefill_chunk_tokens)
    return (InstanceModel(CFG, GPU_B, ParallelStrategy(), fw),
            InstanceModel(CFG, GPU_A, ParallelStrategy(), fw))


def run(wl: Workload, n_p: int = 1, n_d: int = 1, mode: str = "disagg",
        duration_s: float = 120.0, chunked_prefill: bool = False,
        prefill_chunk_tokens: int = 512,
        connector: Optional[str] = None,
        bandwidth_gbps: float = 25.0) -> SimResult:
    """``connector``: KV-transport backend name — wire time and streaming
    chunk granularity are then sourced from its capabilities() descriptor
    instead of the hard-coded 25 Gbps / 512-token constants."""
    caps = connector_caps(connector, bandwidth_gbps)
    if caps is not None and chunked_prefill:
        prefill_chunk_tokens = connector_chunk_tokens(
            caps, wire_bytes_per_token(), default=prefill_chunk_tokens)
    mP, mD = models(chunked_prefill=chunked_prefill,
                    prefill_chunk_tokens=prefill_chunk_tokens)
    return simulate(CFG, wl, p_model=mP, d_model=mD, n_prefill=n_p,
                    n_decode=n_d, mode=mode, duration_s=duration_s,
                    connector_caps=caps)


def row(label: str, r: SimResult) -> str:
    return (f"{label:28s} ttft {r.ttft_mean()*1e3:8.1f} ms   "
            f"tpot {r.tpot_mean()*1e3:7.2f} ms   "
            f"tput {r.throughput_tok_s():8.1f} tok/s   "
            f"done {r.completed()}")
