"""Shared benchmark helpers: the paper's experimental platform (GPU A for
decode, GPU B for prefill, Llama2-7B) driven through the event simulator."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import get_config
from repro.core.planner.events import SimResult, simulate
from repro.core.planner.hardware import GPU_A, GPU_B
from repro.core.planner.simulator import (FrameworkModel, InstanceModel,
                                          ParallelStrategy)
from repro.core.planner.workload import Workload

CFG = get_config("llama2-7b")


def models(chunked_prefill: bool = False,
           prefill_chunk_tokens: int = 512):
    """(P on GPU B — compute-strong, D on GPU A — HBM-strong)."""
    fw = FrameworkModel(chunked_prefill=chunked_prefill,
                        prefill_chunk_tokens=prefill_chunk_tokens)
    return (InstanceModel(CFG, GPU_B, ParallelStrategy(), fw),
            InstanceModel(CFG, GPU_A, ParallelStrategy(), fw))


def run(wl: Workload, n_p: int = 1, n_d: int = 1, mode: str = "disagg",
        duration_s: float = 120.0, chunked_prefill: bool = False,
        prefill_chunk_tokens: int = 512) -> SimResult:
    mP, mD = models(chunked_prefill=chunked_prefill,
                    prefill_chunk_tokens=prefill_chunk_tokens)
    return simulate(CFG, wl, p_model=mP, d_model=mD, n_prefill=n_p,
                    n_decode=n_d, mode=mode, duration_s=duration_s)


def row(label: str, r: SimResult) -> str:
    return (f"{label:28s} ttft {r.ttft_mean()*1e3:8.1f} ms   "
            f"tpot {r.tpot_mean()*1e3:7.2f} ms   "
            f"tput {r.throughput_tok_s():8.1f} tok/s   "
            f"done {r.completed()}")
