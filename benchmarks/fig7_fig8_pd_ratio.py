"""Paper Figs. 7-8 — influence of the P:D ratio.

Fig. 7 (256+256, QPS 2): short context saturates — 2P1D ≈ 3P1D and
1P2D ≈ 1P3D (adding instances past the bottleneck buys nothing).
Fig. 8 (1024+1024): P-bound regime — the paper's stated condition is that
"the P instances cannot handle the requests", i.e. arrivals saturate one
prefill GPU. The paper reaches that at QPS 3 on its platform; our modeled
GPU B prefills 1024 tokens in ~51 ms, so the same regime needs the QPS
scaled to ≳1/l_p (hardware adaptation, not a different experiment). Both
the paper's literal QPS 3 point and the saturating point are reported; the
claim ("adding P produces an exponential TTFT reduction") is checked in
the saturating regime where it is defined.
"""
from __future__ import annotations

from repro.core.planner.workload import FIG7, FIG8, Workload

from benchmarks.common import models, row, run

RATIOS = [(1, 1), (2, 1), (3, 1), (1, 2), (1, 3)]


def main(duration: float = 120.0) -> dict:
    mP, _ = models()
    qps_sat = 1.25 / mP.prefill_latency(FIG8.input_len)
    fig8_sat = Workload(qps=round(qps_sat, 1), input_len=FIG8.input_len,
                        output_len=FIG8.output_len)
    res = {}
    for name, wl in (("Fig. 7 (256+256 QPS2)", FIG7),
                     ("Fig. 8 (1024+1024 QPS3, paper point)", FIG8),
                     (f"Fig. 8 regime (1024+1024 QPS{fig8_sat.qps:g}, "
                      f"P-saturating)", fig8_sat)):
        print(f"== {name}: P:D ratio sweep ==")
        for (n_p, n_d) in RATIOS:
            r = run(wl, n_p=n_p, n_d=n_d, duration_s=duration)
            res[(wl.qps, n_p, n_d)] = r
            print(row(f"{n_p}P{n_d}D", r))

    # Fig. 7 saturation claims
    t7 = {k[1:]: v.throughput_tok_s() for k, v in res.items()
          if k[0] == FIG7.qps}
    sat_p = abs(t7[(2, 1)] - t7[(3, 1)]) / t7[(2, 1)] < 0.05
    sat_d = abs(t7[(1, 2)] - t7[(1, 3)]) / t7[(1, 2)] < 0.05
    # Fig. 8: more P cuts TTFT sharply once P saturates
    f8_1p = res[(fig8_sat.qps, 1, 1)].ttft_mean()
    f8_2p = res[(fig8_sat.qps, 2, 1)].ttft_mean()
    f8_3p = res[(fig8_sat.qps, 3, 1)].ttft_mean()
    p_helps = f8_2p < 0.3 * f8_1p and f8_3p <= f8_2p * 1.05
    for k, v in (("Fig7: 2P1D ≈ 3P1D", sat_p), ("Fig7: 1P2D ≈ 1P3D", sat_d),
                 ("Fig8: P scaling collapses TTFT once P saturates",
                  p_helps)):
        print(f"  [{'ok' if v else 'X'}] {k}")
    assert sat_p and sat_d and p_helps
    return {"fig7": t7, "fig8_ttft": (f8_1p, f8_2p, f8_3p)}


if __name__ == "__main__":
    main()
