"""Zero-copy wire benchmark: fixed-layout segment codec vs legacy pickle.

Two measurements, legacy (``codec="pickle"``) and zero-copy
(``codec="fixed"``) side by side:

  * **encode+stage bytes/s** — P-side cost of putting one prefill chunk
    on the wire: ``encode_chunk`` + ``SharedMemoryConnector.stage`` into
    a real shm segment. The legacy path pickles host copies of every
    shard; the fixed path casts/quantizes through ``np.frombuffer``
    views straight into the segment. Synthetic Llama-like chunk sizes so
    the wire dominates, not model FLOPs.
  * **re-page tokens/s** — D-side cost of landing delivered chunks in
    the vendor pools, measured over a real streamed handoff (tiny model,
    mismatched P/D block sizes so every chunk straddles block edges).
    The legacy path decodes and RMW-scatters per entry; the fixed path
    decodes each chunk's slab in one pass and scatters once per pool
    with boundary-only overlay.

Pool bit-parity between the two codecs is asserted, not assumed, and the
streamed run also reports the measured wire/compute overlap fraction.
Writes ``BENCH_wire.json`` at the repo root (CI uploads it).

  PYTHONPATH=src python -m benchmarks.wire_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from types import SimpleNamespace

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.transport import SharedMemoryConnector
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_wire.json"

VENDOR_P = VendorProfile("benchB", block_size=8, layout="nhbd",
                         kv_dtype="float32", tp=2, hardware="gpu-b")
VENDOR_D = VendorProfile("benchA", block_size=4, layout="nbhd",
                         kv_dtype="float32", tp=1, hardware="gpu-a")

# re-page model: tiny FLOPs, real chunked prefill + streamed re-page
CFG = ModelConfig(name="wire-bench-tiny", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                  d_ff=128, vocab_size=512, param_dtype="float32",
                  compute_dtype="float32")


def _chunk_entries(layers: int, tokens: int, kv_heads: int, head_dim: int,
                   seed: int = 0):
    """Synthetic normalized prefill-chunk entries (Llama-like slab)."""
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(layers, tokens, kv_heads, head_dim)) \
        .astype(np.float32)
    v = rng.normal(size=(layers, tokens, kv_heads, head_dim)) \
        .astype(np.float32)
    return {"kv": [("kv", 0, 0, {"k": k, "v": v, "start": 0})],
            "length": tokens}


def bench_encode_stage(codec: str, wire: WireFormat, iters: int,
                       layers: int = 8, tokens: int = 256,
                       kv_heads: int = 8, head_dim: int = 64) -> dict:
    """P-side wall time of encode_chunk + stage into shm, per chunk."""
    chunk = _chunk_entries(layers, tokens, kv_heads, head_dim)
    payload_bytes = 2 * layers * tokens * kv_heads * head_dim * 4
    p_stub = SimpleNamespace(vendor=SimpleNamespace(tp=VENDOR_P.tp))
    conn = SharedMemoryConnector()
    pipe = DisaggPipeline(conn, wire, codec=codec)
    # warm once (shm segment pool, numpy temporaries)
    wired = pipe.encode_chunk(p_stub, chunk)
    meta = wired.meta() if hasattr(wired, "meta") else {"wire": wire}
    conn.stage("warm", wired, meta)
    conn.complete("warm")
    t0 = time.perf_counter()
    for i in range(iters):
        wired = pipe.encode_chunk(p_stub, chunk)
        meta = wired.meta() if hasattr(wired, "meta") else {"wire": wire}
        conn.stage(f"c{i}", wired, meta)
        conn.complete(f"c{i}")
    dt = time.perf_counter() - t0
    conn.close()
    return {"codec": codec, "iters": iters,
            "chunk_payload_bytes": payload_bytes,
            "seconds_per_chunk": round(dt / iters, 6),
            "encode_stage_bytes_per_s": round(payload_bytes * iters / dt)}


def _engines(seed: int = 0):
    import jax

    from repro.models import model as M
    params = M.init_params(jax.random.key(seed), CFG)
    p = Engine("P0", CFG, params, VENDOR_P, num_blocks=128, max_batch=4,
               max_seq_len=256, role="prefill")
    d = Engine("D0", CFG, params, VENDOR_D, num_blocks=128, max_batch=4,
               max_seq_len=256, role="decode")
    return p, d


def bench_repage(codec: str, wire: WireFormat, plen: int, chunk_tokens: int,
                 repeats: int) -> dict:
    """D-side re-page tokens/s over a real streamed handoff; the
    materialize calls are timed in isolation (device-synchronized)."""
    import jax

    rng = np.random.default_rng(11)
    prompt = rng.integers(0, CFG.vocab_size, plen).astype(np.int32)
    repage_s = [0.0]
    pools = None
    overlap = {}
    for rep in range(repeats + 1):           # rep 0 = jit warm-up, untimed
        p, d = _engines()
        conn = SharedMemoryConnector()
        pipe = DisaggPipeline(conn, wire, codec=codec)
        orig = pipe.materialize

        def timed(d_engine, *a, **kw):
            t0 = time.perf_counter()
            out = orig(d_engine, *a, **kw)
            jax.block_until_ready(jax.tree.leaves(d_engine.caches))
            if rep > 0:
                repage_s[0] += time.perf_counter() - t0
            return out

        pipe.materialize = timed
        req = Request(req_id=f"bench-{codec}-{rep}", prompt=prompt,
                      max_new_tokens=1)
        pipe.handoff_streamed(req, p, d, chunk_tokens=chunk_tokens,
                              chunked_compute=True)
        overlap = {
            "wall_handoff_s": round(conn.stats.wall_handoff_seconds, 4),
            "wall_overlap_s": round(conn.stats.wall_overlap_seconds, 4),
            "overlap_pct": round(
                100.0 * conn.stats.wall_overlap_seconds
                / max(conn.stats.wall_handoff_seconds, 1e-12), 1),
            "wire_bytes": conn.stats.bytes_moved,
            "payload_bytes": conn.stats.payload_bytes,
        }
        conn.close()
        pools = [np.asarray(x) for x in jax.tree.leaves(d.caches)]
    tokens = plen * repeats
    return {"codec": codec, "prompt_tokens": plen,
            "chunk_tokens": chunk_tokens, "repeats": repeats,
            "repage_seconds": round(repage_s[0], 4),
            "repage_tokens_per_s": round(tokens / repage_s[0])
            if repage_s[0] else None,
            **overlap}, pools


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizing")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    enc_iters = 8 if args.fast else 32
    plen, chunk, repeats = (96, 16, 2) if args.fast else (192, 16, 4)

    wire = WireFormat("raw", "float32")
    result = {"bench": "wire", "wire": "raw/float32",
              "vendors": f"{VENDOR_P.layout}/bs{VENDOR_P.block_size}"
                         f" -> {VENDOR_D.layout}/bs{VENDOR_D.block_size}",
              "encode_stage": {}, "repage": {}}

    for codec in ("pickle", "fixed"):
        result["encode_stage"][codec] = bench_encode_stage(
            codec, wire, enc_iters)
    es = result["encode_stage"]
    es["speedup"] = round(es["fixed"]["encode_stage_bytes_per_s"]
                          / es["pickle"]["encode_stage_bytes_per_s"], 2)

    pools = {}
    for codec in ("pickle", "fixed"):
        result["repage"][codec], pools[codec] = bench_repage(
            codec, wire, plen, chunk, repeats)
    rp = result["repage"]
    rp["speedup"] = round(rp["fixed"]["repage_tokens_per_s"]
                          / rp["pickle"]["repage_tokens_per_s"], 2)

    # parity is asserted, not assumed: both codecs land identical pools
    mismatch = sum(not np.array_equal(a, b)
                   for a, b in zip(pools["pickle"], pools["fixed"]))
    if mismatch:
        raise RuntimeError(
            f"codec parity violated: {mismatch} pool leaves differ")
    result["pools_bit_identical"] = True

    args.out.write_text(json.dumps(result, indent=2) + "\n")
    print(json.dumps(result, indent=2))
    print(f"\nwrote {args.out}")
    print(f"encode+stage: fixed {es['fixed']['encode_stage_bytes_per_s']:,}"
          f" B/s vs pickle {es['pickle']['encode_stage_bytes_per_s']:,} B/s"
          f"  ({es['speedup']}x)")
    print(f"re-page:      fixed {rp['fixed']['repage_tokens_per_s']:,}"
          f" tok/s vs pickle {rp['pickle']['repage_tokens_per_s']:,} tok/s"
          f"  ({rp['speedup']}x)")


if __name__ == "__main__":
    main()
