"""Kernel micro-benchmarks: Pallas (interpret=True, CPU) vs pure-jnp oracle.

Absolute µs on CPU interpret mode are NOT TPU performance — the value here
is (a) correctness at benchmark shapes, (b) the bytes/flops each kernel
moves (roofline inputs), (c) a regression guard on the reference path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.serving.paged_cache import KVPageSpec


def _t(fn, *args, reps=3, **kw):
    fn(*args, **kw).block_until_ready()          # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def main() -> list:
    rows = []
    print("== kernel micro (CPU interpret vs jnp oracle) ==")
    print(f"{'kernel':34s} {'shape':28s} {'ref_us':>10s} {'max_err':>9s}")

    ks = jax.random.split(jax.random.key(0), 3)
    for (b, h, kv, s, d) in [(1, 8, 2, 256, 64), (2, 16, 8, 512, 128)]:
        q = jax.random.normal(ks[0], (b, h, s, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, kv, s, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, kv, s, d), jnp.bfloat16)
        t_ref = _t(ref.flash_attention_ref, q, k, v)
        got = ops.flash_attention(q, k, v, force_interpret=True)
        err = float(jnp.max(jnp.abs(
            got.astype(jnp.float32)
            - ref.flash_attention_ref(q, k, v).astype(jnp.float32))))
        name = "flash_attention(causal)"
        print(f"{name:34s} b{b} h{h}/{kv} s{s} d{d:<6d} {t_ref:10.0f} {err:9.3f}")
        rows.append((name, t_ref, err))
        assert err < 3e-2

    for (b, h, kv, d, bs, pages) in [(4, 8, 2, 64, 16, 8),
                                     (8, 16, 8, 128, 16, 16)]:
        n = b * pages + 1
        q = jax.random.normal(ks[0], (b, h, d), jnp.bfloat16)
        kp = jax.random.normal(ks[1], (n, bs, kv, d), jnp.bfloat16)
        vp = jax.random.normal(ks[2], (n, bs, kv, d), jnp.bfloat16)
        table = jnp.asarray(
            np.random.default_rng(0).permutation(n - 1)[:b * pages]
            .reshape(b, pages) + 1, jnp.int32)
        lens = jnp.full((b,), bs * pages - 3, jnp.int32)
        t_ref = _t(ref.paged_attention_ref, q, kp, vp, table, lens)
        got = ops.paged_attention(q, kp, vp, table, lens,
                                  force_interpret=True)
        err = float(jnp.max(jnp.abs(
            got.astype(jnp.float32) -
            ref.paged_attention_ref(q, kp, vp, table, lens)
            .astype(jnp.float32))))
        name = "paged_attention(decode)"
        print(f"{name:34s} b{b} h{h}/{kv} {pages}p×{bs} d{d:<3d} "
              f"{t_ref:10.0f} {err:9.3f}")
        rows.append((name, t_ref, err))
        assert err < 3e-2

    for (src_l, dst_l, sbs, dbs) in [("nbhd", "nhdb", 16, 8),
                                     ("nhbd", "nbhd", 8, 16)]:
        kvh, hd, seq = 8, 128, 250
        src = KVPageSpec(sbs, src_l, "bfloat16", kvh, hd)
        dst = KVPageSpec(dbs, dst_l, "bfloat16", kvh, hd)
        sp = jax.random.normal(ks[0], src.pool_shape(src.blocks_for(seq) + 1)
                               ).astype(jnp.bfloat16)
        dpool = jnp.zeros(dst.pool_shape(dst.blocks_for(seq) + 1),
                          jnp.bfloat16)
        sb = jnp.arange(1, src.blocks_for(seq) + 1, dtype=jnp.int32)
        db = jnp.arange(1, dst.blocks_for(seq) + 1, dtype=jnp.int32)
        t_ref = _t(ref.repack_ref, src, dst, sp, sb, dpool, db, seq)
        got = ops.repack(src, dst, sp, sb, dpool, db, seq,
                         force_interpret=True)
        want = ref.repack_ref(src, dst, sp, sb, dpool, db, seq)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        name = f"kv_repack({src_l}{sbs}→{dst_l}{dbs})"
        print(f"{name:34s} seq{seq} kv{kvh} hd{hd:<7d} {t_ref:10.0f} {err:9.3f}")
        rows.append((name, t_ref, err))
        assert err == 0.0
    return rows


if __name__ == "__main__":
    main()
