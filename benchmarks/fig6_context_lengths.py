"""Paper Fig. 6 — influence of context length (P:D = 1:1, QPS 2).

Claims checked: TTFT grows with input length and is ~flat in output length;
TPOT grows with both (decode is memory-bound over the full KV); throughput
falls as context grows.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.planner.workload import PAPER_CONTEXTS, Workload

from benchmarks.common import row, run


def main(duration: float = 120.0) -> dict:
    print("== Fig. 6: context-length sweep (1P1D, QPS 2) ==")
    out = {}
    cap = {}
    for (i, o) in PAPER_CONTEXTS:
        wl = Workload(qps=2.0, input_len=i, output_len=o)
        r = run(wl, duration_s=duration)
        out[(i, o)] = r
        # capacity: saturating arrival rate → tokens/s at the roofline of
        # the pair (the regime where the paper's throughput plot lives)
        sat = run(Workload(qps=30.0, input_len=i, output_len=o),
                  duration_s=duration / 2)
        cap[(i, o)] = sat.throughput_tok_s() / (i + o) * o  # decode tokens
        print(row(f"{i}+{o}", r) + f"   capacity {cap[(i, o)]:7.0f} tok/s")

    # chunked streaming handoff (serving stack's StreamedHandoff): the P→D
    # wire overlaps chunk compute, so admission to decode is earlier at
    # long context — TTFT (set by prefill itself) must not move.
    wl_long = Workload(qps=2.0, input_len=1024, output_len=1024)
    chk = run(wl_long, duration_s=duration, chunked_prefill=True)
    print(row("1024+1024 chunked-stream", chk))

    # KV-connector wire models: the same sweep point with the wire sourced
    # from a connector's capabilities() descriptor. inproc declares the
    # default 25 Gbps and zero setup latency, so it must reproduce the
    # hard-coded-constant numbers exactly; modeled RDMA adds a per-read
    # setup latency and a preferred chunk granularity.
    inp = run(wl_long, duration_s=duration, chunked_prefill=True,
              connector="inproc")
    rdma = run(wl_long, duration_s=duration, chunked_prefill=True,
               connector="rdma")
    print(row("1024+1024 inproc-connector", inp))
    print(row("1024+1024 rdma-connector", rdma))

    ttft = {k: v.ttft_mean() for k, v in out.items()}
    tpot = {k: v.tpot_mean() for k, v in out.items()}
    mono_long = out[(1024, 1024)]
    checks = {
        "chunked stream ttft unchanged":
            abs(chk.ttft_mean() - mono_long.ttft_mean())
            <= 0.02 * mono_long.ttft_mean() + 1e-6,
        "chunked stream tpot no worse":
            chk.tpot_mean() <= mono_long.tpot_mean() * 1.02 + 1e-6,
        "ttft grows with input": ttft[(1024, 1024)] > ttft[(256, 256)] * 1.5,
        "ttft flat in output":
            abs(ttft[(512, 1024)] - ttft[(512, 512)])
            < 0.35 * ttft[(512, 512)] + 1e-4,
        "tpot grows with context": tpot[(1024, 1024)] > tpot[(256, 256)],
        "capacity falls with context":
            cap[(1024, 1024)] < cap[(256, 256)],
        # capabilities() plumb-through: a zero-setup-latency 25 Gbps
        # connector is the hard-coded constant, modulo chunk granularity
        "inproc connector caps match constant wire":
            abs(inp.ttft_mean() - chk.ttft_mean())
            <= 0.02 * chk.ttft_mean() + 1e-6,
        "rdma fixed latency not free":
            rdma.ttft_mean() >= inp.ttft_mean() - 1e-6,
    }
    for k, v in checks.items():
        print(f"  [{'ok' if v else 'X'}] {k}")
    assert all(checks.values()), checks
    return {"ttft": {f"{i}+{o}": v for (i, o), v in ttft.items()},
            "tpot": {f"{i}+{o}": v for (i, o), v in tpot.items()},
            "capacity_tok_s": {f"{i}+{o}": v for (i, o), v in cap.items()},
            "sweep": {f"{i}+{o}": r.summary() for (i, o), r in out.items()},
            "chunked_1024+1024": chk.summary(),
            "connector_1024+1024": {"inproc": inp.summary(),
                                    "rdma": rdma.summary()},
            "duration_s": duration, "checks": checks}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=120.0,
                    help="simulated seconds per sweep point")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the results as JSON (CI perf-trajectory "
                         "artifact)")
    args = ap.parse_args()
    results = main(duration=args.duration)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"results written to {args.json}")
