"""Version-compat shims for JAX symbols that moved between releases.

Two symbols this repo needs have different homes across the JAX versions we
support:

  * Pallas-TPU compiler params: ``pltpu.CompilerParams`` (new) vs
    ``pltpu.TPUCompilerParams`` (<= 0.4.x).
  * ``shard_map``: top-level ``jax.shard_map`` (new) vs
    ``jax.experimental.shard_map.shard_map``.

Resolution happens once at import; kernels and layers import from here so
the rest of the tree never version-checks.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as _pltpu


def _resolve_compiler_params():
    cls = getattr(_pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(_pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise ImportError(
            "no Pallas-TPU compiler-params class found (looked for "
            "pltpu.CompilerParams and pltpu.TPUCompilerParams)")
    return cls


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    import inspect
    params = inspect.signature(fn).parameters
    has_vma = "check_vma" in params
    has_rep = "check_rep" in params

    def wrapped(f, *args, **kwargs):
        """shard_map with the replication-check kwarg normalized: callers may
        pass either ``check_vma`` (new) or ``check_rep`` (old); the one the
        installed JAX understands is forwarded."""
        check = kwargs.pop("check_vma", kwargs.pop("check_rep", None))
        if check is not None:
            if has_vma:
                kwargs["check_vma"] = check
            elif has_rep:
                kwargs["check_rep"] = check
        return fn(f, *args, **kwargs)

    return wrapped


CompilerParams = _resolve_compiler_params()
shard_map = _resolve_shard_map()

__all__ = ["CompilerParams", "shard_map"]
