"""Paged decode attention — Pallas TPU kernel (decode hot spot).

TPU adaptation of PagedAttention: the block table is a *scalar-prefetch*
operand (PrefetchScalarGridSpec), so each grid step's K/V page is DMA'd
HBM→VMEM directly from the physical page the table points at — the
data-dependent indirection happens in the BlockSpec index_map, which is
exactly how the TPU pipelines dynamic gathers. Online-softmax state lives
in VMEM scratch across the page loop (minor-most, "arbitrary" dimension).

Pool layout must be canonical "nbhd" (num_blocks, block, kv, hd) — `ops.py`
pre-permutes other vendor layouts (that permutation IS the vendor-alignment
step and is benchmarked separately via kv_repack).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._jax_compat import CompilerParams

NEG_INF = -1e30


def _paged_kernel(block_tbl, seq_lens, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, block_size: int,
                  grp: int, window: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = seq_lens[b]
    pos = p * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)[0]

    @pl.when(p * block_size < seq_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)                # (h, d)
        k = k_ref[0].astype(jnp.float32)                # (bs, kv, d)
        v = v_ref[0].astype(jnp.float32)
        h, d = q.shape
        bs, kv, _ = k.shape
        qg = q.reshape(kv, grp, d)
        # scores: (kv, grp, bs)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32) * scale
        ok = pos < seq_len
        if window > 0:
            ok &= pos >= (seq_len - window)
        s = jnp.where(ok[None, None, :], s, NEG_INF)
        s2 = s.reshape(h, bs)
        m_prev = m_ref[...]
        m_cur = jnp.max(s2, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pr = jnp.exp(s2 - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pr, axis=-1, keepdims=True)
        # out: (kv, grp, d)
        o = jax.lax.dot_general(pr.reshape(kv, grp, bs), v,
                                (((2,), (0,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + o.reshape(h, d)
        m_ref[...] = m_new

    @pl.when(p == pl.num_programs(1) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_table: jax.Array, seq_lens: jax.Array, *,
                    scale: Optional[float] = None, window: int = 0,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, d); pools: (N, bs, KV, d) canonical layout;
    block_table: (B, max_pages) int32; seq_lens: (B,) int32 (lengths
    including the current token, already appended). Returns (B, H, d)."""
    b, h, d = q.shape
    n, bs, kv, _ = k_pool.shape
    assert h % kv == 0
    grp = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    max_pages = block_table.shape[1]

    kernel = functools.partial(_paged_kernel, scale=scale, block_size=bs,
                               grp=grp, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, max_pages),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b_, p_, bt, sl: (b_, 0, 0)),
            pl.BlockSpec((1, bs, kv, d),
                         lambda b_, p_, bt, sl: (bt[b_, p_], 0, 0, 0)),
            pl.BlockSpec((1, bs, kv, d),
                         lambda b_, p_, bt, sl: (bt[b_, p_], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, p_, bt, sl: (b_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, seq_lens, q, k_pool, v_pool)
