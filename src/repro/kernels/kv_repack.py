"""KV repack — Pallas TPU kernels for the VRAM-management alignment
component (paper Fig. 3).

Two kernels implementing the paper's flatten-to-1D method as fused
gather/scatter over paged pools:

  * ``gather_pages``  — P side: pool pages (any vendor layout) → contiguous
    canonical (S, kv, hd). Source page id comes from a scalar-prefetched
    block list (data-dependent DMA, same mechanism as paged attention).
  * ``scatter_pages`` — D side: canonical → pool pages in the D vendor's
    layout/block size/dtype. The destination page id is scalar-prefetched in
    the *output* index_map; untouched pool pages are preserved through
    input-output aliasing.

Layout permutation (nbhd / nhbd / nhdb) and dtype cast happen inside the
kernel — one pass over the data, no HBM round-trip for the transpose.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.serving.paged_cache import KVPageSpec, _FROM_CANON

# inverse permutation: layout page axes → canonical (block, kv, hd)
def _to_canon_perm(layout: str) -> Tuple[int, ...]:
    perm = _FROM_CANON[layout]
    inv = [0, 0, 0]
    for i, p in enumerate(perm):
        inv[p] = i
    return tuple(inv)


def _gather_kernel(block_ids, src_ref, out_ref, *, layout: str):
    page = src_ref[0]                                   # (*page_shape)
    canon = jnp.transpose(page, _to_canon_perm(layout))  # (bs, kv, hd)
    out_ref[0] = canon.astype(out_ref.dtype)


def gather_pages(spec: KVPageSpec, pool: jax.Array, block_ids: jax.Array,
                 out_dtype=None, interpret: bool = False) -> jax.Array:
    """pool: (N, *spec.page_shape()); block_ids: (nb,) int32.
    Returns canonical pages (nb, bs, kv, hd) in ``out_dtype``."""
    nb = block_ids.shape[0]
    bs, kv, hd = spec.block_size, spec.kv_heads, spec.head_dim
    out_dtype = out_dtype or pool.dtype
    kernel = functools.partial(_gather_kernel, layout=spec.layout)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1,) + spec.page_shape(),
                               lambda i, ids: (ids[i], 0, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, kv, hd),
                               lambda i, ids: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, bs, kv, hd), out_dtype),
        interpret=interpret,
    )(block_ids, pool)


def _scatter_kernel(block_ids, canon_ref, pool_in_ref, pool_out_ref, *,
                    layout: str):
    canon = canon_ref[0]                                 # (bs, kv, hd)
    perm = _FROM_CANON[layout]
    pool_out_ref[0] = jnp.transpose(canon, perm).astype(pool_out_ref.dtype)


def scatter_pages(spec: KVPageSpec, pool: jax.Array, block_ids: jax.Array,
                  canon: jax.Array, interpret: bool = False) -> jax.Array:
    """canon: (nb, bs, kv, hd) canonical pages → write into ``pool`` at
    ``block_ids`` in the vendor layout. Returns the updated pool (aliased)."""
    nb = block_ids.shape[0]
    kernel = functools.partial(_scatter_kernel, layout=spec.layout)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,) + (spec.block_size, spec.kv_heads,
                                 spec.head_dim),
                         lambda i, ids: (i, 0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),     # aliased full pool
        ],
        out_specs=pl.BlockSpec((1,) + spec.page_shape(),
                               lambda i, ids: (ids[i], 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, spec.jdtype),
        input_output_aliases={2: 0},   # pool (after scalar-prefetch + canon)
        interpret=interpret,
    )(block_ids, canon, pool)


def _scatter_overlay_kernel(block_ids, canon_ref, cur_ref, pool_in_ref,
                            pool_out_ref, *, layout: str, front: int,
                            seq_len: int, block_size: int):
    i = pl.program_id(0)
    canon = canon_ref[0]                                 # (bs, kv, hd)
    cur = jnp.transpose(cur_ref[0], _to_canon_perm(layout))
    row = jax.lax.broadcasted_iota(jnp.int32, canon.shape, 0)
    abs_row = i * block_size + row
    keep = (abs_row < front) | (abs_row >= front + seq_len)
    merged = jnp.where(keep, cur, canon.astype(cur.dtype))
    perm = _FROM_CANON[layout]
    pool_out_ref[0] = jnp.transpose(merged, perm).astype(pool_out_ref.dtype)


def scatter_pages_overlay(spec: KVPageSpec, pool: jax.Array,
                          block_ids: jax.Array, canon: jax.Array,
                          front: int, seq_len: int,
                          interpret: bool = False) -> jax.Array:
    """Scatter canonical pages into ``pool`` while preserving rows outside
    ``[front, front + seq_len)`` of the flattened page span.

    ``canon``: (nb, bs, kv, hd) pages whose flat rows ``front .. front +
    seq_len`` hold the incoming stream (outside that range the content is
    ignored). Each grid step reads the *current* destination page — the same
    data-dependent ``ids[i]`` prefetch as the scatter — and overlays only
    the covered rows, so partial head/tail blocks merge inside the kernel:
    no host-side readback, one pass per page. ``front``/``seq_len`` are
    host-known and baked into the kernel."""
    nb = block_ids.shape[0]
    kernel = functools.partial(
        _scatter_overlay_kernel, layout=spec.layout, front=front,
        seq_len=seq_len, block_size=spec.block_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, spec.block_size, spec.kv_heads, spec.head_dim),
                         lambda i, ids: (i, 0, 0, 0)),
            pl.BlockSpec((1,) + spec.page_shape(),       # current dst page
                         lambda i, ids: (ids[i], 0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),        # aliased full pool
        ],
        out_specs=pl.BlockSpec((1,) + spec.page_shape(),
                               lambda i, ids: (ids[i], 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, spec.jdtype),
        input_output_aliases={3: 0},   # pool (after prefetch, canon, cur)
        interpret=interpret,
    )(block_ids, canon, pool, pool)


def repack(src: KVPageSpec, dst: KVPageSpec, src_pool: jax.Array,
           src_blocks: jax.Array, dst_pool: jax.Array,
           dst_blocks: jax.Array, seq_len: int,
           interpret: bool = False) -> jax.Array:
    """Full vendor-alignment path: gather from P pool (src layout/blocksize)
    → canonical 1-D stream → scatter into D pool (dst layout/blocksize).

    seq_len tokens move; block counts follow each side's block size."""
    canon_pages = gather_pages(src, src_pool, src_blocks,
                               out_dtype=dst.jdtype, interpret=interpret)
    flat = canon_pages.reshape(-1, src.kv_heads, src.head_dim)[:seq_len]
    nb_d = dst.blocks_for(seq_len)
    pad = nb_d * dst.block_size - seq_len
    flat = jnp.pad(flat, ((0, pad), (0, 0), (0, 0)))
    canon_d = flat.reshape(nb_d, dst.block_size, dst.kv_heads, dst.head_dim)
    return scatter_pages(dst, dst_pool, dst_blocks[:nb_d], canon_d,
                         interpret=interpret)
