"""Causal flash attention — Pallas TPU kernel (prefill hot spot).

TPU adaptation of the FlashAttention-2 schedule: the KV-block loop is the
minor-most ("arbitrary") grid dimension so the running max / sum / output
accumulator live in VMEM scratch across iterations; Q/K/V tiles are
MXU-aligned BlockSpecs streamed HBM→VMEM by the pipeline. GQA folds query
heads onto KV heads through the K/V index map (no KV duplication in HBM).

Supports: causal or full, optional sliding window, GQA (h % kv == 0).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._jax_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    run = True
    if causal:
        # whole block strictly above the diagonal → nothing to do
        run = (ik * block_k) <= (iq * block_q + block_q - 1)

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, d); k, v: (B, KV, Skv, d). Returns (B, H, Sq, d).

    Sq/Skv are padded to block multiples internally; padded KV positions are
    masked via ``kv_len``.
    """
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    grp = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(skv, 8))
    pq = (-sq) % block_q
    pk = (-skv) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    grid = (b, h, (sq + pq) // block_q, (skv + pk) // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, g=grp: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, g=grp: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
