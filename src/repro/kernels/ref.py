"""Pure-jnp oracles for every Pallas kernel (the numerically-authoritative
references the per-kernel shape/dtype sweeps assert against)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.serving.paged_cache import (KVPageSpec, pages_from_canonical,
                                       pages_to_canonical)

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B,H,Sq,d); k,v: (B,KV,Skv,d) → (B,H,Sq,d). Full-materialized."""
    b, h, sq, d = q.shape
    kv, skv = k.shape[1], k.shape[2]
    grp = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, kv, grp, sq, d).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kj <= qi
    if window > 0:
        ok &= (qi - kj) < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_table: jax.Array, seq_lens: jax.Array, *,
                        scale: Optional[float] = None,
                        window: int = 0) -> jax.Array:
    """q: (B,H,d); pools canonical (N,bs,KV,d); → (B,H,d)."""
    b, h, d = q.shape
    n, bs, kv, _ = k_pool.shape
    grp = h // kv
    maxp = block_table.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = k_pool[block_table.reshape(-1)].reshape(b, maxp * bs, kv, d)
    v = v_pool[block_table.reshape(-1)].reshape(b, maxp * bs, kv, d)
    qg = q.reshape(b, kv, grp, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    pos = jnp.arange(maxp * bs)[None]
    ok = pos < seq_lens[:, None]
    if window > 0:
        ok &= pos >= (seq_lens[:, None] - window)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


def gather_pages_ref(spec: KVPageSpec, pool: jax.Array,
                     block_ids: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or pool.dtype
    return pages_to_canonical(spec, pool[block_ids]).astype(out_dtype)


def scatter_pages_ref(spec: KVPageSpec, pool: jax.Array,
                      block_ids: jax.Array, canon: jax.Array) -> jax.Array:
    return pool.at[block_ids].set(
        pages_from_canonical(spec, canon).astype(pool.dtype))


def repack_ref(src: KVPageSpec, dst: KVPageSpec, src_pool: jax.Array,
               src_blocks: jax.Array, dst_pool: jax.Array,
               dst_blocks: jax.Array, seq_len: int) -> jax.Array:
    canon = gather_pages_ref(src, src_pool, src_blocks, out_dtype=dst.jdtype)
    flat = canon.reshape(-1, src.kv_heads, src.head_dim)[:seq_len]
    nb_d = dst.blocks_for(seq_len)
    pad = nb_d * dst.block_size - seq_len
    flat = jnp.pad(flat, ((0, pad), (0, 0), (0, 0)))
    canon_d = flat.reshape(nb_d, dst.block_size, dst.kv_heads, dst.head_dim)
    return scatter_pages_ref(dst, dst_pool, dst_blocks[:nb_d], canon_d)
