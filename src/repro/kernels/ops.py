"""Jit'd public wrappers around the Pallas kernels.

On TPU the kernels run compiled; elsewhere (this CPU container) they run in
``interpret=True`` mode, which executes the kernel body op-by-op — the
correctness path the test sweeps exercise. ``force_interpret`` pins the
mode for tests.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import kv_repack as _kr
from repro.kernels import paged_attention as _pa
from repro.serving.paged_cache import KVPageSpec


def _interpret(force: Optional[bool]) -> bool:
    if force is not None:
        return force
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "force_interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    force_interpret: Optional[bool] = None):
    """Causal flash attention. q: (B,H,Sq,d); k,v: (B,KV,Skv,d)."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret(force_interpret))


@partial(jax.jit, static_argnames=("window", "force_interpret"))
def paged_attention(q, k_pool, v_pool, block_table, seq_lens, *,
                    window: int = 0,
                    force_interpret: Optional[bool] = None):
    """Decode attention over paged pools. q: (B,H,d); pools (N,bs,KV,d)."""
    return _pa.paged_attention(q, k_pool, v_pool, block_table, seq_lens,
                               window=window,
                               interpret=_interpret(force_interpret))


@partial(jax.jit, static_argnames=("spec", "force_interpret"))
def gather_pages(spec: KVPageSpec, pool, block_ids, *,
                 force_interpret: Optional[bool] = None):
    return _kr.gather_pages(spec, pool, block_ids,
                            interpret=_interpret(force_interpret))


@partial(jax.jit, static_argnames=("spec", "force_interpret"))
def scatter_pages(spec: KVPageSpec, pool, block_ids, canon, *,
                  force_interpret: Optional[bool] = None):
    return _kr.scatter_pages(spec, pool, block_ids, canon,
                             interpret=_interpret(force_interpret))


@partial(jax.jit, static_argnames=("spec", "front", "seq_len",
                                   "force_interpret"))
def scatter_pages_overlay(spec: KVPageSpec, pool, block_ids, canon, *,
                          front: int, seq_len: int,
                          force_interpret: Optional[bool] = None):
    """Scatter preserving rows outside [front, front+seq_len) (streamed
    chunk re-page: partial head/tail blocks merge inside the kernel)."""
    return _kr.scatter_pages_overlay(spec, pool, block_ids, canon, front,
                                     seq_len,
                                     interpret=_interpret(force_interpret))


@partial(jax.jit, static_argnames=("src", "dst", "seq_len",
                                   "force_interpret"))
def repack(src: KVPageSpec, dst: KVPageSpec, src_pool, src_blocks,
           dst_pool, dst_blocks, seq_len: int, *,
           force_interpret: Optional[bool] = None):
    """Vendor alignment: P pool → canonical 1-D → D pool (paper Fig. 3)."""
    return _kr.repack(src, dst, src_pool, src_blocks, dst_pool, dst_blocks,
                      seq_len, interpret=_interpret(force_interpret))
