"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (one TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods; the
"pod" axis is the slow DCN dimension (data parallel across pods, gradient
all-reduce hierarchical, KV-handoff P→D crosses it in disaggregated
serving).

Functions, not module-level constants, so importing this module never
touches jax device state (device count is locked at first jax init —
dryrun.py must set XLA_FLAGS before any import).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (hillclimb variants: e.g. (8, 32), (4, 64))."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes of a mesh — ('pod','data') when multi-pod."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def device_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
