"""Step builders: (cell, mesh) → AOT-lowerable jitted programs.

Three program kinds, matching the paper's instance roles:
  train_step    — loss/grad/AdamW (ZeRO-1, microbatched, remat)
  prefill_step  — P instance: prompt → (first-token logits, KV caches)
  serve_step    — D instance: one decode token against seq_len-deep caches

Each builder returns a ``StepArtifacts`` with the jitted fn, abstract args,
and the sharding trees, so dryrun / roofline / launchers share one source
of truth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, get_config
from repro.launch import sharding as SH
from repro.launch.cells import Cell
from repro.launch.mesh import data_axes, model_axis
from repro.models import dist
from repro.models import model as M
from repro.training.optim import AdamWConfig
from repro.training.train_step import (TrainState, abstract_train_state,
                                       make_train_step)


@dataclasses.dataclass
class StepArtifacts:
    name: str
    cfg: ModelConfig                 # deployed (padded) config
    fn: Any                          # jitted, AOT-lowerable
    abstract_args: Tuple[Any, ...]   # ShapeDtypeStruct pytrees
    in_shardings: Any
    out_shardings: Any

    def lower(self):
        return self.fn.lower(*self.abstract_args)


def _axis_sizes(mesh) -> Tuple[Tuple[str, ...], int, str, int]:
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    m = model_axis(mesh)
    return dp, dp_size, m, (mesh.shape[m] if m else 1)


def _dctx(mesh, dp, m, *, mode: str, unroll: bool,
          chunk_size: int = 1024, act_seq: bool = False,
          attn_p_bf16: bool = False) -> dist.DistContext:
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    return dist.DistContext(
        mesh=mesh, dp_axes=dp, model_axis=m,
        chunk_kv=8192 if mode in ("train", "prefill") else 0,
        chunk_size=chunk_size,
        moe_shard_map=True,
        attn_p_bf16=attn_p_bf16,
        unroll=unroll,
        # act_seq: Megatron-style sequence parallelism on the residual
        # stream (hillclimb variant — cuts boundary-activation memory 16×
        # for per-layer all-gathers at attention/MLP entry)
        act_spec=P(dp_spec, m if act_seq else None, None))


def _input_structs(cfg: ModelConfig, cell: Cell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = cell.batch, cell.seq_len
    sds = jax.ShapeDtypeStruct
    if cell.mode == "decode":
        return {"tokens": sds((b, 1), jnp.int32),
                "positions": sds((b, 1), jnp.int32)}
    toks = s
    out: Dict[str, Any] = {}
    if cfg.frontend.kind == "vision":
        npatch = cfg.frontend.num_patches
        toks = s - npatch
        out["patches"] = sds((b, npatch, cfg.d_model), jnp.bfloat16)
    if cfg.is_enc_dec:
        out["frames"] = sds((b, cfg.max_source_len, cfg.d_model),
                            jnp.bfloat16)
    out["tokens"] = sds((b, toks), jnp.int32)
    if cell.mode == "train":
        out["labels"] = sds((b, toks), jnp.int32)
    return out


# --------------------------------------------------------------------------- #
def make_train_artifacts(cell: Cell, mesh, *, unroll: bool = False,
                         layer_override: Optional[Dict[str, int]] = None,
                         chunk_size: int = 1024, act_seq: bool = False
                         ) -> StepArtifacts:
    dp, dp_size, m, m_size = _axis_sizes(mesh)
    cfg = SH.deploy_config(get_config(cell.arch), m_size, "train")
    if layer_override:
        cfg = cfg.with_(**layer_override)
    dctx = _dctx(mesh, dp, m, mode="train", unroll=unroll,
                 chunk_size=chunk_size,
                 act_seq=act_seq or getattr(cell, "act_seq", False))

    state_abs = abstract_train_state(cfg)
    pspecs = SH.param_pspecs(state_abs.params, cfg, m, m_size)
    if cell.zero3:
        # FSDP: shard weights over the data axes too; the per-layer
        # all-gather is inserted by GSPMD inside the scan body.
        pspecs = jax.tree.map(
            lambda sd, sp: SH.zero1_pspec(sp, sd.shape, dp, dp_size),
            state_abs.params, pspecs)
    ospecs = SH.opt_pspecs(state_abs.opt, pspecs, dp, dp_size)
    batch_abs = _input_structs(cfg, cell)
    bspecs = SH.batch_pspecs(batch_abs, dp, dp_size)

    state_sh = TrainState(params=SH.to_shardings(mesh, pspecs),
                          opt=SH.to_shardings(mesh, ospecs))
    batch_sh = SH.to_shardings(mesh, bspecs)
    metrics_sh = {k: NamedSharding(mesh, P())
                  for k in ("loss", "grad_norm", "lr")}

    accum_sh = None
    if cell.n_micro > 1:
        accum_specs = jax.tree.map(
            lambda sd, sp: SH.zero1_pspec(sp, sd.shape, dp, dp_size),
            state_abs.params, pspecs)
        accum_sh = SH.to_shardings(mesh, accum_specs)
    step = make_train_step(cfg, AdamWConfig(), remat=True,
                           n_micro=cell.n_micro, accum_shardings=accum_sh)

    def wrapped(state, batch):
        with dist.use(dctx):
            return step(state, batch)

    fn = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, metrics_sh), donate_argnums=(0,))
    return StepArtifacts(name=f"{cell.name}:train", cfg=cfg, fn=fn,
                         abstract_args=(state_abs, batch_abs),
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, metrics_sh))


# --------------------------------------------------------------------------- #
def make_prefill_artifacts(cell: Cell, mesh, *, unroll: bool = False,
                           layer_override: Optional[Dict[str, int]] = None,
                           chunk_size: int = 1024, act_seq: bool = False,
                           attn_p_bf16: bool = False) -> StepArtifacts:
    dp, dp_size, m, m_size = _axis_sizes(mesh)
    cfg = SH.deploy_config(get_config(cell.arch), m_size, "prefill")
    if layer_override:
        cfg = cfg.with_(**layer_override)
    dctx = _dctx(mesh, dp, m, mode="prefill", unroll=unroll,
                 chunk_size=chunk_size, act_seq=act_seq,
                 attn_p_bf16=attn_p_bf16)
    b, s = cell.batch, cell.seq_len
    mem_len = cfg.max_source_len if cfg.is_enc_dec else 0

    params_abs = M.abstract_params(cfg)
    pspecs = SH.param_pspecs(params_abs, cfg, m, m_size)
    inputs_abs = _input_structs(cfg, cell)
    ispecs = SH.batch_pspecs(inputs_abs, dp, dp_size)
    caches_abs = M.abstract_caches(cfg, b, s, jnp.dtype(cell.cache_dtype),
                                   mem_len=mem_len)
    cspecs = SH.cache_pspecs(caches_abs, cfg, b, dp, dp_size, m, m_size,
                             mode="prefill")

    params_sh = SH.to_shardings(mesh, pspecs)
    inputs_sh = SH.to_shardings(mesh, ispecs)
    caches_sh = SH.to_shardings(mesh, cspecs)
    logits_sh = NamedSharding(mesh, P(SH._dp(b, dp, dp_size), m))

    def prefill_step(params, inputs):
        with dist.use(dctx):
            caches = M.init_caches(cfg, b, s, jnp.dtype(cell.cache_dtype),
                                   mem_len=mem_len)
            last, caches = M.prefill(params, cfg, inputs, caches)
            return last, caches

    fn = jax.jit(prefill_step, in_shardings=(params_sh, inputs_sh),
                 out_shardings=(logits_sh, caches_sh))
    return StepArtifacts(name=f"{cell.name}:prefill", cfg=cfg, fn=fn,
                         abstract_args=(params_abs, inputs_abs),
                         in_shardings=(params_sh, inputs_sh),
                         out_shardings=(logits_sh, caches_sh))


# --------------------------------------------------------------------------- #
def make_serve_artifacts(cell: Cell, mesh, *, unroll: bool = False,
                         layer_override: Optional[Dict[str, int]] = None,
                         chunk_size: int = 1024, act_seq: bool = False
                         ) -> StepArtifacts:
    """One-token decode against a KV cache holding cell.seq_len context."""
    dp, dp_size, m, m_size = _axis_sizes(mesh)
    cfg = SH.deploy_config(get_config(cell.arch), m_size, "decode")
    if layer_override:
        cfg = cfg.with_(**layer_override)
    dctx = _dctx(mesh, dp, m, mode="decode", unroll=unroll,
                 chunk_size=chunk_size)
    b = cell.batch
    cap = cell.decode_capacity()
    mem_len = cfg.max_source_len if cfg.is_enc_dec else 0

    params_abs = M.abstract_params(cfg)
    pspecs = SH.param_pspecs(params_abs, cfg, m, m_size)
    caches_abs = M.abstract_caches(cfg, b, cap, jnp.dtype(cell.cache_dtype),
                                   mem_len=mem_len)
    cspecs = SH.cache_pspecs(caches_abs, cfg, b, dp, dp_size, m, m_size,
                             mode="decode")
    tok_abs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
               "positions": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    tspecs = SH.batch_pspecs(tok_abs, dp, dp_size)

    params_sh = SH.to_shardings(mesh, pspecs)
    caches_sh = SH.to_shardings(mesh, cspecs)
    tok_sh = SH.to_shardings(mesh, tspecs)
    logits_sh = NamedSharding(mesh, P(SH._dp(b, dp, dp_size), None, m))

    def serve_step(params, caches, io):
        with dist.use(dctx):
            logits, caches = M.decode_step(params, cfg, io["tokens"],
                                           io["positions"], caches)
            return logits, caches

    fn = jax.jit(serve_step, in_shardings=(params_sh, caches_sh, tok_sh),
                 out_shardings=(logits_sh, caches_sh), donate_argnums=(1,))
    return StepArtifacts(name=f"{cell.name}:decode", cfg=cfg, fn=fn,
                         abstract_args=(params_abs, caches_abs, tok_abs),
                         in_shardings=(params_sh, caches_sh, tok_sh),
                         out_shardings=(logits_sh, caches_sh))


# --------------------------------------------------------------------------- #
def make_handoff_artifacts(arch: str, mesh, *,
                           layer_override: Optional[Dict[str, int]] = None
                           ) -> StepArtifacts:
    """The P→D KV handoff as ONE lowered program — the paper's
    heterogeneous-compatible transmission module at pod scale:

      * parallel-strategy alignment: prefill emits hd-sharded caches, the
        decode instance wants capacity-sharded ones → the reshard lowers
        to the all-to-all a real transfer engine would schedule;
      * data alignment: the prefill deployment pads kv heads for TP — the
        pad heads are sliced off;
      * VRAM-management alignment: the decode capacity (seq+margin) is
        padded onto the sequence axis;
      * precision alignment: cast to the decode cell's KV dtype (fp8 for
        qwen1.5-32b).

    Runs at the prefill batch (one P instance's output)."""
    from repro.launch.cells import get_cell
    import jax.numpy as jnp

    dp, dp_size, m, m_size = _axis_sizes(mesh)
    cell_p = get_cell(arch, "prefill_32k")
    cell_d = get_cell(arch, "decode_32k")
    cfg_p = SH.deploy_config(get_config(arch), m_size, "prefill")
    cfg_d = SH.deploy_config(get_config(arch), m_size, "decode")
    if layer_override:
        cfg_p = cfg_p.with_(**layer_override)
        cfg_d = cfg_d.with_(**layer_override)
    b = cell_p.batch
    s, cap = cell_p.seq_len, cell_d.decode_capacity()
    mem_len = cfg_p.max_source_len if cfg_p.is_enc_dec else 0
    kv_d = max(cfg_d.num_kv_heads, 1)
    d_dtype = jnp.dtype(cell_d.cache_dtype)

    caches_p = M.abstract_caches(cfg_p, b, s, jnp.dtype(cell_p.cache_dtype),
                                 mem_len=mem_len)
    caches_d = M.abstract_caches(cfg_d, b, cap, d_dtype, mem_len=mem_len)
    specs_p = SH.cache_pspecs(caches_p, cfg_p, b, dp, dp_size, m, m_size,
                              mode="prefill")
    specs_d = SH.cache_pspecs(caches_d, cfg_d, b, dp, dp_size, m, m_size,
                              mode="decode")

    def realign(path, src, dst_abs):
        name = SH._leaf_name(path)
        x = src
        if name in ("k", "v", "cross_k", "cross_v") \
                and x.shape[3] != dst_abs.shape[3]:
            x = x[:, :, :, :kv_d]                  # drop TP pad heads
        if name in ("k", "v", "pos", "ckv", "kpe") \
                and x.shape[2] != dst_abs.shape[2]:
            pad = dst_abs.shape[2] - x.shape[2]    # decode margin
            widths = [(0, 0)] * x.ndim
            widths[2] = (0, pad)
            x = jnp.pad(x, widths,
                        constant_values=(-1 if name == "pos" else 0))
        return x.astype(dst_abs.dtype)

    def handoff(caches):
        flat_p = jax.tree_util.tree_flatten_with_path(caches)[0]
        flat_d, treedef = jax.tree_util.tree_flatten(caches_d)
        out = [realign(kp, leaf, dabs)
               for (kp, leaf), dabs in zip(flat_p, flat_d)]
        return jax.tree_util.tree_unflatten(treedef, out)

    fn = jax.jit(handoff,
                 in_shardings=(SH.to_shardings(mesh, specs_p),),
                 out_shardings=SH.to_shardings(mesh, specs_d),
                 donate_argnums=(0,))
    return StepArtifacts(name=f"{arch}@handoff", cfg=cfg_d, fn=fn,
                         abstract_args=(caches_p,),
                         in_shardings=(specs_p,), out_shardings=specs_d)


def make_artifacts(cell: Cell, mesh, **kw) -> StepArtifacts:
    if cell.mode == "train":
        return make_train_artifacts(cell, mesh, **kw)
    if cell.mode == "prefill":
        return make_prefill_artifacts(cell, mesh, **kw)
    return make_serve_artifacts(cell, mesh, **kw)
