"""Training launcher: lower + AOT-compile the train_step for an assigned
architecture on the production mesh (ZeRO-1/3, microbatched, remat).
For a runnable local training loop see examples/train_tiny.py.

  python -m repro.launch.train --arch qwen3-4b [--multi-pod]
"""
import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = \
        "--xla_force_host_platform_device_count=512 " \
        "--xla_disable_hlo_passes=while-loop-invariant-code-motion," \
        "while-loop-expensive-invariant-code-motion"

import argparse
import time


def compile_at_scale(arch: str, multi_pod: bool) -> None:
    from repro.launch.cells import get_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_train_artifacts
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = get_cell(arch, "train_4k")
    art = make_train_artifacts(cell, mesh)
    compiled = art.lower().compile()
    ma = compiled.memory_analysis()
    tot = (ma.argument_size_in_bytes + ma.output_size_in_bytes
           + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    print(f"[ok] {art.name}: compiled for {mesh.devices.size} chips "
          f"(zero3={cell.zero3}, n_micro={cell.n_micro}), "
          f"{tot/2**30:.2f} GiB/chip")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    t0 = time.time()
    compile_at_scale(args.arch, args.multi_pod)
    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
