"""Sharding rules: params / caches / batches / optimizer state → PartitionSpec.

Scheme (Megatron-style TP on the "model" axis, DP over ("pod","data")):

  * attention: q/o weights sharded on the *head* axis; k/v weights on
    head_dim (kv-head counts of 8/10/20/40 never divide a 16-way axis).
    Head counts that do not divide the model axis are PADDED at deploy time
    (``pad_heads``) for train/prefill programs — the same data-alignment
    padding the planner's ``align_ops`` models; decode runs unpadded.
  * MLP: column (d_ff) then row (d_ff) — classic col/row pair.
  * MoE: expert-TP (d_ff_expert sharded), matching the shard_map MoE's
    in_specs; EP over the model axis is a hillclimb variant.
  * embeddings/LM head: vocab-sharded (padded to the axis via ``pad_vocab``).
  * decode KV caches: sharded on the *capacity* (sequence) axis — the
    flash-decoding layout: per-chip partial attention + tiny stat psums.
    Prefill emits hd-sharded caches; the P→D handoff reshards (the paper's
    parallel-strategy alignment, at pod scale).
  * ZeRO-1: optimizer moments additionally sharded over "data" on the first
    free divisible dim.

Every rule falls back towards replication when a dim is not divisible by
the axis size — jit rejects uneven input shardings.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Params = Any


# --------------------------------------------------------------------------- #
# Deploy-time config transforms (data alignment)
# --------------------------------------------------------------------------- #
def pad_heads(cfg: ModelConfig, axis: int) -> ModelConfig:
    """Pad num_heads up to a multiple of the model axis, then num_kv_heads
    up to the nearest divisor of the padded head count (grp stays integral).
    Identity when already aligned."""
    h = cfg.num_heads
    if h <= 0 or cfg.attention_kind == "none":
        return cfg
    h2 = math.ceil(h / axis) * axis if h % axis else h
    kv = max(cfg.num_kv_heads, 1)
    kv2 = kv
    while h2 % kv2:
        kv2 += 1
    if (h2, kv2) == (h, kv):
        return cfg
    # keep hd explicit so padding heads does not change per-head dim
    return cfg.with_(num_heads=h2, num_kv_heads=kv2, head_dim=cfg.hd)


def pad_vocab(cfg: ModelConfig, axis: int) -> ModelConfig:
    v = cfg.vocab_size
    v2 = math.ceil(v / axis) * axis
    return cfg if v2 == v else cfg.with_(vocab_size=v2)


def deploy_config(cfg: ModelConfig, axis: int, mode: str) -> ModelConfig:
    """The deployment model for a given program kind.

    train/prefill shard attention scores on heads → need head padding;
    decode shards scores on the cache capacity axis → unpadded."""
    cfg = pad_vocab(cfg, axis)
    if mode in ("train", "prefill"):
        cfg = pad_heads(cfg, axis)
    return cfg


# --------------------------------------------------------------------------- #
# Param rules
# --------------------------------------------------------------------------- #
def _div(n: int, size: int) -> bool:
    return n >= size and n % size == 0


def _pick(shape: Tuple[int, ...], prefs: Tuple[int, ...], size: int
          ) -> Optional[int]:
    for d in prefs:
        if d < len(shape) and _div(shape[d], size):
            return d
    return None


_LEAF_PREFS = {
    # name: preference order of dims (unstacked leaf coordinates)
    "embed": (0, 1),          # (V, d)
    "lm_head": (1, 0),        # (d, V)
    "wq": (1, 2),             # (d, h, hd)
    "wk": (2,),               # (d, kv, hd) → hd only (kv never divides)
    "wv": (2,),
    "wo": (0, 1),             # (h, hd, d)
    "bq": (0, 1),             # (h, hd)
    "bk": (1,),               # (kv, hd)
    "bv": (1,),
    "w_ukv": (1,),            # (lora, h, ·)
    "w_gate": (1,),           # (d, f) | moe (E, d, fe) handled by ndim
    "w_up": (1,),
    "w_down": (0,),           # (f, d) | moe (E, fe, d)
    "w_x": (1,),              # rglru (d, w)
    "conv_w": (1,),           # rglru (K, w)
    "conv_b": (0,),
    "lru_in_w": (1,),         # (w, w)
    "lru_a_w": (1,),
    "lru_in_b": (0,),
    "lru_a_b": (0,),
    "lam": (0,),
    "w_out": (0,),            # rglru (w, d)
}

_MOE_PREFS = {"w_gate": (2,), "w_up": (2,), "w_down": (1,)}     # (E,d,fe)
_REPLICATED = {"router", "w_dkv", "kv_norm", "q_norm", "k_norm",
               "norm", "norm1", "norm2", "norm_x", "final_norm", "enc_norm"}


def _key_name(k) -> Optional[str]:
    if isinstance(k, jax.tree_util.DictKey):
        return k.key
    if isinstance(k, jax.tree_util.GetAttrKey):
        return k.name
    return None


def _leaf_name(path) -> str:
    for k in reversed(path):
        n = _key_name(k)
        if n is not None:
            return n
    return ""


def _in_subtree(path, name: str) -> bool:
    return any(_key_name(k) == name for k in path)


def param_pspec(path, leaf, cfg: ModelConfig, axis_name: str,
                axis_size: int) -> P:
    """PartitionSpec for one param leaf (stacked group params have a
    leading ``count`` dim, detected via the 'groups' path)."""
    name = _leaf_name(path)
    shape = tuple(leaf.shape)
    stacked = _in_subtree(path, "groups") or _in_subtree(path, "enc_groups")
    off = 1 if stacked else 0
    base = shape[off:]
    if _in_subtree(path, "ssd"):
        return P()                     # SSD params replicated (see DESIGN)
    if name in _REPLICATED or len(base) <= 1 and name not in _LEAF_PREFS:
        return P()
    prefs = _LEAF_PREFS.get(name)
    moe = _in_subtree(path, "mlp") and len(base) == 3 \
        and name in ("w_gate", "w_up", "w_down") \
        and not _in_subtree(path, "shared")
    if moe:
        prefs = _MOE_PREFS[name]
    if prefs is None:
        return P()
    dim = _pick(base, prefs, axis_size)
    if dim is None:
        return P()
    spec = [None] * len(shape)
    spec[dim + off] = axis_name
    return P(*spec)


def param_pspecs(abstract_params: Params, cfg: ModelConfig,
                 axis_name: str = "model", axis_size: int = 16) -> Params:
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: param_pspec(kp, leaf, cfg, axis_name, axis_size),
        abstract_params)


# --------------------------------------------------------------------------- #
# ZeRO-1 optimizer-state rules
# --------------------------------------------------------------------------- #
def zero1_pspec(pspec: P, shape: Tuple[int, ...], data_axes: Tuple[str, ...],
                data_size: int) -> P:
    """Add the data axes on the first unsharded divisible dim (idempotent —
    a spec that already uses a data axis is returned unchanged)."""
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    used = {a for s in spec if s is not None
            for a in (s if isinstance(s, tuple) else (s,))}
    if used & set(data_axes):
        return P(*spec)
    for d, s in enumerate(shape):
        if spec[d] is None and _div(s, data_size):
            spec[d] = data_axes if len(data_axes) > 1 else data_axes[0]
            break
    return P(*spec)


def opt_pspecs(abstract_opt: Any, pspecs: Params,
               data_axes: Tuple[str, ...], data_size: int) -> Any:
    def one(moments):
        return jax.tree.map(
            lambda sd, sp: zero1_pspec(sp, sd.shape, data_axes, data_size),
            moments, pspecs)
    return {"mu": one(abstract_opt["mu"]), "nu": one(abstract_opt["nu"]),
            "step": P()}


# --------------------------------------------------------------------------- #
# Cache + batch rules
# --------------------------------------------------------------------------- #
def _dp(batch: int, data_axes: Tuple[str, ...], data_size: int):
    if not data_axes or not _div(batch, data_size):
        return None
    return data_axes if len(data_axes) > 1 else data_axes[0]


def cache_pspecs(abstract_caches: Any, cfg: ModelConfig, batch: int,
                 data_axes: Tuple[str, ...], data_size: int,
                 axis_name: str = "model", axis_size: int = 16,
                 mode: str = "decode") -> Any:
    """Decode caches: capacity(seq)-sharded on the model axis.
    Prefill-emitted caches: hd-sharded (matches how K/V are computed).
    States (SSM/RG-LRU): batch-sharded only. Leaves are stacked (count,…)."""
    dp = _dp(batch, data_axes, data_size)

    def rule(path, leaf):
        shape = tuple(leaf.shape)           # (count, B, ...)
        name = _leaf_name(path)
        spec: list = [None] * len(shape)
        if len(shape) >= 2 and shape[1] == batch:
            spec[1] = dp
        # KVCache fields k/v: (count,B,cap,kv,hd); pos: (count,B,cap)
        if name in ("k", "v"):
            if mode == "decode" and _div(shape[2], axis_size):
                spec[2] = axis_name
            elif mode == "prefill" and _div(shape[4], axis_size):
                spec[4] = axis_name
        elif name == "pos" and len(shape) == 3:
            if mode == "decode" and _div(shape[2], axis_size):
                spec[2] = axis_name
        # MLA: ckv (count,B,cap,lora), kpe (count,B,cap,rope)
        elif name in ("ckv", "kpe"):
            if mode == "decode" and _div(shape[2], axis_size):
                spec[2] = axis_name
        elif name in ("cross_k", "cross_v"):
            if _div(shape[4], axis_size):
                spec[4] = axis_name
        # rglru h: (count,B,w); conv: (count,B,K-1,w)
        elif name == "h" and len(shape) == 3 and _div(shape[2], axis_size):
            spec[2] = axis_name
        elif name == "conv" and len(shape) == 4 and _div(shape[3], axis_size):
            spec[3] = axis_name
        # SSM h (count,B,H,P,N) / conv: batch-sharded only
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, abstract_caches)


def batch_pspecs(abstract_batch: Any, data_axes: Tuple[str, ...],
                 data_size: int) -> Any:
    def rule(_path, leaf):
        dp = _dp(leaf.shape[0], data_axes, data_size)
        return P(dp, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map_with_path(rule, abstract_batch)


def to_shardings(mesh, pspecs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
