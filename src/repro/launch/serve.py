"""Serving launcher: lower + AOT-compile the P (prefill) and D (decode)
programs for an assigned architecture on the production mesh, then run a
local functional demo of the disaggregated flow on a reduced config.

On real hardware each pod runs this under its own jax.distributed
initialization; on this container the compile path is the multi-pod
dry-run (see dryrun.py) and ``--demo`` exercises the same code on a small
model with real numerics.

  python -m repro.launch.serve --arch qwen3-4b --shape decode_32k
  python -m repro.launch.serve --demo
"""
import os
if "XLA_FLAGS" not in os.environ:      # 512 fake chips unless launched real
    os.environ["XLA_FLAGS"] = \
        "--xla_force_host_platform_device_count=512 " \
        "--xla_disable_hlo_passes=while-loop-invariant-code-motion," \
        "while-loop-expensive-invariant-code-motion"

import argparse


def compile_programs(arch: str, shape: str, multi_pod: bool) -> None:
    from repro.launch.cells import get_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (make_prefill_artifacts,
                                    make_serve_artifacts)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = get_cell(arch, shape)
    if cell.skip:
        print(f"[skip] {cell.name}: {cell.skip}")
        return
    arts = []
    if cell.mode in ("prefill", "decode"):
        arts.append(make_prefill_artifacts(
            get_cell(arch, "prefill_32k"), mesh))
        arts.append(make_serve_artifacts(
            get_cell(arch, "decode_32k"), mesh))
    for art in arts:
        compiled = art.lower().compile()
        ma = compiled.memory_analysis()
        tot = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        print(f"[ok] {art.name}: compiled for {mesh.devices.size} chips, "
              f"{tot/2**30:.2f} GiB/chip")


def demo(connector: str = "inproc", two_process: bool = False,
         num_p: int = None, num_d: int = None, plan: bool = False,
         prefix_cache: bool = False) -> None:
    import subprocess
    import sys
    root = os.path.join(os.path.dirname(__file__), "..", "..", "..")
    cmd = [sys.executable,
           os.path.join(root, "examples", "serve_disagg.py"),
           "--requests", "8", "--max-new", "8",
           "--connector", connector]
    if two_process:
        cmd.append("--two-process")
    if num_p is not None:
        cmd += ["--num-p", str(num_p)]
    if num_d is not None:
        cmd += ["--num-d", str(num_d)]
    if plan:
        cmd.append("--plan")
    if prefix_cache:
        cmd.append("--prefix-cache")
    subprocess.run(cmd, check=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--connector", default="inproc",
                    choices=["inproc", "shm", "rdma"],
                    help="KV-transport backend for the --demo serving loop")
    ap.add_argument("--two-process", action="store_true",
                    help="--demo only: run the P and D engines in separate "
                         "OS processes (requires --connector shm)")
    ap.add_argument("--num-p", type=int, default=None,
                    help="--demo only: prefill worker processes "
                         "(multi-process runtime; requires --connector shm)")
    ap.add_argument("--num-d", type=int, default=None,
                    help="--demo only: decode worker processes "
                         "(multi-process runtime; requires --connector shm)")
    ap.add_argument("--plan", action="store_true",
                    help="--demo only: size the topology with the planner "
                         "(plan_deployment → to_cluster_spec)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="--demo only: enable the shared-prefix KV cache "
                         "(prefill-compute and wire-byte skipping plus "
                         "cache-aware D routing)")
    args = ap.parse_args()
    if args.demo:
        demo(args.connector, args.two_process, args.num_p, args.num_d,
             args.plan, args.prefix_cache)
    else:
        compile_programs(args.arch, args.shape, args.multi_pod)


if __name__ == "__main__":
    main()
