import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 --xla_disable_hlo_passes=while-loop-invariant-code-motion,while-loop-expensive-invariant-code-motion"
# ^ These two lines MUST stay first — before ANY other import — since jax
# locks the device count at first init, and the production meshes need 512
# placeholder devices on this CPU-only container. Do NOT set this globally.
#
# The two disabled passes hoist loop-invariant f32 copies of bf16 weights
# out of the layer scan. Those copies only exist because the CPU backend
# emulates bf16 dots in f32 (float-normalization); the TPU MXU consumes
# bf16 natively, so the hoisted buffers would misreport the target's
# per-chip memory by +2× weight bytes. See DESIGN.md §Hardware adaptation.
#
# Multi-pod dry-run: lower + compile every (architecture × input shape ×
# mesh) cell and record memory / cost / collective evidence.
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen3-4b --shape decode_32k
#   python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
#   python -m repro.launch.dryrun --all --probes   # add roofline probes
#
# Per cell it emits a JSON record:
#   {cell, mesh, ok, seconds, memory_analysis, flops, bytes, wire_bytes,
#    roofline terms (from probes), skip reason if any}

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, List, Optional

import jax

from repro.configs import ASSIGNED
from repro.launch.cells import Cell, get_cell, make_cells
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_artifacts
from repro.roofline import analysis as RA


def _mem_fields(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    if out:
        out["total_minus_aliased"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(cell: Cell, mesh_kind: str, *, probes: bool = False,
             verbose: bool = True) -> Dict[str, Any]:
    rec: Dict[str, Any] = {
        "arch": cell.arch, "shape": cell.shape, "mode": cell.mode,
        "mesh": mesh_kind, "seq_len": cell.seq_len, "batch": cell.batch,
        "n_micro": cell.n_micro, "cache_dtype": cell.cache_dtype,
    }
    if cell.skip:
        rec["ok"] = None
        rec["skip"] = cell.skip
        if verbose:
            print(f"[skip] {cell.name} ({mesh_kind}): {cell.skip}",
                  flush=True)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    try:
        art = make_artifacts(cell, mesh)
        lowered = art.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec["ok"] = True
        rec["seconds"] = {"lower": round(t_lower, 1),
                          "compile": round(t_compile, 1)}
        rec["memory_analysis"] = _mem_fields(compiled)
        ca = compiled.cost_analysis() or {}
        rec["scanned_cost"] = {"flops": float(ca.get("flops", 0.0)),
                               "bytes": float(ca.get("bytes accessed", 0.0))}
        if verbose:
            mb = rec["memory_analysis"].get("total_minus_aliased", 0) / 2**30
            print(f"[ok] {cell.name}:{cell.mode} ({mesh_kind}, {chips}ch) "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
                  f"mem/chip {mb:.2f} GiB", flush=True)
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {str(e)[:400]}"
        if verbose:
            print(f"[FAIL] {cell.name}:{cell.mode} ({mesh_kind}): "
                  f"{rec['error']}", flush=True)
            traceback.print_exc(limit=3)
        return rec

    if probes and mesh_kind == "single":
        try:
            rec["roofline"] = run_probes(cell, mesh, verbose=verbose)
        except Exception as e:
            rec["roofline"] = {"error": f"{type(e).__name__}: {str(e)[:400]}"}
            if verbose:
                print(f"[probe-FAIL] {cell.name}: {rec['roofline']}",
                      flush=True)
    return rec


def run_probes(cell: Cell, mesh, verbose: bool = True) -> Dict[str, Any]:
    """Unrolled reduced-depth probe compiles → extrapolated roofline terms."""
    plan = RA.probe_plan(cell.arch)
    chips = mesh.devices.size
    model_axis = mesh.shape.get("model", 1)
    acc: List = []
    for override, coeff in plan:
        art = make_artifacts(cell, mesh, unroll=True,
                             layer_override=override)
        compiled = art.lower().compile()
        terms = RA.analyze_compiled(compiled, model_axis)
        acc.append((terms, coeff))
    terms = RA.roofline_for_cell(acc)
    secs = terms.seconds()
    tokens = cell.batch * (cell.seq_len if cell.mode != "decode" else 1)
    mf = RA.model_flops(cell.arch, cell.mode, tokens)
    ratio = RA.useful_ratio(cell.arch, cell.mode, tokens,
                            terms.flops * chips)
    out = {
        "flops_per_chip": terms.flops,
        "hbm_bytes_per_chip": terms.hbm_bytes,
        "hbm_bytes_corrected": terms.hbm_bytes_corrected,
        "convert_bytes_per_chip": terms.convert_bytes,
        "wire_bytes_per_chip": terms.wire_bytes,
        "by_kind": terms.by_kind,
        "seconds": secs,
        "dominant": terms.dominant(),
        "model_flops_global": mf,
        "useful_ratio": ratio,
        "probe_overrides": [o for o, _ in plan],
    }
    if verbose:
        print(f"  roofline {cell.name}: compute {secs['compute']:.4f}s "
              f"memory {secs['memory']:.4f}s collective "
              f"{secs['collective']:.4f}s → {out['dominant']}-bound, "
              f"useful {ratio:.2f}", flush=True)
    return out


def run_handoffs(arch: Optional[str], out: Optional[str]) -> None:
    """Lower the P→D cache-realignment program (the paper's compatible
    transmission module as HLO) and report its wire bytes per arch."""
    from repro.launch.steps import make_handoff_artifacts
    from repro.roofline import analysis as RA
    mesh = make_production_mesh()
    archs = ASSIGNED if arch in (None, "all") else [arch]
    print("| arch | KV bytes (32-seq batch) | wire bytes/chip | "
          "collective breakdown |")
    for a in archs:
        try:
            art = make_handoff_artifacts(a, mesh)
            compiled = art.lower().compile()
            terms = RA.analyze_compiled(compiled, mesh.shape.get("model", 1))
            kv_bytes = sum(
                l.size * l.dtype.itemsize
                for l in jax.tree.leaves(art.abstract_args[0]))
            rec = {"arch": a, "kind": "handoff",
                   "kv_bytes_global": int(kv_bytes),
                   "wire_bytes_per_chip": terms.wire_bytes,
                   "by_kind": terms.by_kind, "ok": True}
            print(f"| {a} | {kv_bytes/2**30:.2f} GiB | "
                  f"{terms.wire_bytes/2**20:.1f} MiB | "
                  f"{ {k: round(v/2**20, 1) for k, v in terms.by_kind.items()} } |",
                  flush=True)
        except Exception as e:
            rec = {"arch": a, "kind": "handoff", "ok": False,
                   "error": f"{type(e).__name__}: {str(e)[:300]}"}
            print(f"| {a} | FAILED {rec['error'][:120]} |", flush=True)
        if out:
            with open(out, "a") as fh:
                fh.write(json.dumps(rec) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ASSIGNED + ["all"],
                    help="architecture id (--all for every arch)")
    ap.add_argument("--shape", default=None,
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--probes", action="store_true",
                    help="also run roofline probe compiles (single-pod)")
    ap.add_argument("--handoff", action="store_true",
                    help="lower the P→D KV-handoff program per arch")
    ap.add_argument("--probes-only", action="store_true",
                    help="re-run roofline probes only (no artifact "
                         "compile; records merge into --out)")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    if args.handoff:
        run_handoffs(args.arch, args.out)
        return

    if args.all or args.arch in (None, "all"):
        cells = make_cells()
        if args.shape:
            cells = [c for c in cells if c.shape == args.shape]
    else:
        cells = ([get_cell(args.arch, args.shape)] if args.shape
                 else make_cells([args.arch]))

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    records = []
    if args.probes_only:
        mesh = make_production_mesh()
        for cell in cells:
            if cell.skip:
                continue
            try:
                rec = {"arch": cell.arch, "shape": cell.shape,
                       "mesh": "single", "mode": cell.mode, "ok": True,
                       "roofline": run_probes(cell, mesh)}
            except Exception as e:
                rec = {"arch": cell.arch, "shape": cell.shape,
                       "mesh": "single", "mode": cell.mode, "ok": True,
                       "roofline": {"error": str(e)[:300]}}
                print(f"[probe-FAIL] {cell.name}: {str(e)[:200]}",
                      flush=True)
            records.append(rec)
            if args.out:
                with open(args.out, "a") as fh:
                    fh.write(json.dumps(rec) + "\n")
        return

    for cell in cells:
        for mk in meshes:
            rec = run_cell(cell, mk, probes=args.probes)
            records.append(rec)
            if args.out:
                with open(args.out, "a") as fh:
                    fh.write(json.dumps(rec) + "\n")

    n_ok = sum(1 for r in records if r["ok"])
    n_skip = sum(1 for r in records if r["ok"] is None)
    n_fail = sum(1 for r in records if r["ok"] is False)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED "
          f"of {len(records)}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
