"""The assigned (architecture × input-shape) grid — 40 cells.

Shapes (per the assignment):
  train_4k     seq 4,096   global_batch 256   lowers train_step
  prefill_32k  seq 32,768  global_batch 32    lowers prefill_step
  decode_32k   seq 32,768  global_batch 128   lowers serve_step (1 new token)
  long_500k    seq 524,288 global_batch 1     lowers serve_step

long_500k needs a sub-quadratic live context; it runs for the SSM / hybrid /
SWA archs whose decode state is bounded (mamba2, recurrentgemma, mixtral)
and is skipped for pure full-attention archs (see DESIGN.md §5).

``cache_dtype`` override: qwen1.5-32b (kv=40, near-MHA) at decode_32k holds
5.5 TB of bf16 KV — beyond a 4 TB v5e pod. Its cell serves with an fp8
(e4m3) KV cache — the paper's *precision alignment* component applied as a
capacity lever; every other cell uses bf16 KV.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.configs import ASSIGNED
from repro.configs.base import get_config

LONG_OK = {"mamba2-370m", "recurrentgemma-9b", "mixtral-8x7b"}

SHAPES = {
    "train_4k": dict(seq_len=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq_len=32768, batch=128, mode="decode"),
    "long_500k": dict(seq_len=524288, batch=1, mode="decode"),
}

_CACHE_DTYPE_OVERRIDE = {("qwen1.5-32b", "decode_32k"): "float8_e4m3fn"}

# activation budget for picking train microbatch count (bytes/chip of
# residual-stream checkpoints under remat)
_ACT_BUDGET = 1.2e9


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    mode: str                  # train | prefill | decode
    seq_len: int
    batch: int
    n_micro: int = 1
    cache_dtype: str = "bfloat16"
    zero3: bool = False        # FSDP weight sharding (train, ≥8B params)
    act_seq: bool = False      # sequence-parallel residual stream (train)
    skip: Optional[str] = None

    @property
    def name(self) -> str:
        return f"{self.arch}@{self.shape}"

    def decode_capacity(self) -> int:
        """Room for the live context + a margin of new tokens, padded to a
        multiple of 16 so the capacity axis shards."""
        cap = self.seq_len + 128
        return -(-cap // 16) * 16


def _n_micro(arch: str, batch: int, seq: int, dp: int = 16) -> int:
    cfg = get_config(arch)
    layers = cfg.num_layers + cfg.encoder_layers
    n = 1
    while n < batch // dp:
        per_chip = (batch / (n * dp)) * seq * cfg.d_model * 2 * layers
        if per_chip <= _ACT_BUDGET:
            break
        n *= 2
    return n


def make_cells(archs: Optional[List[str]] = None) -> List[Cell]:
    out = []
    for arch in (archs or ASSIGNED):
        for shape, sd in SHAPES.items():
            skip = None
            if shape == "long_500k" and arch not in LONG_OK:
                skip = "full-attention arch: 500k live KV is unservable " \
                       "(see DESIGN.md §5)"
            nm = _n_micro(arch, sd["batch"], sd["seq_len"]) \
                if sd["mode"] == "train" else 1
            # ZeRO-3 weight sharding: a bf16 replica of a 45B+ model does
            # not leave room for grads on a 16 GiB chip.
            z3 = (sd["mode"] == "train"
                  and get_config(arch).param_count() > 8e9)
            # mixtral train: the §Perf-validated deployment — fewer micros
            # (ZeRO-3 weight gathers repeat per micro) paid for with
            # sequence-parallel residuals (EXPERIMENTS.md §Perf cell B).
            act_seq = False
            if arch == "mixtral-8x7b" and sd["mode"] == "train":
                nm, act_seq = 8, True
            out.append(Cell(
                arch=arch, shape=shape, mode=sd["mode"],
                seq_len=sd["seq_len"], batch=sd["batch"], n_micro=nm,
                cache_dtype=_CACHE_DTYPE_OVERRIDE.get((arch, shape),
                                                      "bfloat16"),
                zero3=z3, act_seq=act_seq, skip=skip))
    return out


def get_cell(arch: str, shape: str) -> Cell:
    for c in make_cells([arch]):
        if c.shape == shape:
            return c
    raise KeyError((arch, shape))
