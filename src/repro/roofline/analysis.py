"""Three-term roofline from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = wire_bytes_per_chip / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. cost_analysis() runs on the per-chip SPMD module, so its numbers are
already per-chip.

XLA's HLO cost analysis counts a while-loop body exactly ONCE, so a
scanned-over-layers model under-reports FLOPs by the trip count. The
roofline therefore measures PROBE compiles — the same program at reduced,
UNROLLED layer counts — and extrapolates linearly to the full depth
(per-layer cost is layer-index independent; the probe plans below make the
algebra exact per layer family). The full scanned artifact still supplies
memory_analysis (what actually fits on chip).

Collective bytes are parsed from the post-partitioning HLO text: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
costed with ring-model wire bytes per chip.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import get_config


@dataclasses.dataclass(frozen=True)
class HW:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16
    hbm_bw: float = 819e9               # B/s
    link_bw: float = 50e9               # B/s effective per chip


V5E = HW()


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\([^)]*\)\s*->")
_CONVERT_RE = re.compile(r"=\s*f32\[([\d,]*)\][^\s]*\s+convert\(")

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(\w+)\[([\d,]*)\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(kind: str, out_bytes: float, group: int) -> float:
    """Ring-model bytes moved per chip."""
    if group <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (group - 1) / group * out_bytes
    if kind == "all-gather":            # output = gathered result
        return (group - 1) / group * out_bytes
    if kind == "reduce-scatter":        # output = one shard
        return (group - 1) * out_bytes
    if kind == "all-to-all":
        return (group - 1) / group * out_bytes
    return out_bytes                    # collective-permute


def convert_emulation_bytes(hlo_text: str) -> float:
    """Bytes attributable to standalone bf16→f32 ``convert`` ops outside
    fusions. The CPU dot emitter cannot consume bf16, so float-
    normalization wraps every dot in f32 converts — ops that DO NOT EXIST
    on the TPU target (native bf16 MXU) yet count 6 B/elem (2 read + 4
    write) in cost_analysis. Subtracting them gives a closer (still
    conservative) estimate of target HBM traffic."""
    total = 0.0
    in_fusion = False
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line.strip())
        if mc:
            in_fusion = "fused" in mc.group(1) or "fusion" in mc.group(1)
            continue
        if in_fusion:
            continue
        m = _CONVERT_RE.search(line)
        if m:
            n = 1
            for d in m.group(1).split(","):
                if d:
                    n *= int(d)
            total += 6.0 * n
    return total


def collective_bytes(hlo_text: str, default_group: int
                     ) -> Tuple[float, Dict[str, float]]:
    """Per-chip wire bytes summed over every collective in the module.
    Returns (total, by-kind breakdown). Call on UNROLLED modules only
    (while bodies appear once in the text)."""
    total = 0.0
    by_kind: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        kind = None
        if m and m.group(3):
            kind = m.group(3)
            if m.group(1):
                out_b = _shape_bytes(m.group(1), m.group(2))
            else:
                out_b = sum(_shape_bytes(d, s) for d, s in
                            _SHAPE_RE.findall(line.split(kind)[0]))
        else:
            mt = _TUPLE_RE.search(line)
            if mt:
                kind = mt.group(2)
                out_b = sum(_shape_bytes(d, s) for d, s in
                            _SHAPE_RE.findall(mt.group(1)))
        if kind is None:
            continue
        g = _group_size(line, default_group)
        w = _wire_bytes(kind, out_b, g)
        total += w
        by_kind[kind] = by_kind.get(kind, 0.0) + w
    return total, by_kind


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class RooflineTerms:
    flops: float = 0.0                  # per chip
    hbm_bytes: float = 0.0              # per chip (raw cost_analysis)
    wire_bytes: float = 0.0             # per chip
    convert_bytes: float = 0.0          # CPU bf16-emulation artifact
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def hbm_bytes_corrected(self) -> float:
        return max(self.hbm_bytes - self.convert_bytes, 0.0)

    def seconds(self, hw: HW = V5E) -> Dict[str, float]:
        return {"compute": self.flops / hw.peak_flops,
                "memory": self.hbm_bytes_corrected / hw.hbm_bw,
                "memory_raw": self.hbm_bytes / hw.hbm_bw,
                "collective": self.wire_bytes / hw.link_bw}

    def _terms(self, hw: HW = V5E) -> Dict[str, float]:
        s = self.seconds(hw)
        return {k: s[k] for k in ("compute", "memory", "collective")}

    def dominant(self, hw: HW = V5E) -> str:
        t = self._terms(hw)
        return max(t, key=t.get)

    def step_time(self, hw: HW = V5E) -> float:
        """Roofline-optimistic step time: terms overlap perfectly."""
        return max(self._terms(hw).values())

    def combine(self, other: "RooflineTerms", coeff: float
                ) -> "RooflineTerms":
        bk = dict(self.by_kind)
        for k, v in other.by_kind.items():
            bk[k] = bk.get(k, 0.0) + coeff * v
        return RooflineTerms(
            flops=self.flops + coeff * other.flops,
            hbm_bytes=self.hbm_bytes + coeff * other.hbm_bytes,
            wire_bytes=self.wire_bytes + coeff * other.wire_bytes,
            convert_bytes=self.convert_bytes + coeff * other.convert_bytes,
            by_kind=bk)


def analyze_compiled(compiled, default_group: int) -> RooflineTerms:
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    wire, by_kind = collective_bytes(text, default_group)
    return RooflineTerms(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        wire_bytes=wire,
        convert_bytes=convert_emulation_bytes(text),
        by_kind=by_kind)


# --------------------------------------------------------------------------- #
# Probe plans: [(layer_override, coeff)] with Σ coeff·F(probe) = F(full).
# --------------------------------------------------------------------------- #
def probe_plan(arch: str) -> List[Tuple[Dict[str, int], float]]:
    cfg = get_config(arch)
    L = cfg.num_layers
    if arch == "deepseek-v2-lite-16b":
        # 1 dense + 26 MoE: F = F(2) + 25·(F(3)−F(2))
        return [({"num_layers": 2}, -24.0), ({"num_layers": 3}, 25.0)]
    if arch == "recurrentgemma-9b":
        # 38 = 12×(r,r,a) + (r,r): F = F(3) + 11·(F(6)−F(3)) + (F(5)−F(3))
        return [({"num_layers": 3}, -11.0), ({"num_layers": 6}, 11.0),
                ({"num_layers": 5}, 1.0)]
    if arch == "whisper-large-v3":
        # F = F(2,2) + 30·(F(3,2)−F(2,2)) + 30·(F(2,3)−F(2,2))
        return [({"encoder_layers": 2, "num_layers": 2}, -59.0),
                ({"encoder_layers": 3, "num_layers": 2}, 30.0),
                ({"encoder_layers": 2, "num_layers": 3}, 30.0)]
    # homogeneous stack: F = (2−L)·F(1) + (L−1)·F(2)
    return [({"num_layers": 1}, float(2 - L)), ({"num_layers": 2},
                                                float(L - 1))]


def roofline_for_cell(probe_terms: List[Tuple[RooflineTerms, float]]
                      ) -> RooflineTerms:
    out = RooflineTerms()
    for terms, coeff in probe_terms:
        out = out.combine(terms, coeff)
    return out


# --------------------------------------------------------------------------- #
def model_flops(arch: str, mode: str, tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); ×3 fwd+bwd ratio
    already inside the 6 for training; inference fwd-only = 2·N·D."""
    cfg = get_config(arch)
    n = cfg.active_param_count()
    per_tok = 6.0 * n if mode == "train" else 2.0 * n
    return per_tok * tokens


def useful_ratio(arch: str, mode: str, tokens: int, hlo_flops_global: float
                 ) -> float:
    if hlo_flops_global <= 0:
        return 0.0
    return model_flops(arch, mode, tokens) / hlo_flops_global
