from repro.roofline.analysis import (HW, RooflineTerms, analyze_compiled,
                                     collective_bytes, probe_plan,
                                     roofline_for_cell)
