"""Model-instance engine: prefill, continuous-batching paged decode.

One Engine == one "model instance" in the paper's sense (a P instance, a D
instance, or an integrated instance). Vendor-specific VRAM management is the
engine's ``KVPageSpec`` (block size / layout / dtype); compute dtype and the
logical TP degree used for KV sharding complete the vendor profile.

The engine is device-agnostic: on this CPU container it runs the tiny-model
functional path; on a TPU mesh the same jitted callables are pjit'd by the
launcher.
"""
from __future__ import annotations

import dataclasses
import enum
import logging
import time
import warnings
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, PrefillCapabilities
from repro.models import model as M
from repro.serving.paged_cache import BlockAllocator, KVPageSpec
from repro.serving.prefix_cache import HostPrefixStore, PrefixStore, hashing
from repro.serving.request import Request, State

log = logging.getLogger(__name__)


class PrefillMode(enum.Enum):
    """Explicit prefill compute mode (replaces the old chunk_tokens
    None/0/negative sentinel tri-state).

      INCREMENTAL  chunk-at-a-time compute; requires positive chunk_tokens
      MONOLITHIC   whole-prompt compute in one pass (the wire may still
                   stream in chunk_tokens slices)
      AUTO         incremental when the family supports it and
                   chunk_tokens subdivides the prompt, else monolithic
    """
    INCREMENTAL = "incremental"
    MONOLITHIC = "monolithic"
    AUTO = "auto"


class PrefillModeError(ValueError):
    """A requested prefill mode is unsupported for this engine/request —
    typed so callers can distinguish a capability mismatch from generic
    argument errors."""


# families already warned about silent prefix-replay degradation (log
# once per family, count every occurrence in EngineStats)
_RESUME_WARNED: set = set()


def page_specs_for(cfg: ModelConfig, block_size: int, layout: str,
                   dtype: str) -> Dict[str, KVPageSpec]:
    if cfg.attention_kind == "mla":
        m = cfg.mla
        return {
            "ckv": KVPageSpec(block_size, layout, dtype, 1, m.kv_lora_rank),
            "kpe": KVPageSpec(block_size, layout, dtype, 1, m.qk_rope_head_dim),
        }
    return {"kv": KVPageSpec(block_size, layout, dtype,
                             max(cfg.num_kv_heads, 1), cfg.hd)}


@dataclasses.dataclass(frozen=True)
class VendorProfile:
    """The 'vendor' of an instance — everything the heterogeneous compat
    module must align across instances."""
    name: str
    block_size: int = 16
    layout: str = "nbhd"
    kv_dtype: str = "float32"
    tp: int = 1                 # logical TP degree of stored KV shards
    hardware: str = "tpu-v5e"   # planner HardwareSpec key


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    prefill_chunks: int = 0         # compute chunks (1 per monolithic prefill)
    decode_steps: int = 0
    decode_tokens: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0
    failures_injected: int = 0
    prefix_cached_tokens: int = 0   # prompt tokens replayed from the P-side
    #                                 host prefix store instead of recomputed
    # measured decode-stall: prefill compute seconds spent on an integrated
    # (role="both") engine while decode-ready sequences sat waiting — the
    # interference disaggregation removes (~0 on pure P or pure D roles)
    contention_stall_seconds: float = 0.0
    # requests that wanted prefix-cache replay / mid-stream resume but the
    # family cannot support it — previously a silent full recompute
    resume_unsupported: int = 0
    # prompt tokens whose compute was skipped via a mid-stream snapshot
    # resume after a failure (state-carrying families)
    resumed_tokens: int = 0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _chronological(arr: np.ndarray, pos: np.ndarray) -> Tuple[np.ndarray, int]:
    """Ring-buffer shard (count, cap, ...) + pos (count, cap) →
    chronological (count, cap, ...) and the absolute start position."""
    order = np.argsort(pos[0])                    # same order across layers
    return arr[:, order], int(pos[0][order[0]])


def kv_entries_with_start(package_kv: List[Tuple]) -> List[Tuple]:
    """Normalize a prefill package's KV entries to chronological order with
    an absolute ``start`` position — the canonical pre-wire form that both
    the monolithic encoder and the chunk splitter consume.

    Returns [(kind, gi, pi, entry)] where entry holds contiguous arrays of
    shape (count, S', ...) covering absolute positions [start, start+S')."""
    out = []
    for kind, gi, pi, entry in package_kv:
        if kind == "mla":
            out.append((kind, gi, pi, {"ckv": np.asarray(entry["ckv"]),
                                       "kpe": np.asarray(entry["kpe"]),
                                       "start": 0}))
            continue
        k, v = np.asarray(entry["k"]), np.asarray(entry["v"])
        start = 0
        if "pos" in entry and k.shape[1] < np.max(entry["pos"]) + 1:
            pos = np.asarray(entry["pos"])
            k, start = _chronological(k, pos)
            v, _ = _chronological(v, pos)
        out.append((kind, gi, pi, {"k": k, "v": v, "start": start}))
    return out


def slice_kv_entries(entries: List[Tuple], w0: int, w1: int) -> List[Tuple]:
    """Restrict normalized entries to the absolute token window [w0, w1)."""
    out = []
    for kind, gi, pi, ent in entries:
        start = ent["start"]
        arrs = {n: a for n, a in ent.items() if n != "start"}
        length = next(iter(arrs.values())).shape[1]
        lo = max(w0, start)
        hi = min(w1, start + length)
        if hi <= lo:
            continue
        sl = {n: a[:, lo - start:hi - start] for n, a in arrs.items()}
        sl["start"] = lo
        out.append((kind, gi, pi, sl))
    return out


class PrefillStream:
    """Resumable chunked prefill on one P engine (paper §III-B overlap).

    ``next_chunk()`` yields KV chunk packages ``{"kv": entries, "start",
    "length"}`` until exhausted (then returns ``None``). Two compute modes
    (:class:`PrefillMode`):

      * *incremental* — every family runs the prompt through the decode
        path over a dense full-capacity cache, one chunk of tokens per
        call, so each chunk's KV can hit the wire while the next chunk
        computes (Mooncake-style streaming). Sliding-window families chunk
        with window-aware masking and ship only positions above the window
        floor; recurrent/SSM layers carry their state across chunks (and
        can snapshot/resume mid-stream); enc-dec and vision families run a
        non-resumable encoder/embedding preamble, then chunk the sequence.
      * *monolithic*  — whole-prompt compute in one pass on the first
        call; the wire still streams in ``chunk_tokens`` slices.

    ``first_token`` / ``tail_package()`` (states, cross-attention memory)
    become available once the final chunk has been produced."""

    def __init__(self, engine: "Engine", req: Request,
                 chunk_tokens: Optional[int] = None,
                 chunked_compute: Optional[bool] = None,
                 mode: Optional[PrefillMode] = None,
                 resume: Optional[Dict[str, Any]] = None):
        self.engine = engine
        self.req = req
        self.caps: PrefillCapabilities = engine.prefill_capabilities()
        patches = req.patches.shape[0] if req.patches is not None else 0
        self.seq_len = req.prompt_len + patches
        if chunk_tokens is not None and chunk_tokens <= 0:
            warnings.warn(
                "chunk_tokens <= 0 as a monolithic sentinel is deprecated; "
                "pass mode=PrefillMode.MONOLITHIC", DeprecationWarning,
                stacklevel=3)
            chunk_tokens = None               # deprecated shim
        self.chunk_tokens = chunk_tokens
        if mode is None:
            # deprecated bool kwarg shim: True/False force the mode, None
            # keeps the automatic choice
            if chunked_compute is None:
                mode = PrefillMode.AUTO
            else:
                mode = PrefillMode.INCREMENTAL if chunked_compute \
                    else PrefillMode.MONOLITHIC
        if not isinstance(mode, PrefillMode):
            raise PrefillModeError(f"unknown prefill mode {mode!r}")
        self.mode = mode
        if mode is PrefillMode.INCREMENTAL:
            if not self.caps.incremental:
                raise PrefillModeError(
                    f"{engine.cfg.name}: incremental chunked prefill is not "
                    f"supported for family {self.caps.family!r}")
            if chunk_tokens is None:
                raise PrefillModeError(
                    f"{engine.cfg.name}: PrefillMode.INCREMENTAL requires "
                    "positive chunk_tokens")
            self.chunked_compute = True
        elif mode is PrefillMode.MONOLITHIC:
            self.chunked_compute = False
        else:
            self.chunked_compute = (self.caps.incremental
                                    and chunk_tokens is not None
                                    and chunk_tokens < self.seq_len)
        self.first_token: Optional[int] = None
        self.chunks_emitted = 0
        self._next_start = 0
        self._wire_sent = 0                           # wire progress (abs pos)
        self._tail: Optional[Dict[str, Any]] = None
        self._entries: Optional[List[Tuple]] = None   # monolithic mode
        self._caches = None                           # incremental mode
        self._emb = None                              # vision: merged embeds
        # mid-stream snapshot resume (state-carrying families): skip the
        # already-computed prefix, re-ship the wire from the window floor
        self._resume: Optional[Dict[str, Any]] = None
        if resume is not None:
            if not self.caps.resumable:
                engine._note_resume_unsupported()
                raise PrefillModeError(
                    f"{engine.cfg.name}: mid-stream resume is not supported "
                    f"for family {self.caps.family!r}")
            if not self.chunked_compute:
                raise PrefillModeError(
                    "resume requires incremental chunked compute")
            if int(resume.get("seq_len", -1)) != self.seq_len:
                raise PrefillModeError(
                    "resume snapshot does not match this request")
            self._resume = resume
        # P-side shared-prefix reuse: replay cached chunks instead of
        # recomputing them, and seed the dense cache so compute resumes
        # at the divergence point. Only safe when every cached row stays
        # attendable (caps.prefix_cache); the final token is always
        # computed (first_token).
        self.prefix_tokens = 0
        self._p_store = None
        self._cached_entries: Optional[List[Tuple]] = None
        self._collect: Optional[List[Tuple]] = None
        store = getattr(engine, "host_prefix_store", None)
        if store is not None and self._resume is None:
            if self.chunked_compute and self.caps.prefix_cache:
                self._p_store = store
                self._collect = []
                hit, entries = store.match(req.prompt, self.seq_len - 1)
                if hit > 0:
                    self.prefix_tokens = hit
                    self._cached_entries = entries
            else:
                # a prefix store exists but this stream cannot replay from
                # it — previously a silent full recompute
                engine._note_resume_unsupported()

    @property
    def done(self) -> bool:
        return self._next_start >= self.seq_len and self.chunks_emitted > 0

    def tail_package(self) -> Dict[str, Any]:
        assert self.done, "tail_package before stream exhausted"
        return self._tail if self._tail is not None \
            else {"states": [], "cross": []}

    def next_chunk(self) -> Optional[Dict[str, Any]]:
        if self.done:
            return None
        if self._next_start < self.prefix_tokens:
            chunk = self._next_cached()
        elif self.chunked_compute:
            chunk = self._next_incremental()
        else:
            chunk = self._next_monolithic()
        self.chunks_emitted += 1
        if self._collect is not None:
            self._collect.extend(chunk["kv"])
            if self._next_start >= self.seq_len:
                self._p_store.insert_prompt(self.req.prompt, self._collect,
                                            self.seq_len)
        return chunk

    # -- replay from the host prefix store ------------------------------- #
    def _next_cached(self) -> Dict[str, Any]:
        eng = self.engine
        if eng.failed:
            raise RuntimeError(f"instance {eng.name} is down")
        c0 = self._next_start
        c1 = min(c0 + (self.chunk_tokens or self.prefix_tokens),
                 self.prefix_tokens)
        self._next_start = c1
        self._wire_sent = c1
        eng.stats.prefix_cached_tokens += c1 - c0
        return {"kv": slice_kv_entries(self._cached_entries, c0, c1),
                "start": c0, "length": c1 - c0, "compute_seconds": 0.0}

    # -- monolithic compute, chunked wire ------------------------------- #
    def _next_monolithic(self) -> Dict[str, Any]:
        compute_s = 0.0
        if self._entries is None:
            t0 = time.perf_counter()
            package = self.engine.prefill(self.req)
            compute_s = time.perf_counter() - t0
            self.first_token = package["first_token"]
            self._tail = {"states": package["states"],
                          "cross": package["cross"]}
            self._entries = kv_entries_with_start(package["kv"])
            if self._entries:
                # ring-buffer (sliding) entries only cover the last window
                # of the prompt — don't ship empty chunks for the evicted
                # prefix, start streaming at the first position on the wire
                self._next_start = min(
                    min(e[3]["start"] for e in self._entries), self.seq_len)
        w0 = self._next_start
        if not self._entries or self.chunk_tokens is None:
            w1 = self.seq_len        # states-only: nothing to chunk
        else:
            w1 = min(w0 + self.chunk_tokens, self.seq_len)
        self._next_start = w1
        return {"kv": slice_kv_entries(self._entries, w0, w1),
                "start": w0, "length": w1 - w0,
                "compute_seconds": compute_s}

    # -- incremental compute (all families) ------------------------------ #
    @property
    def _wire_floor(self) -> int:
        """First absolute position the D side can still attend. Sliding-
        window KV below ``seq_len - window`` is dead weight — never ship."""
        if self.caps.window:
            return max(0, self.seq_len - self.caps.window)
        return 0

    def _next_incremental(self) -> Dict[str, Any]:
        """Compute exactly ONE chunk per call (one unit of per-tick P
        work). When the chunk produced nothing for the wire — pure-SSM
        layers, or sliding-window positions below the wire floor — the
        returned package is a zero-``length`` *progress marker* that
        drivers account but never send."""
        eng, req = self.engine, self.req
        if eng.failed:
            raise RuntimeError(f"instance {eng.name} is down")
        t0 = time.perf_counter()
        if self._caches is None:
            self._setup_incremental()
        c0 = self._next_start
        c1 = min(c0 + self.chunk_tokens, self.seq_len)
        logits = self._compute_chunk(c0, c1)
        self._next_start = c1
        eng.stats.prefill_tokens += c1 - c0
        eng.stats.prefill_chunks += 1
        if c1 == self.seq_len:
            self.first_token = int(
                eng._sample(np.asarray(logits[:, -1]), req)[0])
            self._tail = self._extract_tail()
        dt = time.perf_counter() - t0
        eng._note_prefill_compute(dt)
        if not self.caps.kv_on_wire:
            # pure-SSM: no attention KV ever lands on the wire — the one
            # final package declares full coverage, states ride the tail
            if c1 < self.seq_len:
                return {"kv": [], "start": c0, "length": 0,
                        "compute_seconds": dt}
            return {"kv": [], "start": 0, "length": self.seq_len,
                    "compute_seconds": dt}
        w0 = max(self._wire_sent, self._wire_floor)
        if c1 <= w0:
            return {"kv": [], "start": c0, "length": 0,
                    "compute_seconds": dt}
        entries = self._extract_entries(w0, c1)
        self._wire_sent = c1
        return {"kv": entries, "start": w0, "length": c1 - w0,
                "compute_seconds": dt}

    def _setup_incremental(self) -> None:
        eng, cfg, req = self.engine, self.engine.cfg, self.req
        # capacity rounded to a chunk multiple: prompts within the same
        # chunk bucket share one compiled cache shape (_chunk_fn traces
        # per (cache capacity, chunk length)); entries past seq_len stay
        # pos=-1 and are masked. full_capacity keeps sliding-window layers
        # dense (slot == position) — the window is enforced by attention
        # masking, never by ring eviction mid-prompt.
        cap = -(-self.seq_len // self.chunk_tokens) * self.chunk_tokens
        mem = eng.mem_len if cfg.is_enc_dec else 0
        self._caches = M.init_caches(cfg, 1, cap, cfg.cdtype, mem_len=mem,
                                     full_capacity=True)
        if req.frames is not None:
            # non-resumable encoder preamble: run the encoder on P once,
            # seed every decoder layer's cross-attention K/V
            memory = eng._encode_fn(eng.params, jnp.asarray(req.frames)[None])
            self._seed_cross(memory)
        if req.patches is not None:
            # vision prefix: merge patch + token embeddings once; chunks
            # slice the merged sequence (absolute positions span both)
            self._emb = eng._embed_fn(
                eng.params, jnp.asarray(req.patches)[None],
                jnp.asarray(req.prompt, jnp.int32)[None])
        if self.prefix_tokens:
            self._caches = self._preload_caches(self._caches)
        if self._resume is not None:
            self._apply_resume(self._resume)

    def _seed_cross(self, memory: jax.Array) -> None:
        eng = self.engine
        cross = eng._cross_kv_fn(eng.params, memory)
        mem = memory.shape[1]
        caches = [list(g) for g in self._caches]
        for (gi, pi), (mk, mv) in cross.items():
            c = dict(caches[gi][pi])
            c["cross_k"] = c["cross_k"].at[:, :, :mem].set(
                mk.astype(c["cross_k"].dtype))
            c["cross_v"] = c["cross_v"].at[:, :, :mem].set(
                mv.astype(c["cross_v"].dtype))
            c["mem_len"] = jnp.full_like(c["mem_len"], mem)
            caches[gi][pi] = c
        self._caches = tuple(tuple(g) for g in caches)

    def _compute_chunk(self, c0: int, c1: int) -> jax.Array:
        eng = self.engine
        positions = jnp.arange(c0, c1, dtype=jnp.int32)[None]
        if self._emb is not None:
            logits, self._caches = eng._chunk_embeds_fn(
                eng.params, self._emb[:, c0:c1], positions, self._caches)
        else:
            tokens = jnp.asarray(self.req.prompt[c0:c1], jnp.int32)[None]
            logits, self._caches = eng._chunk_fn(eng.params, tokens,
                                                 positions, self._caches)
        return logits

    def _extract_entries(self, w0: int, w1: int) -> List[Tuple]:
        """Wire entries for absolute positions [w0, w1) — slot == position
        because incremental caches are full-capacity."""
        entries = []
        for gi, g in enumerate(M.block_groups(self.engine.cfg)):
            for pi, kind in enumerate(g.kinds):
                if kind in ("ssd", "rglru"):
                    continue
                c = self._caches[gi][pi]
                self_c = c["self"] if isinstance(c, dict) else c
                if self.caps.latent_kv:
                    entries.append(("mla", gi, pi, {
                        "ckv": np.asarray(self_c.ckv[:, 0, w0:w1]),
                        "kpe": np.asarray(self_c.kpe[:, 0, w0:w1]),
                        "start": w0}))
                else:
                    entries.append(("kv", gi, pi, {
                        "k": np.asarray(self_c.k[:, 0, w0:w1]),
                        "v": np.asarray(self_c.v[:, 0, w0:w1]),
                        "start": w0}))
        return entries

    def _extract_tail(self) -> Dict[str, Any]:
        """States / cross-KV that ride with finalize (same shape as the
        monolithic ``_package_handoff`` tail)."""
        states, cross = [], []
        for gi, g in enumerate(M.block_groups(self.engine.cfg)):
            for pi, kind in enumerate(g.kinds):
                c = self._caches[gi][pi]
                if kind in ("ssd", "rglru"):
                    states.append(("state", gi, pi,
                                   jax.tree.map(lambda x: x[:, 0], c)))
                elif isinstance(c, dict):                  # enc-dec cross
                    cross.append((gi, pi, {
                        "cross_k": c["cross_k"][:, 0],
                        "cross_v": c["cross_v"][:, 0],
                        "mem_len": c["mem_len"][:, 0]}))
        return {"states": states, "cross": cross}

    # -- mid-stream snapshot resume (state-carrying families) ------------ #
    def snapshot(self) -> Optional[Dict[str, Any]]:
        """Portable mid-stream progress: recurrent/SSM layer states plus
        the KV rows still inside the sliding window. Replaying it on a
        fresh stream (same request, same params) skips recomputing the
        first ``next_start`` prompt tokens after a failure."""
        if not (self.caps.resumable and self.chunked_compute):
            return None
        if self._caches is None or self._next_start <= 0 or self.done:
            return None
        ns = self._next_start
        lo = max(0, ns - self.caps.window) if self.caps.window else ns
        states, kv = [], []
        for gi, g in enumerate(M.block_groups(self.engine.cfg)):
            for pi, kind in enumerate(g.kinds):
                c = self._caches[gi][pi]
                if kind in ("ssd", "rglru"):
                    states.append((gi, pi, jax.tree.map(np.asarray, c)))
                else:
                    self_c = c["self"] if isinstance(c, dict) else c
                    kv.append((gi, pi, {
                        "k": np.asarray(self_c.k[:, :, lo:ns]),
                        "v": np.asarray(self_c.v[:, :, lo:ns])}))
        return {"seq_len": self.seq_len, "next_start": ns,
                "row_start": lo, "states": states, "kv": kv}

    def _apply_resume(self, snap: Dict[str, Any]) -> None:
        ns = int(snap["next_start"])
        s0 = int(snap["row_start"])
        caches = [list(g) for g in self._caches]
        for gi, pi, st in snap["states"]:
            old = caches[gi][pi]
            caches[gi][pi] = jax.tree.map(
                lambda o, n: jnp.asarray(n, o.dtype), old, st)
        for gi, pi, ent in snap["kv"]:
            c = caches[gi][pi]
            self_c = c["self"] if isinstance(c, dict) else c
            pos = jnp.broadcast_to(
                jnp.arange(s0, ns, dtype=self_c.pos.dtype),
                self_c.pos[:, :, s0:ns].shape)
            new_self = dataclasses.replace(
                self_c,
                k=self_c.k.at[:, :, s0:ns].set(
                    jnp.asarray(ent["k"], self_c.k.dtype)),
                v=self_c.v.at[:, :, s0:ns].set(
                    jnp.asarray(ent["v"], self_c.v.dtype)),
                pos=self_c.pos.at[:, :, s0:ns].set(pos))
            caches[gi][pi] = ({**c, "self": new_self}
                              if isinstance(c, dict) else new_self)
        self._caches = tuple(tuple(g) for g in caches)
        self._next_start = ns
        self.engine.stats.resumed_tokens += ns

    def _preload_caches(self, caches):
        """Seed the dense chunked-prefill cache with the replayed prefix
        KV so computed chunks resume at ``prefix_tokens`` with the exact
        bits a cold run would have produced. ``pos`` rows must carry the
        real absolute positions — attention masks on them."""
        caches = [list(g) for g in caches]
        for kind, gi, pi, ent in self._cached_entries:
            c = caches[gi][pi]
            s0 = int(ent["start"])
            if kind == "mla":
                n = int(np.asarray(ent["ckv"]).shape[1])
                c = dataclasses.replace(
                    c,
                    ckv=c.ckv.at[:, 0, s0:s0 + n].set(
                        jnp.asarray(ent["ckv"]).astype(c.ckv.dtype)),
                    kpe=c.kpe.at[:, 0, s0:s0 + n].set(
                        jnp.asarray(ent["kpe"]).astype(c.kpe.dtype)),
                    pos=c.pos.at[:, 0, s0:s0 + n].set(
                        jnp.arange(s0, s0 + n, dtype=c.pos.dtype)))
            else:
                n = int(np.asarray(ent["k"]).shape[1])
                c = dataclasses.replace(
                    c,
                    k=c.k.at[:, 0, s0:s0 + n].set(
                        jnp.asarray(ent["k"]).astype(c.k.dtype)),
                    v=c.v.at[:, 0, s0:s0 + n].set(
                        jnp.asarray(ent["v"]).astype(c.v.dtype)),
                    pos=c.pos.at[:, 0, s0:s0 + n].set(
                        jnp.arange(s0, s0 + n, dtype=c.pos.dtype)))
            caches[gi][pi] = c
        return tuple(tuple(g) for g in caches)


class Engine:
    """One model instance with paged KV and slot-based continuous batching."""

    def __init__(self, name: str, cfg: ModelConfig, params,
                 vendor: VendorProfile, *, num_blocks: int = 256,
                 max_batch: int = 8, max_seq_len: int = 512,
                 mem_len: int = 0, role: str = "both",
                 prefix_cache: bool = False):
        self.name = name
        self.cfg = cfg
        self.params = params
        self.vendor = vendor
        self.role = role
        self.max_batch = max_batch
        self.max_seq_len = max_seq_len
        self.mem_len = mem_len or (cfg.max_source_len if cfg.is_enc_dec else 0)
        self.specs = page_specs_for(cfg, vendor.block_size, vendor.layout,
                                    vendor.kv_dtype)
        self.block_size = vendor.block_size
        self.max_blocks_per_seq = -(-max_seq_len // vendor.block_size)
        self.allocator = BlockAllocator(num_blocks)
        self.allocator.allocate("__scratch__", 1)   # trash page for idle slots
        self._scratch_block = self.allocator.blocks_of("__scratch__")[0]
        self.caches = M.init_paged_caches(cfg, self.specs, num_blocks,
                                          batch=max_batch, mem_len=self.mem_len)
        # slot bookkeeping (host side)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        # a slot is reserved when slot_req is set; ready once its KV has
        # fully landed (streamed chunks materialized + first token known)
        self.slot_ready: List[bool] = [False] * max_batch
        self.block_tables = np.full((max_batch, self.max_blocks_per_seq),
                                    self._scratch_block, np.int32)
        self.seq_lens = np.zeros((max_batch,), np.int32)
        self.last_token = np.zeros((max_batch,), np.int32)
        self.stats = EngineStats()
        self.failed = False
        # shared-prefix KV cache (opt-in): the decode role indexes pool
        # pages by hash chain; the prefill role keeps host-side wire
        # entries to replay instead of recomputing
        self.prefix_cache_enabled = bool(prefix_cache)
        self.prefix_store: Optional[PrefixStore] = None
        self.host_prefix_store: Optional[HostPrefixStore] = None
        if prefix_cache and role in ("decode", "both"):
            self.prefix_store = PrefixStore(self.allocator, self.block_size)
        if prefix_cache and role in ("prefill", "both"):
            self.host_prefix_store = HostPrefixStore(self.block_size)
        # per-slot prefix tokens already resident at reservation time —
        # the handoff skips exactly this many tokens on the wire
        self.slot_prefix_tokens: List[int] = [0] * max_batch
        self._rng = np.random.default_rng(abs(hash(name)) % (2 ** 31))
        self._build_jits()

    # ------------------------------------------------------------------ #
    def _build_jits(self) -> None:
        cfg = self.cfg

        @partial(jax.jit, static_argnames=("prompt_len",))
        def _prefill(params, inputs, prompt_len):
            caches = M.init_caches(cfg, inputs["tokens"].shape[0], prompt_len,
                                   cfg.cdtype, mem_len=self.mem_len)
            return M.prefill(params, cfg, inputs, caches)

        @jax.jit
        def _decode(params, tokens, seq_lens, block_table, write_blocks,
                    write_slots, caches):
            return M.decode_step_paged(params, cfg, tokens, seq_lens,
                                       block_table, write_blocks, write_slots,
                                       caches, self.specs)

        def _place(caches, updates, slot):
            """Write per-sequence rows (states / cross kv) into batch axis 1."""
            def upd(c, u):
                return c.at[:, slot].set(u.astype(c.dtype))
            return jax.tree.map(upd, caches, updates)

        @jax.jit
        def _prefill_chunk(params, tokens, positions, caches):
            """One chunk of incremental prefill: the decode path over a
            dense prompt-capacity cache (retraced per distinct chunk len)."""
            return M.decode_step(params, cfg, tokens, positions, caches)

        @jax.jit
        def _prefill_chunk_embeds(params, embeds, positions, caches):
            """Chunked prefill over precomputed embeddings (vision prefix)."""
            return M.decode_step_embeds(params, cfg, embeds, positions, caches)

        @jax.jit
        def _encode(params, frames):
            """Encoder preamble of a chunked enc-dec prefill."""
            return M.encode(params, cfg, frames)

        @jax.jit
        def _cross_kv(params, memory):
            return M.encoder_cross_kv(params, cfg, memory)

        @jax.jit
        def _merged_embeds(params, patches, tokens):
            emb = M.embed_tokens(params, cfg, tokens)
            return jnp.concatenate([patches.astype(cfg.cdtype), emb], axis=1)

        self._prefill_fn = _prefill
        self._decode_fn = _decode
        self._chunk_fn = _prefill_chunk
        self._chunk_embeds_fn = _prefill_chunk_embeds
        self._encode_fn = _encode
        self._cross_kv_fn = _cross_kv
        self._embed_fn = _merged_embeds
        self._place_fn = jax.jit(_place, donate_argnums=(0,))

    @property
    def supports_chunked_prefill(self) -> bool:
        """Incremental chunk compute is a model-structure property — see
        ModelConfig.prefill_capabilities."""
        return self.prefill_capabilities().incremental

    def prefill_capabilities(self) -> PrefillCapabilities:
        """What this instance's family supports on the prefill path — a
        frozen descriptor consumed (not introspected) by the scheduler,
        router and planner, mirroring the connector ``capabilities()``
        convention."""
        return self.cfg.prefill_capabilities()

    def prefill_stream(self, req: Request,
                       chunk_tokens: Optional[int] = None,
                       chunked_compute: Optional[bool] = None,
                       mode: Optional[PrefillMode] = None,
                       resume: Optional[Dict[str, Any]] = None
                       ) -> PrefillStream:
        """Start a resumable (chunked) prefill for ``req``."""
        return PrefillStream(self, req, chunk_tokens, chunked_compute,
                             mode=mode, resume=resume)

    # ------------------------------------------------------------------ #
    # Prefill (P role)
    # ------------------------------------------------------------------ #
    def prefill(self, req: Request) -> Dict[str, Any]:
        """Run prefill for one request; returns the handoff package:
        {"first_token", "kv": per-group list, "states", "cross", "logits"}.

        The KV part stays in *this* engine's canonical per-layer form — the
        transfer module converts it to the wire and the D instance's format.
        """
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        t0 = time.perf_counter()
        cfg = self.cfg
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        inputs: Dict[str, Any] = {"tokens": tokens}
        if req.frames is not None:
            inputs["frames"] = jnp.asarray(req.frames)[None]
        if req.patches is not None:
            inputs["patches"] = jnp.asarray(req.patches)[None]
        plen = req.prompt_len + (req.patches.shape[0] if req.patches is not None else 0)
        last_logits, caches = self._prefill_fn(self.params, inputs, plen)
        first_token = self._sample(np.asarray(last_logits), req)[0]
        package = self._package_handoff(caches, plen)
        package["first_token"] = int(first_token)
        package["seq_len"] = plen
        self.stats.prefill_tokens += plen
        self.stats.prefill_chunks += 1
        self._note_prefill_compute(time.perf_counter() - t0)
        return package

    def _note_prefill_compute(self, dt: float) -> None:
        """Account prefill compute time. On an integrated (role="both")
        instance, compute spent while decode-ready sequences sat waiting
        is measured decode-stall — the interference disaggregation
        removes (~0 on pure P or pure D roles)."""
        self.stats.prefill_seconds += dt
        if self.role == "both" and any(
                r is not None and self.slot_ready[i]
                for i, r in enumerate(self.slot_req)):
            self.stats.contention_stall_seconds += dt

    def _note_resume_unsupported(self) -> None:
        """A request wanted prefix-cache replay or mid-stream resume but
        this family cannot support it — count every occurrence, log once
        per (family, attention_kind)."""
        self.stats.resume_unsupported += 1
        key = (self.cfg.family, self.cfg.attention_kind)
        if key not in _RESUME_WARNED:
            _RESUME_WARNED.add(key)
            log.warning(
                "family %s (attention=%s): prefix-cache replay / mid-stream "
                "resume unsupported — falling back to full recompute", *key)

    def _package_handoff(self, caches, seq_len: int) -> Dict[str, Any]:
        """Extract per-layer canonical KV (+ states / cross) for transfer."""
        cfg = self.cfg
        groups = M.block_groups(cfg)
        kv, states, cross = [], [], []
        for gi, g in enumerate(groups):
            for pi, kind in enumerate(g.kinds):
                c = caches[gi][pi]
                if kind == "ssd" or kind == "rglru":
                    states.append(("state", gi, pi,
                                   jax.tree.map(lambda x: x[:, 0], c)))
                    continue
                self_c = c["self"] if isinstance(c, dict) else c
                if cfg.attention_kind == "mla":
                    kv.append(("mla", gi, pi, {
                        "ckv": self_c.ckv[:, 0, :seq_len],       # (count,S,lora)
                        "kpe": self_c.kpe[:, 0, :seq_len]}))
                else:
                    cap = self_c.k.shape[2]
                    s = min(seq_len, cap)
                    kv.append(("kv", gi, pi, {
                        # (count, S', kv, hd) — last `cap` tokens for SWA
                        "k": self_c.k[:, 0, :s] if cap >= seq_len else self_c.k[:, 0],
                        "v": self_c.v[:, 0, :s] if cap >= seq_len else self_c.v[:, 0],
                        "pos": self_c.pos[:, 0]}))
                if isinstance(c, dict):                          # enc-dec cross
                    cross.append((gi, pi, {
                        "cross_k": c["cross_k"][:, 0],
                        "cross_v": c["cross_v"][:, 0],
                        "mem_len": c["mem_len"][:, 0]}))
        return {"kv": kv, "states": states, "cross": cross}

    # ------------------------------------------------------------------ #
    # Decode (D role)
    # ------------------------------------------------------------------ #
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def load(self) -> float:
        """Outstanding work (for the global scheduler's load-aware routing)."""
        active = sum(1 for r in self.slot_req if r is not None)
        return active / self.max_batch

    def can_admit(self, seq_len: int, new_tokens: int) -> bool:
        need = -(-(seq_len + new_tokens) // self.block_size)
        free = self.allocator.free_blocks
        if self.prefix_store is not None:
            # zero-ref cached blocks are reclaimable on demand
            free += self.prefix_store.evictable_blocks()
        return (not self.failed and len(self.free_slots()) > 0
                and free >= need
                and seq_len + new_tokens <= self.max_seq_len)

    def _prefix_eligible(self, req: Request) -> bool:
        """Prefix reuse needs every cached row to stay attendable across
        the whole decode (caps.prefix_cache) and a pure-token prompt —
        mirrors PrefillStream's gate."""
        return (self.prefill_capabilities().prefix_cache
                and req.patches is None and req.frames is None)

    def reserve_sequence(self, req: Request, seq_len: int, *,
                         use_prefix_cache: bool = False
                         ) -> Tuple[int, np.ndarray]:
        """Claim a decode slot + paged blocks for an in-flight handoff.

        The slot is occupied (counts toward load, not free) but NOT decoded
        until ``activate_sequence`` — streamed KV chunks land in between.

        With ``use_prefix_cache`` (and a store), the block table's head
        borrows the store's pages for the longest cached prefix — pinned,
        read-shared — plus an optional copy-on-write divergence block;
        ``slot_prefix_tokens[slot]`` records how many leading tokens need
        no wire transfer. All writes (RMW re-page and decode appends) land
        at positions ≥ that count, i.e. strictly in private blocks."""
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        slot = self.free_slots()[0]
        nblocks = -(-(seq_len + req.max_new_tokens) // self.block_size)
        nblocks = min(nblocks, self.max_blocks_per_seq)
        store = self.prefix_store
        prefix_tokens = 0
        if (use_prefix_cache and store is not None
                and self._prefix_eligible(req)):
            # reuse limit seq_len-1: P always computes ≥ 1 trailing token
            # (it must sample first_token from real logits)
            match = store.match(req.prompt, min(seq_len, req.prompt_len) - 1)
            match = match.truncated(max(nblocks - 1, 0), self.block_size)
            store.acquire(match, req.req_id)
            shared = list(match.block_ids)
            need = nblocks - len(shared)
            short = need - self.allocator.free_blocks
            if short > 0:
                store.evict(short)
            try:
                private = self.allocator.allocate(req.req_id, need)
            except MemoryError:
                store.release_seq(req.req_id)
                raise
            if match.cow_src is not None and need > 0:
                # mid-block divergence: private copy of the source page,
                # valid up to match.tokens — later rows are overwritten
                # by the stream's RMW re-page
                self._copy_block(match.cow_src, private[0])
            prefix_tokens = match.tokens
            block_ids = shared + private
        else:
            if (use_prefix_cache and store is not None
                    and not self._prefix_eligible(req)):
                # the router asked for prefix reuse but this family's rows
                # can't be replayed — previously a silent full recompute
                self._note_resume_unsupported()
            short = nblocks - self.allocator.free_blocks
            if store is not None and short > 0:
                store.evict(short)
            block_ids = self.allocator.allocate(req.req_id, nblocks)
        self.block_tables[slot, :] = self._scratch_block
        self.block_tables[slot, :nblocks] = block_ids
        self.seq_lens[slot] = 0
        self.slot_req[slot] = req
        self.slot_ready[slot] = False
        self.slot_prefix_tokens[slot] = prefix_tokens
        return slot, np.asarray(block_ids, np.int32)

    def _copy_block(self, src: int, dst: int) -> None:
        """Copy one physical page across every paged pool (COW)."""
        caches = [list(g) for g in self.caches]
        for gi, g in enumerate(caches):
            for pi, c in enumerate(g):
                if not isinstance(c, dict):
                    continue
                new = dict(c)
                changed = False
                for name, arr in c.items():
                    if name.endswith("_pool"):
                        # pools stack layers on axis 0: (count, blocks, ...)
                        new[name] = arr.at[:, dst].set(arr[:, src])
                        changed = True
                if changed:
                    g[pi] = new
        self.caches = tuple(tuple(g) for g in caches)

    def activate_sequence(self, slot: int, first_token: int,
                          seq_len: int) -> None:
        """All KV landed — the slot joins continuous batching next step.

        With a prefix store, the sequence's full prompt blocks are adopted
        into it here (ownership transfer, still pinned for this sequence):
        every block is fully written by now, and decode appends only at
        positions ≥ seq_len, which live past the last full prompt block."""
        self.seq_lens[slot] = seq_len
        self.last_token[slot] = first_token
        self.slot_ready[slot] = True
        req = self.slot_req[slot]
        if (self.prefix_store is not None and req is not None
                and self._prefix_eligible(req)):
            self._adopt_prompt_blocks(req, min(seq_len, req.prompt_len), slot)

    def _adopt_prompt_blocks(self, req: Request, prompt_len: int,
                             slot: int) -> None:
        store = self.prefix_store
        prompt = np.asarray(req.prompt)
        bs = self.block_size
        parent = hashing.ROOT
        for b in range(min(prompt_len, len(prompt)) // bs):
            blk = prompt[b * bs:(b + 1) * bs]
            digest = hashing.block_hash(parent, blk)
            # blocks borrowed from the store at reservation re-hash to a
            # cached digest → insert() is a refresh no-op; only this
            # sequence's own (private) blocks transfer ownership
            store.insert(req.req_id, digest, parent, blk,
                         int(self.block_tables[slot, b]))
            parent = digest

    def abort_reservation(self, slot: int) -> None:
        """Handoff failed mid-stream: free the slot and its blocks."""
        if self.failed:
            # node is down: recover() rebuilds the allocator and pools, but
            # the slot must drop its request NOW so the failure sweep does
            # not requeue it a second time (two parallel lives)
            self.slot_req[slot] = None
            self.slot_ready[slot] = False
            self.slot_prefix_tokens[slot] = 0
            return
        self.release(slot)

    def add_sequence(self, req: Request, package: Dict[str, Any],
                     materialize_fn) -> int:
        """Admit a fully-transferred request into a decode slot.

        ``materialize_fn(engine, slot, block_ids, package)`` is provided by
        the disagg orchestrator (it owns the compat conversion)."""
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        seq_len = package["seq_len"]
        slot, block_ids = self.reserve_sequence(req, seq_len)
        materialize_fn(self, slot, block_ids, package)
        self.activate_sequence(slot, package["first_token"], seq_len)
        return slot

    def release(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is not None:
            if self.prefix_store is not None:
                # unpin borrowed/adopted prefix blocks (they stay cached
                # at zero refs until LRU eviction), then free whatever
                # this sequence still owns privately
                self.prefix_store.release_seq(req.req_id)
            self.allocator.free(req.req_id)
        self.slot_req[slot] = None
        self.slot_ready[slot] = False
        self.seq_lens[slot] = 0
        self.slot_prefix_tokens[slot] = 0
        self.block_tables[slot, :] = self._scratch_block

    def decode_step(self) -> List[Tuple[int, Request, int]]:
        """One continuous-batching step. Returns [(slot, request, token)]."""
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and self.slot_ready[i]]
        if not active:
            return []
        t0 = time.perf_counter()
        write_slots = self.seq_lens % self.block_size
        write_block_idx = self.seq_lens // self.block_size
        write_blocks = self.block_tables[np.arange(self.max_batch),
                                         np.minimum(write_block_idx,
                                                    self.max_blocks_per_seq - 1)]
        idle = np.asarray([r is None or not self.slot_ready[i]
                           for i, r in enumerate(self.slot_req)])
        write_blocks = np.where(idle, self._scratch_block, write_blocks)
        logits, self.caches = self._decode_fn(
            self.params, jnp.asarray(self.last_token[:, None]),
            jnp.asarray(self.seq_lens), jnp.asarray(self.block_tables),
            jnp.asarray(write_blocks.astype(np.int32)),
            jnp.asarray(write_slots.astype(np.int32)), self.caches)
        logits = np.asarray(logits[:, 0])
        out = []
        for slot in active:
            req = self.slot_req[slot]
            tok = self._sample(logits[slot:slot + 1], req)[0]
            self.seq_lens[slot] += 1
            self.last_token[slot] = tok
            out.append((slot, req, int(tok)))
        self.stats.decode_steps += 1
        self.stats.decode_tokens += len(active)
        self.stats.decode_seconds += time.perf_counter() - t0
        return out

    # ------------------------------------------------------------------ #
    def _sample(self, logits: np.ndarray, req: Request) -> np.ndarray:
        if req.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits.astype(np.float64) / req.temperature
        z -= z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.asarray([self._rng.choice(p.shape[-1], p=p[i])
                           for i in range(p.shape[0])], np.int32)

    # -- fault injection ------------------------------------------------ #
    def fail(self) -> None:
        self.failed = True
        self.stats.failures_injected += 1

    def recover(self) -> None:
        """Restart: all volatile KV state is lost (as on a real node)."""
        self.failed = False
        for slot in range(self.max_batch):
            self.release(slot)
        self.allocator = BlockAllocator(self.allocator.num_blocks)
        self.allocator.allocate("__scratch__", 1)
        self._scratch_block = self.allocator.blocks_of("__scratch__")[0]
        if self.prefix_store is not None:
            # the pages the store indexed died with the pool
            self.prefix_store = PrefixStore(self.allocator, self.block_size)
        self.slot_prefix_tokens = [0] * self.max_batch
