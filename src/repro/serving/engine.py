"""Model-instance engine: prefill, continuous-batching paged decode.

One Engine == one "model instance" in the paper's sense (a P instance, a D
instance, or an integrated instance). Vendor-specific VRAM management is the
engine's ``KVPageSpec`` (block size / layout / dtype); compute dtype and the
logical TP degree used for KV sharding complete the vendor profile.

The engine is device-agnostic: on this CPU container it runs the tiny-model
functional path; on a TPU mesh the same jitted callables are pjit'd by the
launcher.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.paged_cache import BlockAllocator, KVPageSpec
from repro.serving.request import Request, State


def page_specs_for(cfg: ModelConfig, block_size: int, layout: str,
                   dtype: str) -> Dict[str, KVPageSpec]:
    if cfg.attention_kind == "mla":
        m = cfg.mla
        return {
            "ckv": KVPageSpec(block_size, layout, dtype, 1, m.kv_lora_rank),
            "kpe": KVPageSpec(block_size, layout, dtype, 1, m.qk_rope_head_dim),
        }
    return {"kv": KVPageSpec(block_size, layout, dtype,
                             max(cfg.num_kv_heads, 1), cfg.hd)}


@dataclasses.dataclass(frozen=True)
class VendorProfile:
    """The 'vendor' of an instance — everything the heterogeneous compat
    module must align across instances."""
    name: str
    block_size: int = 16
    layout: str = "nbhd"
    kv_dtype: str = "float32"
    tp: int = 1                 # logical TP degree of stored KV shards
    hardware: str = "tpu-v5e"   # planner HardwareSpec key


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0
    failures_injected: int = 0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


class Engine:
    """One model instance with paged KV and slot-based continuous batching."""

    def __init__(self, name: str, cfg: ModelConfig, params,
                 vendor: VendorProfile, *, num_blocks: int = 256,
                 max_batch: int = 8, max_seq_len: int = 512,
                 mem_len: int = 0, role: str = "both"):
        self.name = name
        self.cfg = cfg
        self.params = params
        self.vendor = vendor
        self.role = role
        self.max_batch = max_batch
        self.max_seq_len = max_seq_len
        self.mem_len = mem_len or (cfg.max_source_len if cfg.is_enc_dec else 0)
        self.specs = page_specs_for(cfg, vendor.block_size, vendor.layout,
                                    vendor.kv_dtype)
        self.block_size = vendor.block_size
        self.max_blocks_per_seq = -(-max_seq_len // vendor.block_size)
        self.allocator = BlockAllocator(num_blocks)
        self.allocator.allocate("__scratch__", 1)   # trash page for idle slots
        self._scratch_block = self.allocator.blocks_of("__scratch__")[0]
        self.caches = M.init_paged_caches(cfg, self.specs, num_blocks,
                                          batch=max_batch, mem_len=self.mem_len)
        # slot bookkeeping (host side)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.block_tables = np.full((max_batch, self.max_blocks_per_seq),
                                    self._scratch_block, np.int32)
        self.seq_lens = np.zeros((max_batch,), np.int32)
        self.last_token = np.zeros((max_batch,), np.int32)
        self.stats = EngineStats()
        self.failed = False
        self._rng = np.random.default_rng(abs(hash(name)) % (2 ** 31))
        self._build_jits()

    # ------------------------------------------------------------------ #
    def _build_jits(self) -> None:
        cfg = self.cfg

        @partial(jax.jit, static_argnames=("prompt_len",))
        def _prefill(params, inputs, prompt_len):
            caches = M.init_caches(cfg, inputs["tokens"].shape[0], prompt_len,
                                   cfg.cdtype, mem_len=self.mem_len)
            return M.prefill(params, cfg, inputs, caches)

        @jax.jit
        def _decode(params, tokens, seq_lens, block_table, write_blocks,
                    write_slots, caches):
            return M.decode_step_paged(params, cfg, tokens, seq_lens,
                                       block_table, write_blocks, write_slots,
                                       caches, self.specs)

        def _place(caches, updates, slot):
            """Write per-sequence rows (states / cross kv) into batch axis 1."""
            def upd(c, u):
                return c.at[:, slot].set(u.astype(c.dtype))
            return jax.tree.map(upd, caches, updates)

        self._prefill_fn = _prefill
        self._decode_fn = _decode
        self._place_fn = jax.jit(_place, donate_argnums=(0,))

    # ------------------------------------------------------------------ #
    # Prefill (P role)
    # ------------------------------------------------------------------ #
    def prefill(self, req: Request) -> Dict[str, Any]:
        """Run prefill for one request; returns the handoff package:
        {"first_token", "kv": per-group list, "states", "cross", "logits"}.

        The KV part stays in *this* engine's canonical per-layer form — the
        transfer module converts it to the wire and the D instance's format.
        """
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        t0 = time.perf_counter()
        cfg = self.cfg
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        inputs: Dict[str, Any] = {"tokens": tokens}
        if req.frames is not None:
            inputs["frames"] = jnp.asarray(req.frames)[None]
        if req.patches is not None:
            inputs["patches"] = jnp.asarray(req.patches)[None]
        plen = req.prompt_len + (req.patches.shape[0] if req.patches is not None else 0)
        last_logits, caches = self._prefill_fn(self.params, inputs, plen)
        first_token = self._sample(np.asarray(last_logits), req)[0]
        package = self._package_handoff(caches, plen)
        package["first_token"] = int(first_token)
        package["seq_len"] = plen
        self.stats.prefill_tokens += plen
        self.stats.prefill_seconds += time.perf_counter() - t0
        return package

    def _package_handoff(self, caches, seq_len: int) -> Dict[str, Any]:
        """Extract per-layer canonical KV (+ states / cross) for transfer."""
        cfg = self.cfg
        groups = M.block_groups(cfg)
        kv, states, cross = [], [], []
        for gi, g in enumerate(groups):
            for pi, kind in enumerate(g.kinds):
                c = caches[gi][pi]
                if kind == "ssd" or kind == "rglru":
                    states.append(("state", gi, pi,
                                   jax.tree.map(lambda x: x[:, 0], c)))
                    continue
                self_c = c["self"] if isinstance(c, dict) else c
                if cfg.attention_kind == "mla":
                    kv.append(("mla", gi, pi, {
                        "ckv": self_c.ckv[:, 0, :seq_len],       # (count,S,lora)
                        "kpe": self_c.kpe[:, 0, :seq_len]}))
                else:
                    cap = self_c.k.shape[2]
                    s = min(seq_len, cap)
                    kv.append(("kv", gi, pi, {
                        # (count, S', kv, hd) — last `cap` tokens for SWA
                        "k": self_c.k[:, 0, :s] if cap >= seq_len else self_c.k[:, 0],
                        "v": self_c.v[:, 0, :s] if cap >= seq_len else self_c.v[:, 0],
                        "pos": self_c.pos[:, 0]}))
                if isinstance(c, dict):                          # enc-dec cross
                    cross.append((gi, pi, {
                        "cross_k": c["cross_k"][:, 0],
                        "cross_v": c["cross_v"][:, 0],
                        "mem_len": c["mem_len"][:, 0]}))
        return {"kv": kv, "states": states, "cross": cross}

    # ------------------------------------------------------------------ #
    # Decode (D role)
    # ------------------------------------------------------------------ #
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def load(self) -> float:
        """Outstanding work (for the global scheduler's load-aware routing)."""
        active = sum(1 for r in self.slot_req if r is not None)
        return active / self.max_batch

    def can_admit(self, seq_len: int, new_tokens: int) -> bool:
        need = -(-(seq_len + new_tokens) // self.block_size)
        return (not self.failed and len(self.free_slots()) > 0
                and self.allocator.can_allocate(need)
                and seq_len + new_tokens <= self.max_seq_len)

    def add_sequence(self, req: Request, package: Dict[str, Any],
                     materialize_fn) -> int:
        """Admit a transferred request into a decode slot.

        ``materialize_fn(engine, slot, block_ids, package)`` is provided by
        the disagg orchestrator (it owns the compat conversion)."""
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        slot = self.free_slots()[0]
        seq_len = package["seq_len"]
        nblocks = -(-(seq_len + req.max_new_tokens) // self.block_size)
        nblocks = min(nblocks, self.max_blocks_per_seq)
        block_ids = self.allocator.allocate(req.req_id, nblocks)
        self.block_tables[slot, :] = self._scratch_block
        self.block_tables[slot, :nblocks] = block_ids
        self.seq_lens[slot] = seq_len
        self.last_token[slot] = package["first_token"]
        self.slot_req[slot] = req
        materialize_fn(self, slot, np.asarray(block_ids, np.int32), package)
        return slot

    def release(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is not None:
            self.allocator.free(req.req_id)
        self.slot_req[slot] = None
        self.seq_lens[slot] = 0
        self.block_tables[slot, :] = self._scratch_block

    def decode_step(self) -> List[Tuple[int, Request, int]]:
        """One continuous-batching step. Returns [(slot, request, token)]."""
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        t0 = time.perf_counter()
        write_slots = self.seq_lens % self.block_size
        write_block_idx = self.seq_lens // self.block_size
        write_blocks = self.block_tables[np.arange(self.max_batch),
                                         np.minimum(write_block_idx,
                                                    self.max_blocks_per_seq - 1)]
        idle = np.asarray([r is None for r in self.slot_req])
        write_blocks = np.where(idle, self._scratch_block, write_blocks)
        logits, self.caches = self._decode_fn(
            self.params, jnp.asarray(self.last_token[:, None]),
            jnp.asarray(self.seq_lens), jnp.asarray(self.block_tables),
            jnp.asarray(write_blocks.astype(np.int32)),
            jnp.asarray(write_slots.astype(np.int32)), self.caches)
        logits = np.asarray(logits[:, 0])
        out = []
        for slot in active:
            req = self.slot_req[slot]
            tok = self._sample(logits[slot:slot + 1], req)[0]
            self.seq_lens[slot] += 1
            self.last_token[slot] = tok
            out.append((slot, req, int(tok)))
        self.stats.decode_steps += 1
        self.stats.decode_tokens += len(active)
        self.stats.decode_seconds += time.perf_counter() - t0
        return out

    # ------------------------------------------------------------------ #
    def _sample(self, logits: np.ndarray, req: Request) -> np.ndarray:
        if req.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits.astype(np.float64) / req.temperature
        z -= z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.asarray([self._rng.choice(p.shape[-1], p=p[i])
                           for i in range(p.shape[0])], np.int32)

    # -- fault injection ------------------------------------------------ #
    def fail(self) -> None:
        self.failed = True
        self.stats.failures_injected += 1

    def recover(self) -> None:
        """Restart: all volatile KV state is lost (as on a real node)."""
        self.failed = False
        for slot in range(self.max_batch):
            self.release(slot)
        self.allocator = BlockAllocator(self.allocator.num_blocks)
        self.allocator.allocate("__scratch__", 1)
        self._scratch_block = self.allocator.blocks_of("__scratch__")[0]
