"""Model-instance engine: prefill, continuous-batching paged decode.

One Engine == one "model instance" in the paper's sense (a P instance, a D
instance, or an integrated instance). Vendor-specific VRAM management is the
engine's ``KVPageSpec`` (block size / layout / dtype); compute dtype and the
logical TP degree used for KV sharding complete the vendor profile.

The engine is device-agnostic: on this CPU container it runs the tiny-model
functional path; on a TPU mesh the same jitted callables are pjit'd by the
launcher.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.paged_cache import BlockAllocator, KVPageSpec
from repro.serving.prefix_cache import HostPrefixStore, PrefixStore, hashing
from repro.serving.request import Request, State


def page_specs_for(cfg: ModelConfig, block_size: int, layout: str,
                   dtype: str) -> Dict[str, KVPageSpec]:
    if cfg.attention_kind == "mla":
        m = cfg.mla
        return {
            "ckv": KVPageSpec(block_size, layout, dtype, 1, m.kv_lora_rank),
            "kpe": KVPageSpec(block_size, layout, dtype, 1, m.qk_rope_head_dim),
        }
    return {"kv": KVPageSpec(block_size, layout, dtype,
                             max(cfg.num_kv_heads, 1), cfg.hd)}


@dataclasses.dataclass(frozen=True)
class VendorProfile:
    """The 'vendor' of an instance — everything the heterogeneous compat
    module must align across instances."""
    name: str
    block_size: int = 16
    layout: str = "nbhd"
    kv_dtype: str = "float32"
    tp: int = 1                 # logical TP degree of stored KV shards
    hardware: str = "tpu-v5e"   # planner HardwareSpec key


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    prefill_chunks: int = 0         # compute chunks (1 per monolithic prefill)
    decode_steps: int = 0
    decode_tokens: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0
    failures_injected: int = 0
    prefix_cached_tokens: int = 0   # prompt tokens replayed from the P-side
    #                                 host prefix store instead of recomputed

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _chronological(arr: np.ndarray, pos: np.ndarray) -> Tuple[np.ndarray, int]:
    """Ring-buffer shard (count, cap, ...) + pos (count, cap) →
    chronological (count, cap, ...) and the absolute start position."""
    order = np.argsort(pos[0])                    # same order across layers
    return arr[:, order], int(pos[0][order[0]])


def kv_entries_with_start(package_kv: List[Tuple]) -> List[Tuple]:
    """Normalize a prefill package's KV entries to chronological order with
    an absolute ``start`` position — the canonical pre-wire form that both
    the monolithic encoder and the chunk splitter consume.

    Returns [(kind, gi, pi, entry)] where entry holds contiguous arrays of
    shape (count, S', ...) covering absolute positions [start, start+S')."""
    out = []
    for kind, gi, pi, entry in package_kv:
        if kind == "mla":
            out.append((kind, gi, pi, {"ckv": np.asarray(entry["ckv"]),
                                       "kpe": np.asarray(entry["kpe"]),
                                       "start": 0}))
            continue
        k, v = np.asarray(entry["k"]), np.asarray(entry["v"])
        start = 0
        if "pos" in entry and k.shape[1] < np.max(entry["pos"]) + 1:
            pos = np.asarray(entry["pos"])
            k, start = _chronological(k, pos)
            v, _ = _chronological(v, pos)
        out.append((kind, gi, pi, {"k": k, "v": v, "start": start}))
    return out


def slice_kv_entries(entries: List[Tuple], w0: int, w1: int) -> List[Tuple]:
    """Restrict normalized entries to the absolute token window [w0, w1)."""
    out = []
    for kind, gi, pi, ent in entries:
        start = ent["start"]
        arrs = {n: a for n, a in ent.items() if n != "start"}
        length = next(iter(arrs.values())).shape[1]
        lo = max(w0, start)
        hi = min(w1, start + length)
        if hi <= lo:
            continue
        sl = {n: a[:, lo - start:hi - start] for n, a in arrs.items()}
        sl["start"] = lo
        out.append((kind, gi, pi, sl))
    return out


class PrefillStream:
    """Resumable chunked prefill on one P engine (paper §III-B overlap).

    ``next_chunk()`` yields KV chunk packages ``{"kv": entries, "start",
    "length"}`` until exhausted (then returns ``None``). Two compute modes:

      * *incremental* — attention-only families run the prompt through the
        decode path over a dense prompt-capacity cache, one chunk of tokens
        per call, so each chunk's KV can hit the wire while the next chunk
        computes (Mooncake-style layer/chunk-wise streaming).
      * *monolithic*  — families with recurrent/SSM state, encoders, or
        multimodal frontends compute the whole prompt in one pass on the
        first call; the wire still streams in ``chunk_tokens`` slices.

    ``first_token`` / ``tail_package()`` (states, cross-attention memory)
    become available once the final chunk has been produced."""

    def __init__(self, engine: "Engine", req: Request,
                 chunk_tokens: Optional[int] = None,
                 chunked_compute: Optional[bool] = None):
        self.engine = engine
        self.req = req
        patches = req.patches.shape[0] if req.patches is not None else 0
        self.seq_len = req.prompt_len + patches
        if chunk_tokens is not None and chunk_tokens <= 0:
            chunk_tokens = None               # 0/negative = monolithic
        self.chunk_tokens = chunk_tokens
        if chunked_compute is None:
            chunked_compute = engine.supports_chunked_prefill
        elif chunked_compute and not engine.supports_chunked_prefill:
            raise ValueError(
                f"{engine.cfg.name}: incremental chunked prefill is not "
                "supported for this family (ring-buffer, recurrent/SSM, "
                "enc-dec, or multimodal prefix)")
        self.chunked_compute = (chunked_compute
                                and chunk_tokens is not None
                                and chunk_tokens < self.seq_len)
        self.first_token: Optional[int] = None
        self.chunks_emitted = 0
        self._next_start = 0
        self._tail: Optional[Dict[str, Any]] = None
        self._entries: Optional[List[Tuple]] = None   # monolithic mode
        self._caches = None                           # incremental mode
        # P-side shared-prefix reuse: replay cached chunks instead of
        # recomputing them, and seed the dense cache so compute resumes
        # at the divergence point. Only the incremental path can resume
        # mid-prompt; the final token is always computed (first_token).
        self.prefix_tokens = 0
        self._p_store = None
        self._cached_entries: Optional[List[Tuple]] = None
        self._collect: Optional[List[Tuple]] = None
        store = getattr(engine, "host_prefix_store", None)
        if (store is not None and self.chunked_compute
                and req.patches is None and req.frames is None):
            self._p_store = store
            self._collect = []
            hit, entries = store.match(req.prompt, self.seq_len - 1)
            if hit > 0:
                self.prefix_tokens = hit
                self._cached_entries = entries

    @property
    def done(self) -> bool:
        return self._next_start >= self.seq_len and self.chunks_emitted > 0

    def tail_package(self) -> Dict[str, Any]:
        assert self.done, "tail_package before stream exhausted"
        return self._tail if self._tail is not None \
            else {"states": [], "cross": []}

    def next_chunk(self) -> Optional[Dict[str, Any]]:
        if self.done:
            return None
        if self._next_start < self.prefix_tokens:
            chunk = self._next_cached()
        elif self.chunked_compute:
            chunk = self._next_incremental()
        else:
            chunk = self._next_monolithic()
        self.chunks_emitted += 1
        if self._collect is not None:
            self._collect.extend(chunk["kv"])
            if self._next_start >= self.seq_len:
                self._p_store.insert_prompt(self.req.prompt, self._collect,
                                            self.seq_len)
        return chunk

    # -- replay from the host prefix store ------------------------------- #
    def _next_cached(self) -> Dict[str, Any]:
        eng = self.engine
        if eng.failed:
            raise RuntimeError(f"instance {eng.name} is down")
        c0 = self._next_start
        c1 = min(c0 + (self.chunk_tokens or self.prefix_tokens),
                 self.prefix_tokens)
        self._next_start = c1
        eng.stats.prefix_cached_tokens += c1 - c0
        return {"kv": slice_kv_entries(self._cached_entries, c0, c1),
                "start": c0, "length": c1 - c0, "compute_seconds": 0.0}

    # -- monolithic compute, chunked wire ------------------------------- #
    def _next_monolithic(self) -> Dict[str, Any]:
        if self._entries is None:
            package = self.engine.prefill(self.req)
            self.first_token = package["first_token"]
            self._tail = {"states": package["states"],
                          "cross": package["cross"]}
            self._entries = kv_entries_with_start(package["kv"])
            if self._entries:
                # ring-buffer (sliding) entries only cover the last window
                # of the prompt — don't ship empty chunks for the evicted
                # prefix, start streaming at the first position on the wire
                self._next_start = min(
                    min(e[3]["start"] for e in self._entries), self.seq_len)
        w0 = self._next_start
        if not self._entries or self.chunk_tokens is None:
            w1 = self.seq_len        # states-only: nothing to chunk
        else:
            w1 = min(w0 + self.chunk_tokens, self.seq_len)
        self._next_start = w1
        return {"kv": slice_kv_entries(self._entries, w0, w1),
                "start": w0, "length": w1 - w0, "compute_seconds": 0.0}

    # -- incremental compute (attention-only families) ------------------- #
    def _next_incremental(self) -> Dict[str, Any]:
        eng, cfg, req = self.engine, self.engine.cfg, self.req
        if eng.failed:
            raise RuntimeError(f"instance {eng.name} is down")
        t0 = time.perf_counter()
        if self._caches is None:
            # capacity rounded to a chunk multiple: prompts within the same
            # chunk bucket share one compiled cache shape (_chunk_fn traces
            # per (cache capacity, chunk length)); entries past seq_len stay
            # pos=-1 and are masked
            cap = -(-self.seq_len // self.chunk_tokens) * self.chunk_tokens
            self._caches = M.init_caches(cfg, 1, cap, cfg.cdtype)
            if self.prefix_tokens:
                self._caches = self._preload_caches(self._caches)
        c0 = self._next_start
        c1 = min(c0 + self.chunk_tokens, self.seq_len)
        tokens = jnp.asarray(req.prompt[c0:c1], jnp.int32)[None]
        positions = jnp.arange(c0, c1, dtype=jnp.int32)[None]
        logits, self._caches = eng._chunk_fn(eng.params, tokens, positions,
                                             self._caches)
        if c1 == self.seq_len:
            self.first_token = int(
                eng._sample(np.asarray(logits[:, -1]), req)[0])
        entries = []
        for gi, g in enumerate(M.block_groups(cfg)):
            for pi, _kind in enumerate(g.kinds):
                c = self._caches[gi][pi]
                if cfg.attention_kind == "mla":
                    entries.append(("mla", gi, pi, {
                        "ckv": np.asarray(c.ckv[:, 0, c0:c1]),
                        "kpe": np.asarray(c.kpe[:, 0, c0:c1]),
                        "start": c0}))
                else:
                    entries.append(("kv", gi, pi, {
                        "k": np.asarray(c.k[:, 0, c0:c1]),
                        "v": np.asarray(c.v[:, 0, c0:c1]),
                        "start": c0}))
        self._next_start = c1
        dt = time.perf_counter() - t0
        eng.stats.prefill_tokens += c1 - c0
        eng.stats.prefill_chunks += 1
        eng.stats.prefill_seconds += dt
        return {"kv": entries, "start": c0, "length": c1 - c0,
                "compute_seconds": dt}

    def _preload_caches(self, caches):
        """Seed the dense chunked-prefill cache with the replayed prefix
        KV so computed chunks resume at ``prefix_tokens`` with the exact
        bits a cold run would have produced. ``pos`` rows must carry the
        real absolute positions — attention masks on them."""
        caches = [list(g) for g in caches]
        for kind, gi, pi, ent in self._cached_entries:
            c = caches[gi][pi]
            s0 = int(ent["start"])
            if kind == "mla":
                n = int(np.asarray(ent["ckv"]).shape[1])
                c = dataclasses.replace(
                    c,
                    ckv=c.ckv.at[:, 0, s0:s0 + n].set(
                        jnp.asarray(ent["ckv"]).astype(c.ckv.dtype)),
                    kpe=c.kpe.at[:, 0, s0:s0 + n].set(
                        jnp.asarray(ent["kpe"]).astype(c.kpe.dtype)),
                    pos=c.pos.at[:, 0, s0:s0 + n].set(
                        jnp.arange(s0, s0 + n, dtype=c.pos.dtype)))
            else:
                n = int(np.asarray(ent["k"]).shape[1])
                c = dataclasses.replace(
                    c,
                    k=c.k.at[:, 0, s0:s0 + n].set(
                        jnp.asarray(ent["k"]).astype(c.k.dtype)),
                    v=c.v.at[:, 0, s0:s0 + n].set(
                        jnp.asarray(ent["v"]).astype(c.v.dtype)),
                    pos=c.pos.at[:, 0, s0:s0 + n].set(
                        jnp.arange(s0, s0 + n, dtype=c.pos.dtype)))
            caches[gi][pi] = c
        return tuple(tuple(g) for g in caches)


class Engine:
    """One model instance with paged KV and slot-based continuous batching."""

    def __init__(self, name: str, cfg: ModelConfig, params,
                 vendor: VendorProfile, *, num_blocks: int = 256,
                 max_batch: int = 8, max_seq_len: int = 512,
                 mem_len: int = 0, role: str = "both",
                 prefix_cache: bool = False):
        self.name = name
        self.cfg = cfg
        self.params = params
        self.vendor = vendor
        self.role = role
        self.max_batch = max_batch
        self.max_seq_len = max_seq_len
        self.mem_len = mem_len or (cfg.max_source_len if cfg.is_enc_dec else 0)
        self.specs = page_specs_for(cfg, vendor.block_size, vendor.layout,
                                    vendor.kv_dtype)
        self.block_size = vendor.block_size
        self.max_blocks_per_seq = -(-max_seq_len // vendor.block_size)
        self.allocator = BlockAllocator(num_blocks)
        self.allocator.allocate("__scratch__", 1)   # trash page for idle slots
        self._scratch_block = self.allocator.blocks_of("__scratch__")[0]
        self.caches = M.init_paged_caches(cfg, self.specs, num_blocks,
                                          batch=max_batch, mem_len=self.mem_len)
        # slot bookkeeping (host side)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        # a slot is reserved when slot_req is set; ready once its KV has
        # fully landed (streamed chunks materialized + first token known)
        self.slot_ready: List[bool] = [False] * max_batch
        self.block_tables = np.full((max_batch, self.max_blocks_per_seq),
                                    self._scratch_block, np.int32)
        self.seq_lens = np.zeros((max_batch,), np.int32)
        self.last_token = np.zeros((max_batch,), np.int32)
        self.stats = EngineStats()
        self.failed = False
        # shared-prefix KV cache (opt-in): the decode role indexes pool
        # pages by hash chain; the prefill role keeps host-side wire
        # entries to replay instead of recomputing
        self.prefix_cache_enabled = bool(prefix_cache)
        self.prefix_store: Optional[PrefixStore] = None
        self.host_prefix_store: Optional[HostPrefixStore] = None
        if prefix_cache and role in ("decode", "both"):
            self.prefix_store = PrefixStore(self.allocator, self.block_size)
        if prefix_cache and role in ("prefill", "both"):
            self.host_prefix_store = HostPrefixStore(self.block_size)
        # per-slot prefix tokens already resident at reservation time —
        # the handoff skips exactly this many tokens on the wire
        self.slot_prefix_tokens: List[int] = [0] * max_batch
        self._rng = np.random.default_rng(abs(hash(name)) % (2 ** 31))
        self._build_jits()

    # ------------------------------------------------------------------ #
    def _build_jits(self) -> None:
        cfg = self.cfg

        @partial(jax.jit, static_argnames=("prompt_len",))
        def _prefill(params, inputs, prompt_len):
            caches = M.init_caches(cfg, inputs["tokens"].shape[0], prompt_len,
                                   cfg.cdtype, mem_len=self.mem_len)
            return M.prefill(params, cfg, inputs, caches)

        @jax.jit
        def _decode(params, tokens, seq_lens, block_table, write_blocks,
                    write_slots, caches):
            return M.decode_step_paged(params, cfg, tokens, seq_lens,
                                       block_table, write_blocks, write_slots,
                                       caches, self.specs)

        def _place(caches, updates, slot):
            """Write per-sequence rows (states / cross kv) into batch axis 1."""
            def upd(c, u):
                return c.at[:, slot].set(u.astype(c.dtype))
            return jax.tree.map(upd, caches, updates)

        @jax.jit
        def _prefill_chunk(params, tokens, positions, caches):
            """One chunk of incremental prefill: the decode path over a
            dense prompt-capacity cache (retraced per distinct chunk len)."""
            return M.decode_step(params, cfg, tokens, positions, caches)

        self._prefill_fn = _prefill
        self._decode_fn = _decode
        self._chunk_fn = _prefill_chunk
        self._place_fn = jax.jit(_place, donate_argnums=(0,))

    @property
    def supports_chunked_prefill(self) -> bool:
        """Incremental chunk compute is a model-structure property — see
        ModelConfig.supports_chunked_prefill."""
        return self.cfg.supports_chunked_prefill

    def prefill_stream(self, req: Request,
                       chunk_tokens: Optional[int] = None,
                       chunked_compute: Optional[bool] = None
                       ) -> PrefillStream:
        """Start a resumable (chunked) prefill for ``req``."""
        return PrefillStream(self, req, chunk_tokens, chunked_compute)

    # ------------------------------------------------------------------ #
    # Prefill (P role)
    # ------------------------------------------------------------------ #
    def prefill(self, req: Request) -> Dict[str, Any]:
        """Run prefill for one request; returns the handoff package:
        {"first_token", "kv": per-group list, "states", "cross", "logits"}.

        The KV part stays in *this* engine's canonical per-layer form — the
        transfer module converts it to the wire and the D instance's format.
        """
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        t0 = time.perf_counter()
        cfg = self.cfg
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        inputs: Dict[str, Any] = {"tokens": tokens}
        if req.frames is not None:
            inputs["frames"] = jnp.asarray(req.frames)[None]
        if req.patches is not None:
            inputs["patches"] = jnp.asarray(req.patches)[None]
        plen = req.prompt_len + (req.patches.shape[0] if req.patches is not None else 0)
        last_logits, caches = self._prefill_fn(self.params, inputs, plen)
        first_token = self._sample(np.asarray(last_logits), req)[0]
        package = self._package_handoff(caches, plen)
        package["first_token"] = int(first_token)
        package["seq_len"] = plen
        self.stats.prefill_tokens += plen
        self.stats.prefill_chunks += 1
        self.stats.prefill_seconds += time.perf_counter() - t0
        return package

    def _package_handoff(self, caches, seq_len: int) -> Dict[str, Any]:
        """Extract per-layer canonical KV (+ states / cross) for transfer."""
        cfg = self.cfg
        groups = M.block_groups(cfg)
        kv, states, cross = [], [], []
        for gi, g in enumerate(groups):
            for pi, kind in enumerate(g.kinds):
                c = caches[gi][pi]
                if kind == "ssd" or kind == "rglru":
                    states.append(("state", gi, pi,
                                   jax.tree.map(lambda x: x[:, 0], c)))
                    continue
                self_c = c["self"] if isinstance(c, dict) else c
                if cfg.attention_kind == "mla":
                    kv.append(("mla", gi, pi, {
                        "ckv": self_c.ckv[:, 0, :seq_len],       # (count,S,lora)
                        "kpe": self_c.kpe[:, 0, :seq_len]}))
                else:
                    cap = self_c.k.shape[2]
                    s = min(seq_len, cap)
                    kv.append(("kv", gi, pi, {
                        # (count, S', kv, hd) — last `cap` tokens for SWA
                        "k": self_c.k[:, 0, :s] if cap >= seq_len else self_c.k[:, 0],
                        "v": self_c.v[:, 0, :s] if cap >= seq_len else self_c.v[:, 0],
                        "pos": self_c.pos[:, 0]}))
                if isinstance(c, dict):                          # enc-dec cross
                    cross.append((gi, pi, {
                        "cross_k": c["cross_k"][:, 0],
                        "cross_v": c["cross_v"][:, 0],
                        "mem_len": c["mem_len"][:, 0]}))
        return {"kv": kv, "states": states, "cross": cross}

    # ------------------------------------------------------------------ #
    # Decode (D role)
    # ------------------------------------------------------------------ #
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def load(self) -> float:
        """Outstanding work (for the global scheduler's load-aware routing)."""
        active = sum(1 for r in self.slot_req if r is not None)
        return active / self.max_batch

    def can_admit(self, seq_len: int, new_tokens: int) -> bool:
        need = -(-(seq_len + new_tokens) // self.block_size)
        free = self.allocator.free_blocks
        if self.prefix_store is not None:
            # zero-ref cached blocks are reclaimable on demand
            free += self.prefix_store.evictable_blocks()
        return (not self.failed and len(self.free_slots()) > 0
                and free >= need
                and seq_len + new_tokens <= self.max_seq_len)

    def _prefix_eligible(self, req: Request) -> bool:
        """Prefix reuse needs resumable (incremental) prefill semantics
        and a pure-token prompt — mirrors PrefillStream's gate."""
        return (self.supports_chunked_prefill
                and req.patches is None and req.frames is None)

    def reserve_sequence(self, req: Request, seq_len: int, *,
                         use_prefix_cache: bool = False
                         ) -> Tuple[int, np.ndarray]:
        """Claim a decode slot + paged blocks for an in-flight handoff.

        The slot is occupied (counts toward load, not free) but NOT decoded
        until ``activate_sequence`` — streamed KV chunks land in between.

        With ``use_prefix_cache`` (and a store), the block table's head
        borrows the store's pages for the longest cached prefix — pinned,
        read-shared — plus an optional copy-on-write divergence block;
        ``slot_prefix_tokens[slot]`` records how many leading tokens need
        no wire transfer. All writes (RMW re-page and decode appends) land
        at positions ≥ that count, i.e. strictly in private blocks."""
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        slot = self.free_slots()[0]
        nblocks = -(-(seq_len + req.max_new_tokens) // self.block_size)
        nblocks = min(nblocks, self.max_blocks_per_seq)
        store = self.prefix_store
        prefix_tokens = 0
        if (use_prefix_cache and store is not None
                and self._prefix_eligible(req)):
            # reuse limit seq_len-1: P always computes ≥ 1 trailing token
            # (it must sample first_token from real logits)
            match = store.match(req.prompt, min(seq_len, req.prompt_len) - 1)
            match = match.truncated(max(nblocks - 1, 0), self.block_size)
            store.acquire(match, req.req_id)
            shared = list(match.block_ids)
            need = nblocks - len(shared)
            short = need - self.allocator.free_blocks
            if short > 0:
                store.evict(short)
            try:
                private = self.allocator.allocate(req.req_id, need)
            except MemoryError:
                store.release_seq(req.req_id)
                raise
            if match.cow_src is not None and need > 0:
                # mid-block divergence: private copy of the source page,
                # valid up to match.tokens — later rows are overwritten
                # by the stream's RMW re-page
                self._copy_block(match.cow_src, private[0])
            prefix_tokens = match.tokens
            block_ids = shared + private
        else:
            short = nblocks - self.allocator.free_blocks
            if store is not None and short > 0:
                store.evict(short)
            block_ids = self.allocator.allocate(req.req_id, nblocks)
        self.block_tables[slot, :] = self._scratch_block
        self.block_tables[slot, :nblocks] = block_ids
        self.seq_lens[slot] = 0
        self.slot_req[slot] = req
        self.slot_ready[slot] = False
        self.slot_prefix_tokens[slot] = prefix_tokens
        return slot, np.asarray(block_ids, np.int32)

    def _copy_block(self, src: int, dst: int) -> None:
        """Copy one physical page across every paged pool (COW)."""
        caches = [list(g) for g in self.caches]
        for gi, g in enumerate(caches):
            for pi, c in enumerate(g):
                if not isinstance(c, dict):
                    continue
                new = dict(c)
                changed = False
                for name, arr in c.items():
                    if name.endswith("_pool"):
                        # pools stack layers on axis 0: (count, blocks, ...)
                        new[name] = arr.at[:, dst].set(arr[:, src])
                        changed = True
                if changed:
                    g[pi] = new
        self.caches = tuple(tuple(g) for g in caches)

    def activate_sequence(self, slot: int, first_token: int,
                          seq_len: int) -> None:
        """All KV landed — the slot joins continuous batching next step.

        With a prefix store, the sequence's full prompt blocks are adopted
        into it here (ownership transfer, still pinned for this sequence):
        every block is fully written by now, and decode appends only at
        positions ≥ seq_len, which live past the last full prompt block."""
        self.seq_lens[slot] = seq_len
        self.last_token[slot] = first_token
        self.slot_ready[slot] = True
        req = self.slot_req[slot]
        if (self.prefix_store is not None and req is not None
                and self._prefix_eligible(req)):
            self._adopt_prompt_blocks(req, min(seq_len, req.prompt_len), slot)

    def _adopt_prompt_blocks(self, req: Request, prompt_len: int,
                             slot: int) -> None:
        store = self.prefix_store
        prompt = np.asarray(req.prompt)
        bs = self.block_size
        parent = hashing.ROOT
        for b in range(min(prompt_len, len(prompt)) // bs):
            blk = prompt[b * bs:(b + 1) * bs]
            digest = hashing.block_hash(parent, blk)
            # blocks borrowed from the store at reservation re-hash to a
            # cached digest → insert() is a refresh no-op; only this
            # sequence's own (private) blocks transfer ownership
            store.insert(req.req_id, digest, parent, blk,
                         int(self.block_tables[slot, b]))
            parent = digest

    def abort_reservation(self, slot: int) -> None:
        """Handoff failed mid-stream: free the slot and its blocks."""
        if self.failed:
            # node is down: recover() rebuilds the allocator and pools, but
            # the slot must drop its request NOW so the failure sweep does
            # not requeue it a second time (two parallel lives)
            self.slot_req[slot] = None
            self.slot_ready[slot] = False
            self.slot_prefix_tokens[slot] = 0
            return
        self.release(slot)

    def add_sequence(self, req: Request, package: Dict[str, Any],
                     materialize_fn) -> int:
        """Admit a fully-transferred request into a decode slot.

        ``materialize_fn(engine, slot, block_ids, package)`` is provided by
        the disagg orchestrator (it owns the compat conversion)."""
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        seq_len = package["seq_len"]
        slot, block_ids = self.reserve_sequence(req, seq_len)
        materialize_fn(self, slot, block_ids, package)
        self.activate_sequence(slot, package["first_token"], seq_len)
        return slot

    def release(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is not None:
            if self.prefix_store is not None:
                # unpin borrowed/adopted prefix blocks (they stay cached
                # at zero refs until LRU eviction), then free whatever
                # this sequence still owns privately
                self.prefix_store.release_seq(req.req_id)
            self.allocator.free(req.req_id)
        self.slot_req[slot] = None
        self.slot_ready[slot] = False
        self.seq_lens[slot] = 0
        self.slot_prefix_tokens[slot] = 0
        self.block_tables[slot, :] = self._scratch_block

    def decode_step(self) -> List[Tuple[int, Request, int]]:
        """One continuous-batching step. Returns [(slot, request, token)]."""
        if self.failed:
            raise RuntimeError(f"instance {self.name} is down")
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and self.slot_ready[i]]
        if not active:
            return []
        t0 = time.perf_counter()
        write_slots = self.seq_lens % self.block_size
        write_block_idx = self.seq_lens // self.block_size
        write_blocks = self.block_tables[np.arange(self.max_batch),
                                         np.minimum(write_block_idx,
                                                    self.max_blocks_per_seq - 1)]
        idle = np.asarray([r is None or not self.slot_ready[i]
                           for i, r in enumerate(self.slot_req)])
        write_blocks = np.where(idle, self._scratch_block, write_blocks)
        logits, self.caches = self._decode_fn(
            self.params, jnp.asarray(self.last_token[:, None]),
            jnp.asarray(self.seq_lens), jnp.asarray(self.block_tables),
            jnp.asarray(write_blocks.astype(np.int32)),
            jnp.asarray(write_slots.astype(np.int32)), self.caches)
        logits = np.asarray(logits[:, 0])
        out = []
        for slot in active:
            req = self.slot_req[slot]
            tok = self._sample(logits[slot:slot + 1], req)[0]
            self.seq_lens[slot] += 1
            self.last_token[slot] = tok
            out.append((slot, req, int(tok)))
        self.stats.decode_steps += 1
        self.stats.decode_tokens += len(active)
        self.stats.decode_seconds += time.perf_counter() - t0
        return out

    # ------------------------------------------------------------------ #
    def _sample(self, logits: np.ndarray, req: Request) -> np.ndarray:
        if req.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits.astype(np.float64) / req.temperature
        z -= z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.asarray([self._rng.choice(p.shape[-1], p=p[i])
                           for i in range(p.shape[0])], np.int32)

    # -- fault injection ------------------------------------------------ #
    def fail(self) -> None:
        self.failed = True
        self.stats.failures_injected += 1

    def recover(self) -> None:
        """Restart: all volatile KV state is lost (as on a real node)."""
        self.failed = False
        for slot in range(self.max_batch):
            self.release(slot)
        self.allocator = BlockAllocator(self.allocator.num_blocks)
        self.allocator.allocate("__scratch__", 1)
        self._scratch_block = self.allocator.blocks_of("__scratch__")[0]
        if self.prefix_store is not None:
            # the pages the store indexed died with the pool
            self.prefix_store = PrefixStore(self.allocator, self.block_size)
        self.slot_prefix_tokens = [0] * self.max_batch
