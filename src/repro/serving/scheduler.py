"""Global scheduler (paper Fig. 1-2): request routing across P and D pools.

Responsibilities beyond the paper's workflow (required for 1000-node scale):
  * load-aware routing (least outstanding work, straggler-penalized)
  * fault tolerance: failed P → re-dispatch prefill; failed D → KV is lost,
    re-prefill with the already-generated prefix appended (the standard
    recovery in disaggregated serving)
  * straggler mitigation: per-instance decode-latency EMA feeds a routing
    penalty; stuck requests are re-dispatched after ``straggler_factor``×
    the pool-median step time
  * elastic scaling: instances join/leave at runtime (leave = drain first)

Structure: the work is split into two *event loops* — the P-side
:class:`PrefillFlightLoop` (dispatch requests, pump each flight's chunk
stream) and the D-side :class:`DecodeLoop` (re-page landed chunks is part
of flight pumping; decode-step every D engine). In single-process serving
``GlobalScheduler.step()`` pumps both loops in turn; the two-process
runtime (``repro.serving.multiproc``) runs the same two loops as real OS
processes, with the control plane over queues instead of direct calls.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import Engine, PrefillMode
from repro.serving.request import Request, State

if TYPE_CHECKING:                      # avoid core <-> serving import cycle
    from repro.core.disagg import DisaggPipeline


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    finished: int = 0
    failed: int = 0
    requeues: int = 0
    # requests rejected by admission control before entering the runtime
    # (open-loop overload shedding — never counts a request mid-stream)
    shed: int = 0
    chunks_streamed: int = 0
    p_dispatches: Dict[str, int] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(int))
    d_dispatches: Dict[str, int] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(int))


# both runtimes (in-process GlobalScheduler, multi-process ClusterRuntime)
# account into the same stats block; the cluster-facing name
RuntimeStats = SchedulerStats


# failures that void a dispatch/flight and requeue the request: a dead
# engine (RuntimeError) or pinned-pool exhaustion (MemoryError from stage).
# Requeues are capped by max_retries so a permanent failure surfaces as a
# FAILED request instead of an infinite dispatch loop.
_DISPATCH_ERRORS = (RuntimeError, MemoryError)


def requeue_for_retry(req: Request, stats: SchedulerStats,
                      transfer_stats, max_retries: int) -> bool:
    """Shared failure/straggler recovery semantics (single-process
    GlobalScheduler AND the two-process launcher — both runtimes must
    requeue identically or the parity gate breaks): re-prefill with the
    generated prefix appended to the prompt. ``output_tokens`` keeps the
    already-streamed tokens (and ``max_new_tokens`` stays put, so ``done``
    still fires at the original budget); the re-prefill's first token is
    the continuation after the prefix. Returns True if the request should
    rejoin the queue, False once it is FAILED past ``max_retries``."""
    if req.retries >= max_retries:
        req.state = State.FAILED
        stats.failed += 1
        return False
    if req.output_tokens:
        req.prompt = np.concatenate(
            [req.prompt, np.asarray(req.output_tokens, req.prompt.dtype)])
    req.retries += 1
    req.state = State.QUEUED
    stats.requeues += 1
    # failure accounting is wire-visible: a requeue retries the transfer
    transfer_stats.retries += 1
    return True


@dataclasses.dataclass
class _Flight:
    """One in-flight chunked prefill+handoff: occupies a P instance and a
    reserved D slot across scheduler ticks."""
    req: Request
    p: Engine
    d: Engine
    stream: Any                     # serving.engine.PrefillStream
    handoff: Any                    # core.disagg.StreamedHandoff


class PrefillFlightLoop:
    """P-side event loop: dispatch pending requests into prefill flights,
    then pump every flight — re-page chunks whose wire reads completed,
    stream new chunks onto the wire, finalize exhausted streams.

    One ``pump()`` call is one tick of P-side progress. The two-process
    runtime's P worker runs the same dispatch→chunk→stage protocol as its
    process main loop (``repro.serving.multiproc.p_worker``)."""

    def __init__(self, sched: "GlobalScheduler"):
        self.sched = sched
        self.inflight: List[_Flight] = []
        # engines that ran prefill compute this tick — integrated
        # (role="both") engines in this set defer their decode step
        # (prefill-priority interleaving; the stall is measured in
        # EngineStats.contention_stall_seconds)
        self.prefilled: set = set()

    def pump(self, emitted: List[Tuple[Request, int]]) -> None:
        self.prefilled.clear()
        self._dispatch(emitted)
        self._advance_all(emitted)

    # -- dispatch --------------------------------------------------------- #
    def _dispatch(self, emitted: List[Tuple[Request, int]]) -> None:
        """Start a prefill flight on a free P with a reserved slot on a D.
        Monolithic mode (prefill_chunk None) drives the flight to completion
        inside this tick; chunked mode leaves it in flight so the tick stays
        short."""
        s = self.sched
        busy_p = {fl.p.name for fl in self.inflight}
        still_pending: collections.deque = collections.deque()
        while s.pending:
            req = s.pending.popleft()
            p_eng = s._pick_p(busy_p)
            patches = req.patches.shape[0] if req.patches is not None else 0
            d_eng = s._pick_d(req, req.prompt_len + patches)
            if p_eng is None or d_eng is None:
                still_pending.append(req)
                continue
            req.state = State.PREFILLING
            req.prefill_instance = p_eng.name
            req.decode_instance = d_eng.name
            if s.prefill_chunk is None:
                # monolithic: whole prefill + single-payload handoff in-tick
                try:
                    meta = s.pipeline.handoff(req, p_eng, d_eng)
                except _DISPATCH_ERRORS:
                    s._requeue(req, p_eng)
                    continue
                self.prefilled.add(p_eng.name)
                s._emit_first_token(req, p_eng, d_eng,
                                    meta["first_token"], emitted)
                continue
            # a mid-stream snapshot from an aborted flight resumes only on
            # the same P (its params produced the snapshot's states/KV)
            snap = s._resume_snaps.pop(req.req_id, None)
            if snap is not None and snap.get("p_name") != p_eng.name:
                snap = None
            try:
                stream = p_eng.prefill_stream(req, s.prefill_chunk,
                                              mode=s.prefill_mode,
                                              resume=snap)
                handoff = s.pipeline.begin_handoff(
                    req, p_eng, d_eng, stream.seq_len,
                    compute_overlapped=stream.chunked_compute)
            except _DISPATCH_ERRORS:
                s._requeue(req, p_eng)
                continue
            self.inflight.append(_Flight(req, p_eng, d_eng, stream, handoff))
            busy_p.add(p_eng.name)
        s.pending = still_pending

    # -- flight pumping --------------------------------------------------- #
    def _advance_all(self, emitted: List[Tuple[Request, int]]) -> None:
        """Advance in-flight chunked prefills by the per-tick budget; each
        chunk's wire transfer overlaps the next chunk's compute."""
        s = self.sched
        for fl in list(self.inflight):
            try:
                tok = self._advance(fl, s.chunk_budget)
            except _DISPATCH_ERRORS:
                s._abort_flight(fl)
                continue
            if tok is not None:
                self.inflight.remove(fl)
                s._emit_first_token(fl.req, fl.p, fl.d, tok, emitted)

    def _advance(self, fl: _Flight, budget: Optional[int]) -> Optional[int]:
        """One tick of flight progress: re-page chunks whose wire reads
        completed (``repage_budget``), then stream up to ``budget`` new
        chunks (None = to completion) while the connector channel has room.
        The flight finalizes only when the prefill stream is exhausted AND
        every issued read has been re-paged — with a modeled-latency
        connector the tail chunks complete in later ticks, and decode steps
        run in between. Returns the first token on finalize, else None."""
        s = self.sched
        repaged = fl.handoff.poll_reads(s.repage_budget)
        sent = 0
        while (budget is None or sent < budget) and fl.handoff.can_send():
            chunk = fl.stream.next_chunk()
            if chunk is None:
                break
            if chunk.get("compute_seconds", 0.0) > 0.0:
                self.prefilled.add(fl.p.name)
            if not chunk["kv"] and chunk["length"] == 0:
                sent += 1        # compute-only progress marker: consumes
                continue         # the tick budget, never hits the wire
            fl.handoff.send_chunk(chunk)
            fl.req.chunks_streamed += 1
            s.stats.chunks_streamed += 1
            sent += 1
        # instant backends complete at issue time — spend what is left of
        # the re-page budget on the chunks just sent
        if s.repage_budget is None:
            fl.handoff.poll_reads(None)
        elif repaged < s.repage_budget:
            fl.handoff.poll_reads(s.repage_budget - repaged)
        if not fl.stream.done or fl.handoff.pending_reads():
            return None
        meta = fl.handoff.finalize(fl.stream.first_token,
                                   fl.stream.tail_package())
        return meta["first_token"]


class DecodeLoop:
    """D-side event loop: one continuous-batching decode step on every
    routable D engine per ``pump()``, with the per-instance latency EMA
    that feeds straggler-penalized routing. The two-process runtime's D
    worker runs the same re-page→decode protocol as its process main loop
    (``repro.serving.multiproc.d_worker``)."""

    def __init__(self, sched: "GlobalScheduler"):
        self.sched = sched
        self.ema: Dict[str, float] = {}        # decode step latency EMA

    def pump(self, emitted: List[Tuple[Request, int]]) -> None:
        s = self.sched
        prefilled = s.prefill_loop.prefilled
        for e in s._routable(s.d_pool) + \
                [s.d_pool[n] for n in list(s._draining)
                 if n in s.d_pool and not s.d_pool[n].failed]:
            # prefill-priority interleaving: an integrated engine that
            # spent this tick on prefill compute defers its decode step —
            # the paper's P/D interference, measured (not modeled) in
            # EngineStats.contention_stall_seconds
            if e.role == "both" and e.name in prefilled:
                continue
            # reserved-but-not-ready flight slots don't decode — timing a
            # no-op step would pollute the straggler-latency EMA
            active = any(r is not None and e.slot_ready[i]
                         for i, r in enumerate(e.slot_req))
            if not active:
                continue
            t0 = time.perf_counter()
            try:
                results = e.decode_step()
            except RuntimeError:
                continue            # picked up by _handle_failures next tick
            dt = time.perf_counter() - t0
            prev = self.ema.get(e.name, dt)
            self.ema[e.name] = 0.8 * prev + 0.2 * dt
            for slot, req, tok in results:
                req.output_tokens.append(tok)
                emitted.append((req, tok))
                if req.done:
                    s._finish(req, e, slot)


class GlobalScheduler:
    def __init__(self, pipeline: "DisaggPipeline",
                 clock: Callable[[], float] = time.monotonic,
                 straggler_factor: float = 8.0,
                 prefill_chunk: Optional[int] = None,
                 chunk_budget: int = 1,
                 repage_budget: Optional[int] = None,
                 max_retries: int = 8,
                 prefill_mode: PrefillMode = PrefillMode.AUTO):
        """``prefill_chunk``: tokens per streamed prefill chunk. ``None``
        keeps the monolithic single-tick handoff; set it to stream long
        prefills across ticks (``chunk_budget`` chunks per flight per tick)
        so decode steps interleave with a long prompt's prefill.

        ``prefill_mode``: explicit compute mode for streamed prefills —
        AUTO picks incremental when the family supports it and the chunk
        subdivides the prompt; INCREMENTAL/MONOLITHIC force it (an
        unsupported combination raises ``PrefillModeError`` at dispatch).

        ``repage_budget``: D-side re-pages per flight per tick — a budget
        *separate* from ``chunk_budget``, so wire time (chunks in flight on
        the connector) and D-side re-page pipeline independently. ``None``
        re-pages every chunk whose read handle reports complete.

        ``max_retries``: dispatch/flight failures requeue the request up to
        this many times, then mark it FAILED (permanent failures must not
        spin the dispatch loop forever)."""
        self.pipeline = pipeline
        self.clock = clock
        self.straggler_factor = straggler_factor
        self.max_retries = max_retries
        # 0/negative = monolithic, same as None
        self.prefill_chunk = prefill_chunk \
            if prefill_chunk is not None and prefill_chunk > 0 else None
        self.prefill_mode = prefill_mode
        # mid-stream snapshots of aborted flights, keyed by req_id —
        # state-carrying families resume instead of recomputing
        self._resume_snaps: Dict[str, Dict] = {}
        self.chunk_budget = max(chunk_budget, 1)
        self.repage_budget = repage_budget \
            if repage_budget is None else max(repage_budget, 1)
        self.p_pool: Dict[str, Engine] = {}
        self.d_pool: Dict[str, Engine] = {}
        self.pending: collections.deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self.stats = SchedulerStats()
        self.prefill_loop = PrefillFlightLoop(self)
        self.decode_loop = DecodeLoop(self)
        self._draining: set = set()

    # back-compat views onto the event loops' state
    @property
    def inflight(self) -> List[_Flight]:
        return self.prefill_loop.inflight

    @property
    def _ema(self) -> Dict[str, float]:
        return self.decode_loop.ema

    # -- elastic pool management ----------------------------------------- #
    def add_instance(self, engine: Engine, role: Optional[str] = None) -> None:
        role = role or engine.role
        if role in ("prefill", "both"):
            self.p_pool[engine.name] = engine
        if role in ("decode", "both"):
            self.d_pool[engine.name] = engine

    def remove_instance(self, name: str) -> None:
        """Elastic scale-down: stop routing to it; it drains naturally."""
        self._draining.add(name)

    def _routable(self, pool: Dict[str, Engine]) -> List[Engine]:
        return [e for n, e in pool.items()
                if not e.failed and n not in self._draining]

    # -- routing ----------------------------------------------------------- #
    def _penalty(self, e: Engine) -> float:
        base = self._ema.get(e.name, 0.0)
        emas = [v for v in self._ema.values() if v > 0]
        med = float(np.median(emas)) if emas else 0.0
        straggler = base / med if med > 0 else 1.0
        return e.load() + max(straggler - 1.0, 0.0)

    def _pick_p(self, busy: Optional[set] = None) -> Optional[Engine]:
        cands = [e for e in self._routable(self.p_pool)
                 if not busy or e.name not in busy]
        return min(cands, key=self._penalty) if cands else None

    def _pick_d(self, req: Request, seq_len: int) -> Optional[Engine]:
        cands = [e for e in self._routable(self.d_pool)
                 if e.can_admit(seq_len, req.max_new_tokens)]

        def key(e: Engine):
            # prefix affinity first: the D already holding the longest
            # cached prefix of this prompt saves wire bytes and decode
            # pool pages — load/straggler penalty breaks ties (and wins
            # outright when no D holds anything: affinity 0 everywhere
            # keeps the legacy ordering)
            hit = 0
            if e.prefix_store is not None and e._prefix_eligible(req):
                hit = e.prefix_store.match_tokens(
                    req.prompt, min(seq_len, req.prompt_len) - 1)
            return (-hit, self._penalty(e))

        return min(cands, key=key) if cands else None

    # -- lifecycle ---------------------------------------------------------- #
    def submit(self, req: Request) -> None:
        # `is None`, not falsy: an explicit 0.0 arrival (virtual-clock or
        # epoch-relative schedule) is a legitimate timestamp to keep
        if req.arrival_time is None:
            req.arrival_time = self.clock()
        self.pending.append(req)
        self.stats.submitted += 1

    def _requeue(self, req: Request, engine: Engine) -> None:
        if requeue_for_retry(req, self.stats, self.pipeline.transfer.stats,
                             self.max_retries):
            self.pending.appendleft(req)

    def _handle_failures(self) -> None:
        # flights first: a failed P or D voids the stream — drop the D
        # reservation and requeue from scratch
        for fl in list(self.inflight):
            if fl.p.failed or fl.d.failed:
                self._abort_flight(fl)
        inflight_reqs = {id(fl.req) for fl in self.inflight}
        for e in list(self.d_pool.values()):
            if e.failed:
                for slot, req in enumerate(e.slot_req):
                    if req is not None and id(req) not in inflight_reqs:
                        e.slot_req[slot] = None      # KV is gone with the node
                        self._requeue(req, e)
                e.recover()

    def _abort_flight(self, fl: _Flight) -> None:
        if not fl.p.failed:
            # a healthy P aborting (D died, wire failed) keeps its chunk
            # progress: resumable families snapshot states + window KV so
            # the retry skips the already-computed prefix
            snap = fl.stream.snapshot()
            if snap is not None:
                snap["p_name"] = fl.p.name
                self._resume_snaps[fl.req.req_id] = snap
        fl.handoff.abort()
        self.prefill_loop.inflight.remove(fl)
        self._requeue(fl.req, fl.p)

    def _emit_first_token(self, req: Request, p_eng: Engine, d_eng: Engine,
                          first_token: int,
                          emitted: List[Tuple[Request, int]]) -> None:
        """Handoff succeeded: the prefill's token starts the stream."""
        self.stats.p_dispatches[p_eng.name] += 1
        self.stats.d_dispatches[d_eng.name] += 1
        req.state = State.DECODING
        req.output_tokens.append(first_token)
        if req.first_token_time is None:
            req.first_token_time = self.clock()
        emitted.append((req, first_token))
        req.decode_steps_at_dispatch = 0
        if req.done:
            self._finish(req, d_eng)

    def step(self) -> List[Tuple[Request, int]]:
        """One scheduler tick: pump the P-side flight loop, then the D-side
        decode loop. Returns emitted (request, token) pairs."""
        self._handle_failures()
        # advance the wire: async connectors progress in-flight reads here
        self.pipeline.transfer.tick()
        emitted: List[Tuple[Request, int]] = []
        self.prefill_loop.pump(emitted)
        self.decode_loop.pump(emitted)
        return emitted

    def _finish(self, req: Request, engine: Engine,
                slot: Optional[int] = None) -> None:
        if slot is None:
            try:
                slot = engine.slot_req.index(req)
            except ValueError:
                slot = None
        if slot is not None:
            engine.release(slot)
        req.state = State.FINISHED
        req.finish_time = self.clock()
        self.finished.append(req)
        self.stats.finished += 1

    def run(self, requests: List[Request], max_ticks: int = 10_000
            ) -> List[Request]:
        """Drive to completion (synchronous loop). Terminates when every
        request reached a terminal state (FINISHED or FAILED)."""
        for r in requests:
            self.submit(r)
        for _ in range(max_ticks):
            if self.stats.finished + self.stats.failed >= len(requests):
                break
            self.step()
        return self.finished
