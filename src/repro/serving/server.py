"""Server front-end (paper Fig. 1 'server' module).

HTTP is out of scope for this container; `Server` is the request-queue +
completion-callback layer the global scheduler sits behind. `ServeResult`
aggregates the SLO metrics the paper reports (TTFT / TPOT / throughput).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler


@dataclasses.dataclass
class ServeResult:
    requests: List[Request]
    wall_seconds: float

    def ttft(self) -> np.ndarray:
        return np.asarray([r.ttft() for r in self.requests
                           if r.ttft() is not None])

    def tpot(self) -> np.ndarray:
        return np.asarray([r.tpot() for r in self.requests
                           if r.tpot() is not None])

    def throughput_tok_s(self) -> float:
        tokens = sum(len(r.output_tokens) for r in self.requests)
        return tokens / max(self.wall_seconds, 1e-9)

    def summary(self) -> Dict[str, float]:
        ttft, tpot = self.ttft(), self.tpot()
        return {
            "requests": len(self.requests),
            "ttft_mean_s": float(ttft.mean()) if ttft.size else float("nan"),
            "ttft_p99_s": float(np.percentile(ttft, 99)) if ttft.size else float("nan"),
            "tpot_mean_s": float(tpot.mean()) if tpot.size else float("nan"),
            "throughput_tok_s": self.throughput_tok_s(),
        }


class Server:
    def __init__(self, scheduler: GlobalScheduler):
        self.scheduler = scheduler
        self._streams: Dict[str, List[int]] = {}
        self._callbacks: Dict[str, Callable[[Request, int], None]] = {}

    def submit(self, req: Request,
               on_token: Optional[Callable[[Request, int], None]] = None) -> None:
        self._streams[req.req_id] = []
        if on_token:
            self._callbacks[req.req_id] = on_token
        self.scheduler.submit(req)

    def serve(self, requests: List[Request], max_ticks: int = 10_000
              ) -> ServeResult:
        t0 = time.perf_counter()
        for r in requests:
            self.submit(r)
        done_target = len(requests)
        for _ in range(max_ticks):
            stats = self.scheduler.stats
            if stats.finished + stats.failed >= done_target:
                break
            for req, tok in self.scheduler.step():
                self._streams.setdefault(req.req_id, []).append(tok)
                cb = self._callbacks.get(req.req_id)
                if cb:
                    cb(req, tok)
        return ServeResult(requests=list(requests),
                           wall_seconds=time.perf_counter() - t0)

    def stream(self, req_id: str) -> List[int]:
        return list(self._streams.get(req_id, []))
