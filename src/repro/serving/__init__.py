from repro.serving.engine import Engine, VendorProfile, page_specs_for  # noqa: F401
from repro.serving.paged_cache import BlockAllocator, KVPageSpec        # noqa: F401
from repro.serving.request import Request, State                        # noqa: F401
from repro.serving.scheduler import GlobalScheduler                     # noqa: F401
from repro.serving.server import Server, ServeResult                    # noqa: F401
