"""Chained block hashes over token prefixes.

One digest per *full* ``block_size`` tokens, each chained on its
parent's digest — so two prompts share the k-th digest iff they share
the entire first ``k * block_size`` tokens. That makes a flat digest
set a complete prefix summary: routers compare a prompt's chain against
a D instance's advertised set and the number of leading digests present
*is* the longest cached prefix (in full blocks).

Digests use hashlib (not Python's salted ``hash()``) so they are stable
across spawned worker processes — the multiproc heartbeat plane ships
them between processes.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Sequence, Union

import numpy as np

Tokens = Union[Sequence[int], np.ndarray]

ROOT = ""  # parent digest of the first block

_DIGEST_HEX = 24  # 96 bits — collision-safe at any plausible store size


def block_hash(parent: str, tokens: Tokens) -> str:
    """Digest of one block of tokens chained on its parent digest."""
    h = hashlib.sha256()
    h.update(parent.encode("ascii"))
    h.update(np.ascontiguousarray(tokens, dtype=np.int64).tobytes())
    return h.hexdigest()[:_DIGEST_HEX]


def chain_hashes(tokens: Tokens, block_size: int,
                 limit: Optional[int] = None) -> List[str]:
    """Chained digests for every full ``block_size`` block of
    ``tokens[:limit]`` (a trailing partial block contributes nothing)."""
    toks = np.asarray(tokens)
    n = len(toks) if limit is None else max(min(int(limit), len(toks)), 0)
    out: List[str] = []
    parent = ROOT
    for b in range(n // block_size):
        parent = block_hash(parent, toks[b * block_size:(b + 1) * block_size])
        out.append(parent)
    return out


def matched_prefix_tokens(chain: Sequence[str], cached: "frozenset[str] | set",
                          block_size: int) -> int:
    """Tokens covered by the longest leading run of ``chain`` present in
    ``cached`` — the router-side affinity score."""
    n = 0
    for digest in chain:
        if digest not in cached:
            break
        n += 1
    return n * block_size
