"""Shared-prefix KV stores.

Two stores, one per role:

* :class:`PrefixStore` (D-side) indexes *physical pages* of a decode
  engine's paged KV pool by chained block hash. Blocks are adopted from
  a sequence at activation (ownership moves to ``__prefix_cache__`` in
  the :class:`~repro.serving.paged_cache.BlockAllocator`), pinned via
  refcounts while any sequence's block table points at them, and
  LRU-evicted back to the allocator's free list only at zero refs. A
  lookup returns the longest cached prefix as a chain of full blocks
  plus an optional mid-block copy-on-write extension (the sequence gets
  a private copy of the divergence block, valid up to the split point).
  A reservation that reuses N prefix tokens needs N fewer tokens over
  the connector wire — the handoff skips those chunks entirely.

* :class:`HostPrefixStore` (P-side) is a byte-capacity LRU of
  *host-side wire entries* — the exact per-block canonical KV a
  completed ``PrefillStream`` produced. A later prompt sharing the
  prefix replays those entries instead of recomputing them, and
  preloads the dense chunked-prefill cache so compute resumes at the
  divergence point. This is also what makes requeue-after-crash cheap:
  the retry prompt extends the original prompt, so its prefill resumes
  from the cached prefix instead of recomputing everything.

Both stores key blocks with :mod:`repro.serving.prefix_cache.hashing`
chained digests, so a digest matches iff the *entire* prefix up to and
including that block matches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.serving.prefix_cache import hashing

STORE_OWNER = "__prefix_cache__"


def _common_prefix_len(a: np.ndarray, b: np.ndarray) -> int:
    n = min(len(a), len(b))
    if n == 0:
        return 0
    eq = np.asarray(a[:n]) == np.asarray(b[:n])
    return n if eq.all() else int(np.argmax(~eq))


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Longest cached prefix for one prompt.

    ``block_ids[i]`` holds the KV for prompt block ``i`` (digest
    ``hashes[i]``). ``tokens`` includes the copy-on-write extension:
    ``cow_src`` (when set) is a physical page whose first ``cow_len``
    rows match the prompt past the last full matched block — the caller
    copies it into the sequence's first private block.
    """
    hashes: Tuple[str, ...]
    block_ids: Tuple[int, ...]
    tokens: int
    cow_src: Optional[int] = None
    cow_len: int = 0

    def truncated(self, max_blocks: int, block_size: int) -> "PrefixMatch":
        """Drop blocks (and any COW extension) beyond ``max_blocks`` —
        used when a reservation's table is shorter than the match."""
        if len(self.block_ids) <= max_blocks:
            return self
        return PrefixMatch(self.hashes[:max_blocks],
                           self.block_ids[:max_blocks],
                           tokens=max_blocks * block_size)


@dataclasses.dataclass
class _CachedBlock:
    digest: str
    parent: str
    block_id: int
    tokens: np.ndarray  # the block_size tokens this page's KV covers
    refs: int = 0
    tick: int = 0


class PrefixStore:
    """Ref-counted, LRU-evicted index of cached prefix blocks in a
    decode engine's paged KV pool."""

    def __init__(self, allocator, block_size: int):
        self.allocator = allocator
        self.block_size = int(block_size)
        self._blocks: Dict[str, _CachedBlock] = {}
        self._children: Dict[str, Set[str]] = {}
        self._pins: Dict[str, List[str]] = {}  # seq_id -> acquired digests
        self._clock = 0
        # accounting (read by workers/reports)
        self.lookups = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0

    def __len__(self) -> int:
        return len(self._blocks)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- lookup ---------------------------------------------------------

    def match(self, prompt, limit: int, count: bool = True) -> PrefixMatch:
        """Longest cached prefix of ``prompt[:limit]``: walk the digest
        chain over full blocks, then probe the children of the last
        matched block for a mid-block (copy-on-write) extension."""
        toks = np.asarray(prompt)
        limit = max(min(int(limit), len(toks)), 0)
        bs = self.block_size
        hashes: List[str] = []
        bids: List[int] = []
        parent = hashing.ROOT
        b = 0
        while (b + 1) * bs <= limit:
            digest = hashing.block_hash(parent, toks[b * bs:(b + 1) * bs])
            rec = self._blocks.get(digest)
            if rec is None:
                break
            hashes.append(digest)
            bids.append(rec.block_id)
            parent = digest
            b += 1
        cow_src: Optional[int] = None
        cow_len = 0
        rest = toks[b * bs:limit]
        if len(rest):
            for child in self._children.get(parent, ()):
                rec = self._blocks.get(child)
                if rec is None:
                    continue
                common = _common_prefix_len(rec.tokens, rest)
                if common > cow_len:
                    cow_len, cow_src = common, rec.block_id
        if count:
            self.lookups += 1
            self.hit_tokens += b * bs + cow_len
        return PrefixMatch(tuple(hashes), tuple(bids),
                           tokens=b * bs + cow_len,
                           cow_src=cow_src, cow_len=cow_len)

    def match_tokens(self, prompt, limit: int) -> int:
        """Peek the reusable-token count without pinning (router/affinity
        scoring — no LRU or accounting side effects)."""
        return self.match(prompt, limit, count=False).tokens

    def summary(self) -> Tuple[str, ...]:
        """All cached digests — the compact prefix summary shipped in
        heartbeats. Chained digests make membership sufficient: a
        prompt's leading chain ∩ summary *is* its cached prefix."""
        return tuple(self._blocks.keys())

    # -- pinning --------------------------------------------------------

    def acquire(self, match: PrefixMatch, seq_id: str) -> None:
        """Pin every matched block for ``seq_id`` (decode reads them
        until :meth:`release_seq`)."""
        tick = self._tick()
        for digest in match.hashes:
            rec = self._blocks[digest]
            rec.refs += 1
            rec.tick = tick
        self._pins.setdefault(seq_id, []).extend(match.hashes)

    def release_seq(self, seq_id: str) -> None:
        for digest in self._pins.pop(seq_id, []):
            rec = self._blocks.get(digest)
            if rec is not None:
                rec.refs = max(rec.refs - 1, 0)

    # -- insertion ------------------------------------------------------

    def insert(self, seq_id: str, digest: str, parent: str, tokens,
               block_id: int) -> bool:
        """Adopt one full prompt block from ``seq_id`` into the store
        (ownership moves to the store; the block stays pinned for
        ``seq_id`` until it releases). No-op when the digest is already
        cached — the sequence keeps its private copy."""
        rec = self._blocks.get(digest)
        if rec is not None:
            rec.tick = self._tick()
            return False
        self.allocator.transfer_block(seq_id, STORE_OWNER, block_id)
        rec = _CachedBlock(digest, parent, block_id,
                           np.array(tokens, copy=True),
                           refs=1, tick=self._tick())
        self._blocks[digest] = rec
        self._children.setdefault(parent, set()).add(digest)
        self._pins.setdefault(seq_id, []).append(digest)
        self.inserted_blocks += 1
        return True

    # -- eviction -------------------------------------------------------

    def evictable_blocks(self) -> int:
        return sum(1 for r in self._blocks.values() if r.refs == 0)

    def evict(self, n: int) -> int:
        """Free up to ``n`` zero-ref blocks back to the allocator, least
        recently used first. Pinned blocks are never freed."""
        cands = sorted((r for r in self._blocks.values() if r.refs == 0),
                       key=lambda r: r.tick)
        freed = 0
        for rec in cands[:n]:
            self._remove(rec)
            freed += 1
        self.evicted_blocks += freed
        return freed

    def _remove(self, rec: _CachedBlock) -> None:
        del self._blocks[rec.digest]
        kids = self._children.get(rec.parent)
        if kids is not None:
            kids.discard(rec.digest)
            if not kids:
                self._children.pop(rec.parent, None)
        # orphaned descendants keep their pages but can no longer be
        # matched (the chain walk starts at the root) — they drain out
        # of the LRU at zero refs like any other block
        self.allocator.free_block(STORE_OWNER, rec.block_id)

    def reset(self) -> None:
        """Forget everything (engine recovery rebuilds the allocator,
        so the pages this store indexed no longer exist)."""
        self._blocks.clear()
        self._children.clear()
        self._pins.clear()


# -- P-side host store ---------------------------------------------------

Entry = Tuple[str, int, int, Dict[str, Any]]  # (kind, gi, pi, arrays)


def _entry_nbytes(entries: Sequence[Entry]) -> int:
    total = 0
    for _, _, _, ent in entries:
        for name, arr in ent.items():
            if name != "start":
                total += int(np.asarray(arr).nbytes)
    return total


def assemble_entries(entries: Sequence[Entry], w0: int, w1: int
                     ) -> Optional[List[Entry]]:
    """Merge (possibly chunk-fragmented) wire entries into one entry per
    (kind, gi, pi) covering exactly ``[w0, w1)``. Returns None when the
    window is not fully covered."""
    groups: Dict[Tuple[str, int, int], List[Dict[str, Any]]] = {}
    for kind, gi, pi, ent in entries:
        start = int(ent["start"])
        names = [n for n in ent if n != "start"]
        length = int(np.asarray(ent[names[0]]).shape[1])
        lo, hi = max(w0, start), min(w1, start + length)
        if lo >= hi:
            continue
        piece = {n: np.asarray(ent[n])[:, lo - start:hi - start]
                 for n in names}
        piece["start"] = lo
        groups.setdefault((kind, gi, pi), []).append(piece)
    out: List[Entry] = []
    for (kind, gi, pi), pieces in groups.items():
        pieces.sort(key=lambda p: p["start"])
        pos = w0
        for p in pieces:
            if p["start"] != pos:
                return None  # gap
            pos += int(np.asarray(next(v for n, v in p.items()
                                       if n != "start")).shape[1])
        if pos != w1:
            return None
        names = [n for n in pieces[0] if n != "start"]
        merged = {n: np.concatenate([p[n] for p in pieces], axis=1)
                  for n in names}
        merged["start"] = w0
        out.append((kind, gi, pi, merged))
    return out or None


@dataclasses.dataclass
class _HostBlock:
    digest: str
    parent: str
    tokens: np.ndarray
    entries: List[Entry]  # one merged entry per (kind, gi, pi), block-local
    nbytes: int
    tick: int = 0


class HostPrefixStore:
    """Byte-capacity LRU of host-side per-block wire entries, keyed by
    the same chained digests as the D-side store. Entries are plain
    numpy — eviction mid-use is safe (a live ``PrefillStream`` holds
    its own references)."""

    def __init__(self, block_size: int, capacity_bytes: int = 256 << 20):
        self.block_size = int(block_size)
        self.capacity_bytes = int(capacity_bytes)
        self._blocks: Dict[str, _HostBlock] = {}
        self._children: Dict[str, Set[str]] = {}
        self._bytes = 0
        self._clock = 0
        self.lookups = 0
        self.hit_tokens = 0

    def __len__(self) -> int:
        return len(self._blocks)

    @property
    def nbytes(self) -> int:
        return self._bytes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, prompt, limit: int) -> Tuple[int, List[Entry]]:
        """Longest cached prefix of ``prompt[:limit]``; returns the hit
        token count and flat wire entries (with absolute ``start``)
        covering ``[0, hit)`` — directly replayable as stream chunks."""
        toks = np.asarray(prompt)
        limit = max(min(int(limit), len(toks)), 0)
        bs = self.block_size
        out: List[Entry] = []
        parent = hashing.ROOT
        b = 0
        tick = self._tick()
        while (b + 1) * bs <= limit:
            digest = hashing.block_hash(parent, toks[b * bs:(b + 1) * bs])
            rec = self._blocks.get(digest)
            if rec is None:
                break
            rec.tick = tick
            for kind, gi, pi, ent in rec.entries:
                shifted = dict(ent)
                shifted["start"] = b * bs
                out.append((kind, gi, pi, shifted))
            parent = digest
            b += 1
        hit = b * bs
        rest = toks[b * bs:limit]
        if len(rest):
            best_len, best = 0, None
            for child in self._children.get(parent, ()):
                rec = self._blocks.get(child)
                if rec is None:
                    continue
                common = _common_prefix_len(rec.tokens, rest)
                if common > best_len:
                    best_len, best = common, rec
            if best is not None:
                for kind, gi, pi, ent in best.entries:
                    part = {n: (v if n == "start" else
                                np.asarray(v)[:, :best_len])
                            for n, v in ent.items()}
                    part["start"] = hit
                    out.append((kind, gi, pi, part))
                hit += best_len
        self.lookups += 1
        self.hit_tokens += hit
        return hit, out

    def insert_prompt(self, prompt, entries: Sequence[Entry],
                      seq_len: int) -> int:
        """Cache every full prompt block a finished stream produced.
        ``entries`` are the stream's accumulated wire entries (absolute
        starts). Returns the number of newly cached blocks."""
        toks = np.asarray(prompt)
        bs = self.block_size
        full = min(int(seq_len), len(toks)) // bs
        parent = hashing.ROOT
        added = 0
        for b in range(full):
            blk = toks[b * bs:(b + 1) * bs]
            digest = hashing.block_hash(parent, blk)
            if digest not in self._blocks:
                merged = assemble_entries(entries, b * bs, (b + 1) * bs)
                if merged is None:
                    break  # incomplete coverage — stop at the gap
                nbytes = _entry_nbytes(merged)
                self._reserve(nbytes)
                rec = _HostBlock(digest, parent, np.array(blk, copy=True),
                                 merged, nbytes, tick=self._tick())
                self._blocks[digest] = rec
                self._children.setdefault(parent, set()).add(digest)
                self._bytes += nbytes
                added += 1
            parent = digest
        return added

    def _reserve(self, nbytes: int) -> None:
        while self._bytes + nbytes > self.capacity_bytes and self._blocks:
            lru = min(self._blocks.values(), key=lambda r: r.tick)
            del self._blocks[lru.digest]
            kids = self._children.get(lru.parent)
            if kids is not None:
                kids.discard(lru.digest)
                if not kids:
                    self._children.pop(lru.parent, None)
            self._bytes -= lru.nbytes

    def reset(self) -> None:
        self._blocks.clear()
        self._children.clear()
        self._bytes = 0
