"""Shared-prefix KV cache: block-aligned, hash-chained prefix reuse
across prefill compute (P-side host store), the connector wire, and
decode-side paged KV (D-side device store) — plus the routing summary
that steers same-prefix requests to the D that already holds them."""
from repro.serving.prefix_cache.hashing import (ROOT, block_hash,
                                                chain_hashes,
                                                matched_prefix_tokens)
from repro.serving.prefix_cache.store import (STORE_OWNER, HostPrefixStore,
                                              PrefixMatch, PrefixStore,
                                              assemble_entries)

__all__ = [
    "ROOT", "block_hash", "chain_hashes", "matched_prefix_tokens",
    "STORE_OWNER", "HostPrefixStore", "PrefixMatch", "PrefixStore",
    "assemble_entries",
]
