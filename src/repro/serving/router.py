"""Load-aware P/D routing policy for the multi-instance cluster runtime.

The cluster parent cannot call ``Engine.load()`` / ``Engine.can_admit()``
— the engines live in other OS processes — so routing runs on *snapshots*:
the parent's own dispatch bookkeeping (authoritative for admission, since
heartbeats lag) refreshed by the measured load each worker reports in its
heartbeats. The policy mirrors the single-process ``GlobalScheduler``:

  * a prompt goes to the P with the least outstanding prefill work —
    queue depth weighted by estimated prefill tokens per request, i.e.
    the sum of estimated tokens still queued on that instance;
  * a stream's D is picked among instances that can admit it (a free
    slot, enough free paged blocks, the sequence fits) by decode queue
    depth first and free KV-pool bytes second — the TetriInfer-style
    per-request instance selection by load.

Pure functions over frozen snapshots so the policy is unit-testable
without processes and reusable by benchmarks and the autoscaler.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.engine import VendorProfile
from repro.serving.prefix_cache import hashing


@dataclasses.dataclass(frozen=True)
class PSnapshot:
    """One prefill instance's routable state."""
    iid: str
    queue_reqs: int                 # dispatched prefills not yet done
    queue_tokens: int               # estimated prompt tokens among them


@dataclasses.dataclass(frozen=True)
class DSnapshot:
    """One decode instance's routable state."""
    iid: str
    active: int                     # slots reserved or decoding
    max_batch: int
    free_blocks: int                # unreserved paged blocks (parent view)
    block_size: int
    max_blocks_per_seq: int
    max_seq_len: int
    block_bytes: int                # KV bytes per paged block (estimate)
    # chained prefix-block digests this instance's prefix store holds
    # (heartbeat-reported; empty when the cache is off or cold). Chained
    # hashing makes set membership sufficient: a prompt's leading chain
    # run inside this set IS its longest cached prefix on that instance.
    prefix_hashes: frozenset = frozenset()


def kv_block_bytes(cfg: ModelConfig, vendor: VendorProfile) -> int:
    """Estimated KV-pool bytes behind one paged block of this instance —
    enough to compare *free KV-pool bytes* across heterogeneous vendors
    (different block sizes / dtypes) without touching device pools."""
    itemsize = np.dtype(vendor.kv_dtype).itemsize
    if cfg.attention_kind == "mla":
        per_token = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    else:
        per_token = 2 * max(cfg.num_kv_heads, 1) * cfg.hd
    return per_token * vendor.block_size * max(cfg.num_layers, 1) * itemsize


def blocks_needed(seq_total: int, block_size: int,
                  max_blocks_per_seq: int) -> int:
    """Paged blocks a sequence of ``seq_total`` tokens reserves — must
    mirror ``Engine.reserve_sequence`` or parent admission drifts from the
    worker's allocator."""
    return min(-(-seq_total // block_size), max_blocks_per_seq)


def pick_p(snaps: List[PSnapshot]) -> Optional[str]:
    """Least-loaded prefill instance: minimal outstanding estimated
    prefill tokens (queue depth × estimated tokens per queued request),
    request count breaking ties, instance id making it deterministic."""
    if not snaps:
        return None
    return min(snaps, key=lambda s: (s.queue_tokens, s.queue_reqs, s.iid)).iid


def pick_d(snaps: List[DSnapshot], seq_len: int, max_new_tokens: int,
           prompt=None) -> Optional[Tuple[str, int]]:
    """Decode instance for a stream of ``seq_len`` prompt tokens +
    ``max_new_tokens`` budget. Returns ``(iid, blocks_reserved)`` or
    ``None`` when no instance can admit (caller keeps the request queued).

    Admission mirrors ``Engine.can_admit``; among admissible instances
    prefix affinity wins first (when ``prompt`` is given and an instance
    advertises cached prefix digests: tokens of the prompt's longest
    chain run inside the instance's digest set — those tokens skip the
    wire entirely, which beats any load delta), then the least-occupied
    (decode queue depth), free KV-pool bytes breaking ties — an idle
    instance with a fuller pool still beats a busy one with an emptier
    pool, matching the single-process router's slot-load primary key.
    With no prompt or all-cold stores every affinity is 0 and the legacy
    ordering is preserved bit-for-bit."""
    seq_total = seq_len + max_new_tokens
    chains = {}     # block_size -> prompt digest chain (computed lazily)
    best = None
    for s in snaps:
        if seq_total > s.max_seq_len or s.active >= s.max_batch:
            continue
        need = blocks_needed(seq_total, s.block_size, s.max_blocks_per_seq)
        if s.free_blocks < need:
            continue
        affinity = 0
        if prompt is not None and s.prefix_hashes:
            chain = chains.get(s.block_size)
            if chain is None:
                chain = hashing.chain_hashes(prompt, s.block_size,
                                             limit=max(seq_len - 1, 0))
                chains[s.block_size] = chain
            affinity = hashing.matched_prefix_tokens(
                chain, s.prefix_hashes, s.block_size)
        key = (-affinity, s.active / s.max_batch,
               -s.free_blocks * s.block_bytes, s.iid)
        if best is None or key < best[0]:
            best = (key, s.iid, need)
    return None if best is None else (best[1], best[2])
