"""Load-aware P/D routing policy for the multi-instance cluster runtime.

The cluster parent cannot call ``Engine.load()`` / ``Engine.can_admit()``
— the engines live in other OS processes — so routing runs on *snapshots*:
the parent's own dispatch bookkeeping (authoritative for admission, since
heartbeats lag) refreshed by the measured load each worker reports in its
heartbeats. The policy mirrors the single-process ``GlobalScheduler``:

  * a prompt goes to the P with the least outstanding prefill work —
    queue depth weighted by estimated prefill tokens per request, i.e.
    the sum of estimated tokens still queued on that instance;
  * a stream's D is picked among instances that can admit it (a free
    slot, enough free paged blocks, the sequence fits) by decode queue
    depth first and free KV-pool bytes second — the TetriInfer-style
    per-request instance selection by load.

The module also holds the *admission-control* policy for open-loop
(heavy-traffic) serving: :class:`AdmissionConfig` + :func:`should_admit`
decide, per arriving request, whether the cluster still has SLO headroom
— measured queue depth below the shed watermark and the TTFT EMA inside
the SLO budget — or whether the request must be shed at the door.
Shedding happens only at submit: a request that was admitted is never
dropped mid-stream.

Pure functions over frozen snapshots so the policy is unit-testable
without processes and reusable by benchmarks and the autoscaler.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.engine import VendorProfile
from repro.serving.prefix_cache import hashing


@dataclasses.dataclass(frozen=True)
class PSnapshot:
    """One prefill instance's routable state."""
    iid: str
    queue_reqs: int                 # dispatched prefills not yet done
    queue_tokens: int               # estimated prompt tokens among them


@dataclasses.dataclass(frozen=True)
class DSnapshot:
    """One decode instance's routable state."""
    iid: str
    active: int                     # slots reserved or decoding
    max_batch: int
    free_blocks: int                # unreserved paged blocks (parent view)
    block_size: int
    max_blocks_per_seq: int
    max_seq_len: int
    block_bytes: int                # KV bytes per paged block (estimate)
    # chained prefix-block digests this instance's prefix store holds
    # (heartbeat-reported; empty when the cache is off or cold). Chained
    # hashing makes set membership sufficient: a prompt's leading chain
    # run inside this set IS its longest cached prefix on that instance.
    prefix_hashes: frozenset = frozenset()


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """SLO-aware admission control for open-loop load.

    A request is shed at submit when either headroom signal is exhausted:

      * ``max_queue_depth`` — measured undispatched queue depth (parent
        pending + dispatched-but-unprefilled P backlog) at or above this
        watermark means arrivals outpace drain; more queueing only adds
        latency to every queued request.
      * ``slo_ttft_s`` × ``headroom`` — the measured TTFT EMA crossing
        this budget means requests already admitted are blowing the SLO;
        admitting more cannot end well.

    Either signal may be disabled with ``None``. ``ema_alpha`` weights the
    newest TTFT sample (higher = faster reaction)."""
    max_queue_depth: Optional[int] = None
    slo_ttft_s: Optional[float] = None
    headroom: float = 1.0
    ema_alpha: float = 0.3


def update_ttft_ema(ema: Optional[float], sample: float,
                    alpha: float) -> float:
    """Fold one measured TTFT into the admission EMA."""
    return sample if ema is None else alpha * sample + (1 - alpha) * ema


def should_admit(cfg: Optional[AdmissionConfig], queue_depth: int,
                 ttft_ema: Optional[float]) -> bool:
    """Pure shed decision: False when measured queue depth or TTFT-EMA
    headroom is exhausted. No config (or no signal yet) always admits.

    The TTFT gate only fires while work is actually queued: the EMA is
    *history*, and it only refreshes when admitted requests produce first
    tokens — shedding on a stale high EMA over an empty cluster would
    lock every future request out (no admits → no fresh samples → shed
    forever). An idle queue means the congestion the EMA recorded has
    drained, so the next arrival is the probe that updates it."""
    if cfg is None:
        return True
    if cfg.max_queue_depth is not None and queue_depth >= cfg.max_queue_depth:
        return False
    if cfg.slo_ttft_s is not None and ttft_ema is not None \
            and queue_depth > 0 \
            and ttft_ema > cfg.slo_ttft_s * cfg.headroom:
        return False
    return True


def kv_block_bytes(cfg: ModelConfig, vendor: VendorProfile) -> int:
    """Estimated KV-pool bytes behind one paged block of this instance —
    enough to compare *free KV-pool bytes* across heterogeneous vendors
    (different block sizes / dtypes) without touching device pools."""
    itemsize = np.dtype(vendor.kv_dtype).itemsize
    if cfg.prefill_capabilities().latent_kv:
        per_token = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    else:
        per_token = 2 * max(cfg.num_kv_heads, 1) * cfg.hd
    return per_token * vendor.block_size * max(cfg.num_layers, 1) * itemsize


def blocks_needed(seq_total: int, block_size: int,
                  max_blocks_per_seq: int) -> int:
    """Paged blocks a sequence of ``seq_total`` tokens reserves — must
    mirror ``Engine.reserve_sequence`` or parent admission drifts from the
    worker's allocator."""
    return min(-(-seq_total // block_size), max_blocks_per_seq)


def pick_p(snaps: List[PSnapshot]) -> Optional[str]:
    """Least-loaded prefill instance: minimal outstanding estimated
    prefill tokens (queue depth × estimated tokens per queued request),
    request count breaking ties, instance id making it deterministic."""
    if not snaps:
        return None
    return min(snaps, key=lambda s: (s.queue_tokens, s.queue_reqs, s.iid)).iid


def pick_d(snaps: List[DSnapshot], seq_len: int, max_new_tokens: int,
           prompt=None) -> Optional[Tuple[str, int]]:
    """Decode instance for a stream of ``seq_len`` prompt tokens +
    ``max_new_tokens`` budget. Returns ``(iid, blocks_reserved)`` or
    ``None`` when no instance can admit (caller keeps the request queued).

    Admission mirrors ``Engine.can_admit``; among admissible instances
    prefix affinity wins first (when ``prompt`` is given and an instance
    advertises cached prefix digests: tokens of the prompt's longest
    chain run inside the instance's digest set — those tokens skip the
    wire entirely, which beats any load delta), then the least-occupied
    (decode queue depth), free KV-pool bytes breaking ties — an idle
    instance with a fuller pool still beats a busy one with an emptier
    pool, matching the single-process router's slot-load primary key.
    With no prompt or all-cold stores every affinity is 0 and the legacy
    ordering is preserved bit-for-bit."""
    seq_total = seq_len + max_new_tokens
    chains = {}     # block_size -> prompt digest chain (computed lazily)
    best = None
    for s in snaps:
        if seq_total > s.max_seq_len or s.active >= s.max_batch:
            continue
        need = blocks_needed(seq_total, s.block_size, s.max_blocks_per_seq)
        if s.free_blocks < need:
            continue
        affinity = 0
        if prompt is not None and s.prefix_hashes:
            chain = chains.get(s.block_size)
            if chain is None:
                chain = hashing.chain_hashes(prompt, s.block_size,
                                             limit=max(seq_len - 1, 0))
                chains[s.block_size] = chain
            affinity = hashing.matched_prefix_tokens(
                chain, s.prefix_hashes, s.block_size)
        key = (-affinity, s.active / s.max_batch,
               -s.free_blocks * s.block_bytes, s.iid)
        if best is None or key < best[0]:
            best = (key, s.iid, need)
    return None if best is None else (best[1], best[2])
