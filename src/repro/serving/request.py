"""Request lifecycle (paper Fig. 2 workflow)."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class State(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    TRANSFERRING = "transferring"
    DECODING = "decoding"
    FINISHED = "finished"
    FAILED = "failed"
    # rejected by admission control before entering the cluster: terminal,
    # never dispatched, never produced a token (open-loop load shedding)
    SHED = "shed"


@dataclasses.dataclass
class Request:
    req_id: str
    prompt: np.ndarray                      # (S,) int32 token ids
    max_new_tokens: int
    # None = "stamp me at submit". An explicit value — *including 0.0*
    # (virtual-clock or epoch-relative schedules) — is the request's
    # scheduled arrival and must survive submit untouched: TTFT measures
    # from here, not from when the driver got around to enqueueing.
    arrival_time: Optional[float] = None
    # multimodal (STUB frontends)
    frames: Optional[np.ndarray] = None     # (F, d) audio frame embeddings
    patches: Optional[np.ndarray] = None    # (P, d) vision patch embeddings
    # sampling
    temperature: float = 0.0                # 0 → greedy
    # lifecycle
    state: State = State.QUEUED
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    prefill_instance: str = ""
    decode_instance: str = ""
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    retries: int = 0
    decode_steps_at_dispatch: int = 0
    chunks_streamed: int = 0                # KV chunks shipped P→D

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def seq_len(self) -> int:
        """Prompt + generated (the KV length)."""
        return self.prompt_len + len(self.output_tokens)

    @property
    def done(self) -> bool:
        return len(self.output_tokens) >= self.max_new_tokens

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None or self.arrival_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = max(len(self.output_tokens) - 1, 1)
        return (self.finish_time - self.first_token_time) / n

    def tpot_live(self) -> Optional[float]:
        """Per-output-token latency including *in-flight* streams: uses the
        last emitted token's timestamp when the request hasn't finished.
        The autoscaler steers on this — a completed-only sample is biased
        toward short requests and reacts a full request-length late."""
        end = self.finish_time if self.finish_time is not None \
            else self.last_token_time
        if end is None or self.first_token_time is None \
                or len(self.output_tokens) < 2:
            return None
        return (end - self.first_token_time) / (len(self.output_tokens) - 1)
