"""Paged KV cache — block pools, block tables, and a host-side allocator.

The paged representation is the substrate of the paper's *VRAM management
alignment* component: different vendors (instances) run different
``block_size`` and page *layout*; `repro.core.compat.layout` converts
between them.

Page layouts (axis order of one pool):
  "nbhd": (num_blocks, block_size, kv_heads, head_dim)   token-major (vLLM-ish)
  "nhbd": (num_blocks, kv_heads, block_size, head_dim)   head-major
  "nhdb": (num_blocks, kv_heads, head_dim, block_size)   dim-major (FT-ish)

The canonical (wire) form of one sequence's KV is the flattened 1-D view of
(S, kv_heads, head_dim) — the paper's "convert to one-dimensional tensor
before transmission" method.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LAYOUTS = ("nbhd", "nhbd", "nhdb")

# permutation from canonical page (block, kv, hd) to each layout
_FROM_CANON = {"nbhd": (0, 1, 2), "nhbd": (1, 0, 2), "nhdb": (1, 2, 0)}


@dataclasses.dataclass(frozen=True)
class KVPageSpec:
    """Vendor-specific VRAM management description of one instance."""
    block_size: int
    layout: str = "nbhd"
    dtype: str = "bfloat16"
    kv_heads: int = 1
    head_dim: int = 1

    def __post_init__(self):
        assert self.layout in LAYOUTS, self.layout

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def page_shape(self) -> Tuple[int, ...]:
        canon = (self.block_size, self.kv_heads, self.head_dim)
        perm = _FROM_CANON[self.layout]
        return tuple(canon[i] for i in perm)

    def pool_shape(self, num_blocks: int) -> Tuple[int, ...]:
        return (num_blocks,) + self.page_shape()

    def blocks_for(self, seq_len: int) -> int:
        return -(-seq_len // self.block_size)


def pages_from_canonical(spec: KVPageSpec, canon: jax.Array) -> jax.Array:
    """(nb, block, kv, hd) canonical pages → layout pages."""
    perm = _FROM_CANON[spec.layout]
    return jnp.transpose(canon, (0,) + tuple(p + 1 for p in perm))


def pages_to_canonical(spec: KVPageSpec, pages: jax.Array) -> jax.Array:
    """layout pages → (nb, block, kv, hd) canonical pages."""
    perm = _FROM_CANON[spec.layout]
    inv = [0] * 3
    for i, p in enumerate(perm):
        inv[p] = i
    return jnp.transpose(pages, (0,) + tuple(i + 1 for i in inv))


def init_pool(spec: KVPageSpec, num_blocks: int) -> jax.Array:
    return jnp.zeros(spec.pool_shape(num_blocks), spec.jdtype)


# --------------------------------------------------------------------------- #
# jnp pool ops (reference implementations; Pallas kernels in repro.kernels)
# --------------------------------------------------------------------------- #
def gather_sequence(spec: KVPageSpec, pool: jax.Array, block_ids: jax.Array,
                    seq_len: int) -> jax.Array:
    """Gather one sequence from a pool → canonical (seq_len, kv, hd).

    block_ids: (nb,) int32; seq_len static (host knows it)."""
    pages = pool[block_ids]                                # (nb, *layout)
    canon = pages_to_canonical(spec, pages)                # (nb, bs, kv, hd)
    flat = canon.reshape(-1, spec.kv_heads, spec.head_dim)
    return flat[:seq_len]


def scatter_sequence(spec: KVPageSpec, pool: jax.Array, block_ids: jax.Array,
                     kv_canon: jax.Array) -> jax.Array:
    """Write canonical (S, kv, hd) into pool pages at ``block_ids``.

    S is padded up to a whole number of blocks internally."""
    s = kv_canon.shape[0]
    nb = block_ids.shape[0]
    pad = nb * spec.block_size - s
    assert pad >= 0, (s, nb, spec.block_size)
    kv_pad = jnp.pad(kv_canon.astype(spec.jdtype), ((0, pad), (0, 0), (0, 0)))
    canon = kv_pad.reshape(nb, spec.block_size, spec.kv_heads, spec.head_dim)
    return pool.at[block_ids].set(pages_from_canonical(spec, canon))


def scatter_sequence_overlay(spec: KVPageSpec, pool: jax.Array,
                             block_ids: jax.Array, kv_canon: jax.Array,
                             front: int) -> jax.Array:
    """Write canonical (S, kv, hd) into pool pages at ``block_ids`` starting
    ``front`` rows into the first block, preserving existing rows outside
    ``[front, front + S)``.

    Boundary-only read-modify-write: only the first and last page are read
    back (the head rows before ``front`` and the tail rows after the chunk);
    interior pages are fully covered by the incoming stream. ``front`` and
    ``S`` are host-known, so the chunk's streamed re-page costs one gather
    of at most two pages plus one scatter — not a full readback of every
    touched page."""
    s = kv_canon.shape[0]
    nb = block_ids.shape[0]
    bs = spec.block_size
    back = nb * bs - front - s
    assert 0 <= front < bs and back >= 0, (front, s, nb, bs)
    head = pages_to_canonical(spec, pool[block_ids[:1]])[0, :front]
    tail = pages_to_canonical(spec, pool[block_ids[-1:]])[0, bs - back:]
    full = jnp.concatenate(
        [head.astype(spec.jdtype), kv_canon.astype(spec.jdtype),
         tail.astype(spec.jdtype)], axis=0)
    canon = full.reshape(nb, bs, spec.kv_heads, spec.head_dim)
    return pool.at[block_ids].set(pages_from_canonical(spec, canon))


def append_token(spec: KVPageSpec, pool: jax.Array, block_ids: jax.Array,
                 slot: jax.Array, kv_tok: jax.Array) -> jax.Array:
    """Write one token's KV per sequence during decode.

    block_ids: (B,) physical block of each seq's current page;
    slot: (B,) offset within the block; kv_tok: (B, kv, hd)."""
    kv_tok = kv_tok.astype(spec.jdtype)
    if spec.layout == "nbhd":
        return pool.at[block_ids, slot].set(kv_tok)
    if spec.layout == "nhbd":
        return pool.at[block_ids, :, slot].set(kv_tok)
    return pool.at[block_ids, :, :, slot].set(kv_tok)      # nhdb


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_table: jax.Array, seq_lens: jax.Array,
                        spec: KVPageSpec, scale: Optional[float] = None,
                        window: int = 0) -> jax.Array:
    """Decode attention against paged KV. Reference (jnp gather) path.

    q: (B, 1, H, hd); block_table: (B, max_blocks); seq_lens: (B,) lengths
    INCLUDING the current token. ``window`` > 0 masks a sliding window.
    Returns (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    max_b = block_table.shape[1]
    kv = spec.kv_heads
    kp = pages_to_canonical(spec, k_pool[block_table.reshape(-1)])
    vp = pages_to_canonical(spec, v_pool[block_table.reshape(-1)])
    s_max = max_b * spec.block_size
    k = kp.reshape(b, s_max, kv, hd)
    v = vp.reshape(b, s_max, kv, hd)
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    grp = h // kv
    qg = q.reshape(b, 1, kv, grp, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = jnp.arange(s_max)[None]
    ok = pos < seq_lens[:, None]
    if window > 0:
        ok &= pos >= (seq_lens[:, None] - window)
    mask = jnp.where(ok, 0.0, -1e30)
    scores = scores + mask[:, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Host-side block allocator (one per instance). Invariants tested with
# hypothesis: a live block is owned by exactly one sequence; free+owned
# partitions the pool.
# --------------------------------------------------------------------------- #
class BlockAllocator:
    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: Dict[str, List[int]] = {}

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def can_allocate(self, n: int) -> bool:
        return len(self._free) >= n

    def allocate(self, seq_id: str, n: int) -> List[int]:
        if len(self._free) < n:
            raise MemoryError(
                f"paged pool exhausted: want {n}, free {len(self._free)}")
        blocks = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(seq_id, []).extend(blocks)
        return blocks

    def blocks_of(self, seq_id: str) -> List[int]:
        return list(self._owned.get(seq_id, []))

    def free(self, seq_id: str) -> int:
        blocks = self._owned.pop(seq_id, [])
        self._free.extend(reversed(blocks))
        return len(blocks)

    def transfer_block(self, from_id: str, to_id: str, block_id: int) -> None:
        """Move one block's ownership between owners without touching the
        free list (prefix-cache adoption of a sequence's prompt blocks)."""
        owned = self._owned.get(from_id)
        if owned is None or block_id not in owned:
            raise ValueError(f"block {block_id} is not owned by {from_id!r}")
        owned.remove(block_id)
        if not owned:
            self._owned.pop(from_id, None)
        self._owned.setdefault(to_id, []).append(block_id)

    def free_block(self, seq_id: str, block_id: int) -> None:
        """Return a single owned block to the free list (prefix-cache
        eviction frees blocks one at a time, LRU order)."""
        owned = self._owned.get(seq_id)
        if owned is None or block_id not in owned:
            raise ValueError(f"block {block_id} is not owned by {seq_id!r}")
        owned.remove(block_id)
        if not owned:
            self._owned.pop(seq_id, None)
        self._free.append(block_id)

    def check_invariants(self) -> None:
        owned = [b for bs in self._owned.values() for b in bs]
        assert len(set(owned)) == len(owned), "double-owned block"
        assert set(owned).isdisjoint(self._free), "owned block in free list"
        assert len(owned) + len(self._free) == self.num_blocks, "leaked block"
