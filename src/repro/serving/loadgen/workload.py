"""Request synthesis for the open-loop harness.

Prompt and output lengths follow independent clamped log-normal
distributions — the ShareGPT-like shape (many short exchanges, a heavy
tail of long ones) that serving papers benchmark against, scaled down by
the caller's clamps so the same generator drives both the tiny CI model
and a real config. Deterministic under a seed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Length mixture + vocab for synthesized requests. The log-normal
    (mu, sigma) are in log-token space; samples are clamped to
    [min, max] so the tail cannot exceed an engine's max_seq_len."""
    vocab_size: int = 512
    prompt_mu: float = 2.6          # median ≈ e^2.6 ≈ 13 tokens
    prompt_sigma: float = 0.4
    prompt_min: int = 4
    prompt_max: int = 32
    output_mu: float = 1.8          # median ≈ 6 tokens
    output_sigma: float = 0.5
    output_min: int = 2
    output_max: int = 16
    # multimodal mix: fraction of requests carrying encoder input and the
    # modality payload sizes. modality "audio" attaches ``frames``
    # (encoder source positions, fixed length — a Whisper-style resampled
    # window), "vision" attaches ``patches`` (drawn uniformly from
    # [patch_min, patch_max] — images vary in tiling). ``encoder_d``
    # is the embedding width of the synthesized frame/patch rows; it must
    # match the target engine's d_model.
    multimodal_fraction: float = 0.0
    modality: str = "audio"         # "audio" | "vision"
    encoder_d: int = 64
    frame_len: int = 10
    patch_min: int = 2
    patch_max: int = 8


@dataclasses.dataclass
class ScheduledRequest:
    """One workload item: a request plus its scheduled arrival offset
    (seconds from the run epoch). The offset is kept outside the request
    so ``Request.arrival_time`` can be rebased to the host monotonic
    clock at run start without losing the schedule."""
    offset_s: float
    request: Request


def _clamped_lognormal(rng: np.random.Generator, n: int, mu: float,
                       sigma: float, lo: int, hi: int) -> np.ndarray:
    ln = rng.lognormal(mu, sigma, size=n)
    return np.clip(np.rint(ln).astype(np.int64), lo, hi)


def build_workload(offsets: List[float], cfg: Optional[WorkloadConfig] = None,
                   seed: int = 0, id_prefix: str = "load"
                   ) -> List[ScheduledRequest]:
    """One request per arrival offset, lengths drawn from ``cfg``'s
    mixture. Same (offsets, cfg, seed) → identical prompts and lengths."""
    cfg = cfg or WorkloadConfig()
    rng = np.random.default_rng(seed)
    n = len(offsets)
    p_lens = _clamped_lognormal(rng, n, cfg.prompt_mu, cfg.prompt_sigma,
                                cfg.prompt_min, cfg.prompt_max)
    o_lens = _clamped_lognormal(rng, n, cfg.output_mu, cfg.output_sigma,
                                cfg.output_min, cfg.output_max)
    mm = rng.random(n) < cfg.multimodal_fraction
    out = []
    for i, off in enumerate(sorted(offsets)):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(p_lens[i])).astype(np.int32)
        frames = patches = None
        if mm[i]:
            if cfg.modality == "audio":
                frames = rng.standard_normal(
                    (cfg.frame_len, cfg.encoder_d)).astype(np.float32)
            else:
                np_i = int(rng.integers(cfg.patch_min, cfg.patch_max + 1))
                patches = rng.standard_normal(
                    (np_i, cfg.encoder_d)).astype(np.float32)
        out.append(ScheduledRequest(
            offset_s=float(off),
            request=Request(req_id=f"{id_prefix}-{i:04d}", prompt=prompt,
                            max_new_tokens=int(o_lens[i]), frames=frames,
                            patches=patches)))
    return out
