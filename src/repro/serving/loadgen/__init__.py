"""Open-loop heavy-traffic harness: seeded arrival processes,
ShareGPT-like synthetic workloads, and a real-time driver that replays
them against the cluster runtime with SLO-aware admission and live
autoscaling."""
from repro.serving.loadgen.arrivals import (bursty_arrivals,
                                            poisson_arrivals)
from repro.serving.loadgen.driver import OpenLoopResult, run_open_loop
from repro.serving.loadgen.workload import (ScheduledRequest,
                                            WorkloadConfig, build_workload)

__all__ = ["poisson_arrivals", "bursty_arrivals", "WorkloadConfig",
           "ScheduledRequest", "build_workload", "OpenLoopResult",
           "run_open_loop"]
