"""Open-loop driver: replay a scheduled workload against a running
``ClusterRuntime`` in real time.

The driver rebases each item's arrival offset onto the host monotonic
clock (``t0 + offset_s``) and stamps it into ``Request.arrival_time``
*before* submitting — TTFT therefore measures from the scheduled
arrival, so time a request spends queued (or waiting for the driver loop
to get around to it) counts against the server, exactly as an external
client would experience it. The closed-loop accounting (TTFT from the
moment of submit) stays reachable by submitting requests with
``arrival_time=None`` through ``ClusterRuntime.serve`` — the parity
baseline for closed-loop tests.

Submission is admission-controlled and non-blocking
(``ClusterRuntime.try_submit``): when the cluster's measured headroom is
exhausted the request is shed at the door (terminal ``State.SHED``,
counted), never abandoned mid-stream. Between due arrivals the driver
pumps ``runtime.step`` and, at a fixed cadence, ticks an optional
autoscaler — live elasticity: grow decisions spawn real worker processes
that join the pool when their Hello lands, while serving continues.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List

from repro.serving.loadgen.workload import ScheduledRequest
from repro.serving.request import Request, State

_TERMINAL = (State.FINISHED, State.FAILED, State.SHED)


@dataclasses.dataclass
class OpenLoopResult:
    """Outcome of one open-loop run (requests carry their own timings)."""
    wall_s: float
    offered: int                       # scheduled arrivals replayed
    admitted: int
    shed: int
    finished: int
    failed: int
    autoscale_actions: List[str] = dataclasses.field(default_factory=list)
    requests: List[Request] = dataclasses.field(default_factory=list)

    def as_dict(self) -> Dict[str, Any]:
        return {"wall_s": self.wall_s, "offered": self.offered,
                "admitted": self.admitted, "shed": self.shed,
                "finished": self.finished, "failed": self.failed,
                "autoscale_actions": list(self.autoscale_actions)}


def run_open_loop(runtime: Any, workload: List[ScheduledRequest], *,
                  autoscaler: Any = None, autoscale_every_s: float = 0.25,
                  step_timeout_s: float = 0.02,
                  max_wall_s: float = 900.0) -> OpenLoopResult:
    """Replay ``workload`` open-loop; drive every admitted request to a
    terminal state. ``runtime`` needs ``try_submit`` and ``step`` (duck-
    typed: tests drive a stub). Raises after ``max_wall_s`` — an open
    loop over a saturated cluster with no admission control would
    otherwise queue without bound."""
    items = collections.deque(sorted(workload, key=lambda it: it.offset_s))
    t0 = time.monotonic()
    deadline = t0 + max_wall_s
    last_tick = t0
    admitted: List[Request] = []
    result = OpenLoopResult(wall_s=0.0, offered=len(items), admitted=0,
                            shed=0, finished=0, failed=0)
    result.requests = [it.request for it in items]

    def outstanding() -> bool:
        return bool(items) or any(r.state not in _TERMINAL for r in admitted)

    while outstanding():
        now = time.monotonic()
        if now > deadline:
            raise RuntimeError(
                f"open-loop run exceeded {max_wall_s:.0f}s with "
                f"{len(items)} arrival(s) unplayed and "
                f"{sum(1 for r in admitted if r.state not in _TERMINAL)} "
                f"request(s) in flight")
        while items and t0 + items[0].offset_s <= now:
            it = items.popleft()
            # scheduled arrival, not submit wall time: queueing delay —
            # including driver-loop lag — lands on TTFT (satellite of the
            # open-loop accounting fix)
            it.request.arrival_time = t0 + it.offset_s
            if runtime.try_submit(it.request):
                admitted.append(it.request)
                result.admitted += 1
            else:
                result.shed += 1
        runtime.step(timeout=step_timeout_s)
        if autoscaler is not None and now - last_tick >= autoscale_every_s:
            last_tick = now
            action = autoscaler.tick()
            if action:
                result.autoscale_actions.append(action)

    result.wall_s = time.monotonic() - t0
    result.finished = sum(1 for r in admitted if r.state == State.FINISHED)
    result.failed = sum(1 for r in admitted if r.state == State.FAILED)
    return result
