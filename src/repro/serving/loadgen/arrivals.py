"""Open-loop arrival processes.

An *open-loop* load generator emits requests on a schedule that does not
depend on the system's responses — the arrival process is fixed up front,
and a slow server accumulates queue instead of slowing the offered load
down (the closed-loop artifact that hides capacity cliffs; see the
coordinated-omission literature). Generators here return sorted arrival
*offsets* in seconds from the run epoch; the driver rebases them onto the
host monotonic clock at run start.

Both processes are deterministic under a seed: the same (seed, rate,
duration) produces bit-identical schedules, so a benchmark run is
replayable and two topologies face the same traffic.
"""
from __future__ import annotations

from typing import List

import numpy as np


def poisson_arrivals(rate_rps: float, duration_s: float,
                     seed: int = 0) -> List[float]:
    """Homogeneous Poisson process: i.i.d. exponential inter-arrival gaps
    at ``rate_rps``, truncated to ``duration_s``."""
    if rate_rps <= 0 or duration_s <= 0:
        return []
    rng = np.random.default_rng(seed)
    out: List[float] = []
    t = 0.0
    # draw in blocks: E[n] = rate·duration, pad by 4·sigma and top up
    while True:
        n = max(int(rate_rps * duration_s
                    + 4 * np.sqrt(rate_rps * duration_s)) + 1, 16)
        for gap in rng.exponential(1.0 / rate_rps, size=n):
            t += float(gap)
            if t >= duration_s:
                return out
            out.append(t)


def bursty_arrivals(rate_rps: float, duration_s: float, seed: int = 0, *,
                    burst_factor: float = 4.0, duty: float = 0.25,
                    period_s: float = 2.0) -> List[float]:
    """Two-state Markov-modulated Poisson process (ON/OFF bursts).

    The ON state offers ``burst_factor``× the base intensity for a
    ``duty`` fraction of each (exponentially jittered) ``period_s``; the
    OFF state offers the remainder so the *average* rate stays
    ``rate_rps`` — bursty and smooth schedules are load-comparable, the
    burstiness only moves when the traffic lands.
    """
    if rate_rps <= 0 or duration_s <= 0:
        return []
    duty = min(max(duty, 1e-6), 1.0)
    on_rate = rate_rps * burst_factor
    # solve duty·on + (1−duty)·off = base  (clamped at 0: extreme
    # burst_factor turns OFF fully silent)
    off_rate = max((rate_rps - duty * on_rate) / max(1.0 - duty, 1e-6), 0.0)
    rng = np.random.default_rng(seed)
    out: List[float] = []
    t = 0.0
    on = True
    while t < duration_s:
        frac = duty if on else (1.0 - duty)
        dwell = float(rng.exponential(period_s * frac))
        rate = on_rate if on else off_rate
        if rate > 0:
            tt = t
            while True:
                tt += float(rng.exponential(1.0 / rate))
                if tt >= min(t + dwell, duration_s):
                    break
                out.append(tt)
        t += dwell
        on = not on
    return out
