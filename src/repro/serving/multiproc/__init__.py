"""Two-process disaggregated P/D serving runtime.

A parent launcher spawns one P-instance process and one D-instance
process (``multiprocessing`` spawn context), each running its own
``Engine`` event loop; the control plane rides ``multiprocessing`` queues
and the KV data plane rides ``SharedMemoryConnector`` segments (staged by
the P process, adopted + read by the D process). See ``launcher.py`` for
the protocol diagram.
"""
from repro.serving.multiproc.launcher import (TwoProcessRuntime,  # noqa: F401
                                              serve_two_process)
from repro.serving.multiproc.messages import (EngineSpec,  # noqa: F401
                                              WorkerSpec)

__all__ = ["TwoProcessRuntime", "serve_two_process", "EngineSpec",
           "WorkerSpec"]
