"""Multi-instance disaggregated P/D serving runtime.

A parent launcher spawns N prefill + M decode worker processes
(``multiprocessing`` spawn context), each running its own ``Engine``
event loop; the parent routes each request to the least-loaded P and an
admitting D (``repro.serving.router``), the control plane rides
``multiprocessing`` queues with instance-addressed messages, and the KV
data plane rides ``SharedMemoryConnector`` segments (staged by the
chosen P process, adopted + read by the chosen D process). See
``launcher.py`` for the protocol diagram; ``TwoProcessRuntime`` is the
degenerate 1P+1D cluster kept as the compatibility entry point.
"""
from repro.serving.multiproc.launcher import (ClusterRuntime,  # noqa: F401
                                              TwoProcessRuntime,
                                              serve_cluster,
                                              serve_two_process)
from repro.serving.multiproc.messages import (ClusterSpec,  # noqa: F401
                                              EngineSpec, WorkerSpec)

__all__ = ["ClusterRuntime", "TwoProcessRuntime", "serve_cluster",
           "serve_two_process", "ClusterSpec", "EngineSpec", "WorkerSpec"]
