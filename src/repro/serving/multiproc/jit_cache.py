"""Shared persistent XLA compilation cache for worker processes.

Every cluster worker builds the same jit programs (prefill chunks, decode
step, chunk re-page) in its own process. Without a shared cache each
process re-traces *and re-compiles* every program it encounters — on an
N×M cluster that multiplies compilation wall time by the process count,
and on small hosts it was the dominant cost of scaling 1P1D → 2P2D
(the BENCH_router regression: compile, not compute, doubled).

``enable_jit_cache`` points this process's JAX at a host-shared on-disk
cache (keyed by program fingerprint + jax version, safe across
heterogeneous EngineSpecs): the first process to compile a program
persists it, every other process — and every later run — loads it.
Must be called before the first jit execution; worker mains call it
before building their engine.
"""
from __future__ import annotations

import os
from typing import Optional


def enable_jit_cache(path: Optional[str]) -> bool:
    """Route this process's XLA compilations through the on-disk cache at
    ``path``. No-op (returns False) when ``path`` is falsy or the cache
    cannot be set up — serving must not fail over a cache."""
    if not path:
        return False
    try:
        os.makedirs(path, exist_ok=True)
        import jax
        jax.config.update("jax_compilation_cache_dir", path)
        # tiny-model programs compile in <1s each; cache them anyway —
        # it is exactly the many-small-programs profile that multiplies
        # across processes
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
        return True
    except Exception:                     # noqa: BLE001 — best-effort only
        return False
