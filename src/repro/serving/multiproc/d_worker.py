"""D-instance worker process: one decode member of the cluster runtime.

Runs the in-process ``DecodeLoop`` protocol as a real OS event loop, with
the re-page half of ``StreamedHandoff`` folded in: adopt each announced
shared-memory segment into this process's ``SharedMemoryConnector``,
``issue_read`` it, re-page completed reads into the paged pools (RMW so
chunk boundaries may straddle blocks), and — once the stream finalizes —
activate the slot and join continuous batching. Decode steps interleave
with re-paging: a request already decoding never waits on another
request's chunks.

Failures are *surfaced*, not swallowed: a lost segment (the P process
died and its staging vanished), an adopt/read error, or an ``AbortStream``
for an in-flight handoff all post :class:`StreamFailed` home so the
scheduler side requeues — the cross-process analogue of the
``TransferError`` → requeue path in the single-process scheduler.

All messages home carry this worker's instance id (``src``), and every
heartbeat carries measured load — occupied slots, free paged blocks, free
KV-pool bytes — the signal the parent's router picks decode instances by.
"""
from __future__ import annotations

import collections
import os
import queue
import time
from typing import Any, Deque, Dict, Optional, Tuple

from repro.serving.multiproc.messages import (AbortStream, BeginStream,
                                              ChunkReady, ChunkRepaged,
                                              FinalizeStream, Heartbeat,
                                              Hello, RequestDone, Shutdown,
                                              StreamAccepted, StreamFailed,
                                              TokenEmitted, WorkerSpec,
                                              WorkerStats)


class _DStream:
    """One in-flight inbound handoff on the D side."""

    def __init__(self, req, attempt: int, slot: int, block_ids):
        self.req = req
        self.attempt = attempt
        self.slot = slot
        self.block_ids = block_ids
        self.pending: Deque[Tuple[str, Any]] = collections.deque()
        self.finalize: Optional[FinalizeStream] = None


class DWorker:
    """Event loop state of one decode worker."""

    def __init__(self, spec: WorkerSpec, cmd_q, evt_q):
        from repro.serving.multiproc.jit_cache import enable_jit_cache
        enable_jit_cache(spec.jit_cache_dir)  # before any jit touches XLA

        import jax

        from repro.core.disagg import DisaggPipeline
        from repro.core.transport import SharedMemoryConnector
        self.spec = spec
        self.iid = spec.iid
        self.cmd_q = cmd_q
        self.evt_q = evt_q
        self.engine = spec.engine.build()
        self.connector = SharedMemoryConnector(**spec.connector_kwargs)
        self.pipeline = DisaggPipeline(self.connector, spec.wire,
                                       codec=spec.codec)
        self.streams: Dict[str, _DStream] = {}
        self.emitted_tokens = 0
        # measured KV-pool footprint per paged block (exact: taken from the
        # pools this engine actually allocated) — free_bytes in heartbeats
        pool_bytes = sum(x.nbytes for x in jax.tree.leaves(self.engine.caches)
                         if hasattr(x, "nbytes"))
        self._block_bytes = pool_bytes // max(spec.engine.num_blocks, 1)
        self.stop = False

    # -- stream lifecycle -------------------------------------------------- #
    def _fail_stream(self, st: _DStream, error: str) -> None:
        """Surface a transfer failure: drop adopted segments, free the
        reservation, tell the scheduler side to requeue."""
        while st.pending:
            key, handle = st.pending.popleft()
            if handle is not None:               # None: adopted, not issued
                handle.cancel()
            self.connector.drop(key)             # adopted: detach only
        self.engine.abort_reservation(st.slot)
        self.streams.pop(st.req.req_id, None)
        self.evt_q.put(StreamFailed(st.req.req_id, st.attempt, error,
                                    src=self.iid))

    def _begin(self, msg: BeginStream) -> None:
        try:
            slot, block_ids = self.engine.reserve_sequence(
                msg.req, msg.seq_len, use_prefix_cache=True)
        except Exception as e:                    # noqa: BLE001
            self.evt_q.put(StreamFailed(msg.req.req_id, msg.attempt, repr(e),
                                        src=self.iid))
            return
        self.streams[msg.req.req_id] = _DStream(msg.req, msg.attempt, slot,
                                                block_ids)
        # report the resident prefix so the parent can tell the P worker
        # which leading chunks to keep off the wire entirely (the P side
        # accounts prefix_hit_tokens/bytes_saved when it actually skips)
        self.evt_q.put(StreamAccepted(msg.req.req_id, msg.attempt,
                                      self.engine.slot_prefix_tokens[slot],
                                      src=self.iid))

    def _adopt_chunk(self, msg: ChunkReady) -> None:
        st = self.streams.get(msg.req_id)
        if st is None or st.attempt != msg.attempt:
            return                                # stale attempt: ignore
        try:
            self.connector.adopt_segment(msg.key, msg.segment, msg.nbytes)
        except Exception as e:                    # noqa: BLE001
            self._fail_stream(st, f"adopt failed: {e!r}")
            return
        # the read is issued lazily in _pump_repage, gated on the
        # connector's max_inflight — a burst of queued ChunkReady must
        # back-pressure, not overrun the channel and fail the stream
        st.pending.append((msg.key, None))

    def _abort(self, msg: AbortStream) -> None:
        st = self.streams.get(msg.req_id)
        if st is None or st.attempt != msg.attempt:
            return
        self._fail_stream(st, msg.reason or "stream aborted mid-handoff")

    # -- re-page / finalize ------------------------------------------------- #
    def _pump_repage(self) -> bool:
        progressed = False
        from repro.core.disagg import _to_device
        for st in list(self.streams.values()):
            while st.pending:
                key, handle = st.pending[0]
                if handle is None:                # issue within channel cap
                    if self.connector.inflight_reads() >= \
                            self.connector.max_inflight:
                        break                     # full: retry next pump
                    try:
                        handle = self.connector.issue_read(key)
                    except Exception as e:        # noqa: BLE001
                        self._fail_stream(st, f"issue_read failed: {e!r}")
                        progressed = True
                        break
                    st.pending[0] = (key, handle)
                if not handle.poll():
                    break
                t0 = time.monotonic()
                try:
                    payload, meta = handle.wait()
                    self.pipeline.materialize(self.engine, st.slot,
                                              st.block_ids,
                                              _to_device(payload), meta,
                                              rmw=True)
                except Exception as e:            # noqa: BLE001 — lost wire
                    self._fail_stream(st, f"transfer failed: {e!r}")
                    progressed = True
                    break
                if hasattr(payload, "release"):
                    payload.release()  # drop views before the segment closes
                self.connector.complete(key)      # detach the adoption
                self.connector.stats.chunks += 1
                st.pending.popleft()
                self.evt_q.put(ChunkRepaged(st.req.req_id, st.attempt, key,
                                            (t0, time.monotonic()),
                                            src=self.iid))
                progressed = True
            if st.req.req_id in self.streams and st.finalize is not None \
                    and not st.pending:
                self._finalize(st)
                progressed = True
        return progressed

    def _finalize(self, st: _DStream) -> None:
        fin = st.finalize
        from repro.core.disagg import _to_device
        if fin.tail is not None:
            t0 = time.monotonic()
            tkey = fin.tail["key"]
            try:
                self.connector.adopt_segment(tkey, fin.tail["segment"],
                                             fin.tail["nbytes"])
            except Exception as e:                # noqa: BLE001
                self._fail_stream(st, f"tail adopt failed: {e!r}")
                return
            try:
                payload, meta = self.connector.issue_read(tkey).wait()
                self.pipeline.materialize(self.engine, st.slot, st.block_ids,
                                          _to_device(payload), meta)
            except Exception as e:                # noqa: BLE001
                self.connector.drop(tkey)         # adopted: free pool+detach
                self._fail_stream(st, f"tail transfer failed: {e!r}")
                return
            self.connector.complete(tkey)
            self.evt_q.put(ChunkRepaged(st.req.req_id, st.attempt, tkey,
                                        (t0, time.monotonic()),
                                        src=self.iid))
        self.engine.activate_sequence(st.slot, fin.first_token, fin.seq_len)
        self.streams.pop(st.req.req_id)
        # the prefill's token starts the stream (scheduler's
        # _emit_first_token, relocated into the D process)
        st.req.output_tokens.append(fin.first_token)
        self.evt_q.put(TokenEmitted(st.req.req_id, fin.first_token,
                                    st.attempt, first=True, src=self.iid))
        self.emitted_tokens += 1
        if st.req.done:
            self.engine.release(st.slot)
            self.evt_q.put(RequestDone(st.req.req_id, st.attempt,
                                       src=self.iid))
        self._maybe_fault_exit()

    # -- decode ------------------------------------------------------------- #
    def _pump_decode(self) -> bool:
        eng = self.engine
        if not any(r is not None and eng.slot_ready[i]
                   for i, r in enumerate(eng.slot_req)):
            return False
        for slot, req, tok in eng.decode_step():
            req.output_tokens.append(tok)
            # this side's req copy froze `retries` at dispatch == the attempt
            self.evt_q.put(TokenEmitted(req.req_id, tok, req.retries,
                                        src=self.iid))
            self.emitted_tokens += 1
            if req.done:
                eng.release(slot)
                self.evt_q.put(RequestDone(req.req_id, req.retries,
                                           src=self.iid))
            self._maybe_fault_exit()
        return True

    def _maybe_fault_exit(self) -> None:
        fault = self.spec.fault_exit_after_tokens
        if fault is not None and self.emitted_tokens >= fault:
            # die *hard*, mid-decode: the volatile KV dies with this
            # process, exactly as a decode node loss. Flush the event
            # queue first so the parent sees the tokens that really left.
            self.evt_q.close()
            self.evt_q.join_thread()
            os._exit(3)

    # -- control plane ------------------------------------------------------ #
    def _drain_cmds(self, limit: int = 64) -> bool:
        progressed = False
        for _ in range(limit):
            try:
                msg = self.cmd_q.get_nowait()
            except queue.Empty:
                break
            progressed = True
            if isinstance(msg, Shutdown):
                self.stop = True
                break
            if isinstance(msg, BeginStream):
                self._begin(msg)
            elif isinstance(msg, ChunkReady):
                self._adopt_chunk(msg)
            elif isinstance(msg, FinalizeStream):
                st = self.streams.get(msg.req_id)
                if st is not None and st.attempt == msg.attempt:
                    st.finalize = msg
            elif isinstance(msg, AbortStream):
                self._abort(msg)
        return progressed

    def _load(self) -> dict:
        """Measured load snapshot for the heartbeat: what the router and
        autoscaler steer by."""
        eng = self.engine
        active = sum(1 for r in eng.slot_req if r is not None)
        free_blocks = eng.allocator.free_blocks
        return {"active": float(active),
                "free_slots": float(eng.max_batch - active),
                "free_blocks": float(free_blocks),
                "free_bytes": float(free_blocks * self._block_bytes),
                "pending_repage": float(sum(len(s.pending)
                                            for s in self.streams.values()))}

    # -- main loop ----------------------------------------------------------- #
    def run(self) -> None:
        self.evt_q.put(Hello(self.iid, os.getpid(), self.engine.name,
                             role="D"))
        last_beat = time.monotonic()
        while not self.stop:
            progressed = self._drain_cmds()
            progressed |= self._pump_repage()
            progressed |= self._pump_decode()
            now = time.monotonic()
            if now - last_beat >= self.spec.heartbeat_s:
                store = self.engine.prefix_store
                self.evt_q.put(Heartbeat(
                    self.iid, load=self._load(),
                    prefix_hashes=None if store is None else store.summary()))
                last_beat = now
            if not progressed:
                time.sleep(0.002)                 # idle: don't spin a core
        self.evt_q.put(WorkerStats(self.iid, self.connector.stats,
                                   self.engine.stats.as_dict()))
        self.connector.close()


def d_main(spec: WorkerSpec, cmd_q, evt_q) -> None:
    """Process entry point (must be importable for spawn)."""
    DWorker(spec, cmd_q, evt_q).run()
