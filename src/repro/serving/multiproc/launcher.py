"""Two-process disaggregated serving runtime (parent/launcher side).

``TwoProcessRuntime`` spawns one P-instance process and one D-instance
process (``multiprocessing.get_context("spawn")``), each running its own
``Engine`` event loop (:mod:`p_worker`, :mod:`d_worker`). The parent is
the control plane — request submission, chunk-ready notifications,
completion, clean shutdown, and crash detection — over ``multiprocessing``
queues; the KV data plane is ``SharedMemoryConnector`` segments staged by
P and adopted + read by D, so model bytes never transit a queue.

    parent (control plane, this module)
      │ SubmitPrefill              │ BeginStream / ChunkReady / Finalize
      ▼                            ▼
    ┌────────────┐  shm segments ┌────────────┐
    │ P process  │ ─────────────▶│ D process  │
    │ prefill +  │  (data plane) │ repage +   │
    │ stage      │               │ decode     │
    └────────────┘               └────────────┘
      │ ChunkStaged/PrefillDone    │ ChunkRepaged/Token/Done/StreamFailed
      └────────────▶ parent ◀──────┘

Fault handling mirrors the single-process ``GlobalScheduler``: a P crash
mid-stream aborts the D-side reservation, strands-then-unlinks the dead
attempt's segments, and requeues the request (``TransferStats.retries``);
a D crash loses all volatile KV, so every unfinished request re-prefills
with its generated prefix appended. Crashed workers are respawned (up to
``max_respawns``) so serving continues.

The parent also *measures* the handoff: every ``ChunkStaged`` /
``ChunkRepaged`` carries ``time.monotonic`` intervals (comparable across
processes on one host), from which the launcher computes true wall-clock
wire/compute overlap per flight — ``TransferStats.wall_overlap_seconds``
— something a single process can only model.
"""
from __future__ import annotations

import collections
import dataclasses
import multiprocessing as mp
import queue
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.transport.base import TransferStats
from repro.serving.multiproc import d_worker, p_worker
from repro.serving.multiproc.messages import (AbortStream, BeginStream,
                                              ChunkReady, ChunkRepaged,
                                              ChunkStaged, EngineSpec,
                                              FinalizeStream, Heartbeat,
                                              Hello, PrefillDone,
                                              PrefillFailed, ReleaseStaged,
                                              RequestDone, Shutdown,
                                              StreamFailed, SubmitPrefill,
                                              TokenEmitted, WorkerSpec,
                                              WorkerStats)
from repro.serving.request import Request, State
from repro.serving.scheduler import SchedulerStats, requeue_for_retry


def _unlink_segment(name: str) -> None:
    """Best-effort unlink of a stranded segment (crashed P's staging)."""
    from multiprocessing import shared_memory
    try:
        seg = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        return
    try:
        seg.close()
        seg.unlink()
    except FileNotFoundError:
        pass


def _interval_overlap(a: Tuple[float, float],
                      spans: List[Tuple[float, float]]) -> float:
    """Length of interval ``a`` covered by the (disjoint) ``spans``."""
    return sum(max(0.0, min(a[1], s1) - max(a[0], s0)) for s0, s1 in spans)


def _union(spans: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Merge intervals into a sorted disjoint union."""
    merged: List[Tuple[float, float]] = []
    for s0, s1 in sorted(spans):
        if merged and s0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], s1))
        elif s1 > s0:
            merged.append((s0, s1))
    return merged


@dataclasses.dataclass
class _FlightRecord:
    """Parent-side view of one dispatched request."""
    req: Request
    attempt: int
    p_gen: int = 0                        # P spawn generation at dispatch
    phase: str = "prefill"                # prefill → decode
    prefill_done: bool = False
    # key → segment of chunks staged but not yet released back to P
    outstanding: Dict[str, str] = dataclasses.field(default_factory=dict)
    # key → segment of EVERY chunk this attempt ever staged (never popped;
    # crash cleanup unlinks from here, since a release sent to a dead P is
    # lost and `outstanding` alone under-counts)
    segments: Dict[str, str] = dataclasses.field(default_factory=dict)
    # measured wall-clock intervals (monotonic), per chunk index order
    stage_spans: List[Tuple[float, float]] = dataclasses.field(
        default_factory=list)
    compute_spans: List[Tuple[float, float]] = dataclasses.field(
        default_factory=list)
    repage_spans: Dict[str, Tuple[float, float]] = dataclasses.field(
        default_factory=dict)
    chunk_keys: List[str] = dataclasses.field(default_factory=list)


class TwoProcessRuntime:
    """1 P-process + 1 D-process disaggregated serving loop."""

    def __init__(self, p_spec: EngineSpec, d_spec: EngineSpec, *,
                 wire=None,
                 connector_kwargs: Optional[Dict[str, Any]] = None,
                 prefill_chunk: Optional[int] = 16,
                 max_retries: int = 3,
                 stall_timeout_s: float = 120.0,
                 max_respawns: int = 4,
                 fault_exit_after_chunks: Optional[int] = None):
        from repro.core.compat.precision import WireFormat
        wire = wire or WireFormat("raw", "float32")
        ck = dict(connector_kwargs or {})
        self.p_spec = WorkerSpec(engine=p_spec, wire=wire,
                                 connector_kwargs=ck,
                                 prefill_chunk=prefill_chunk,
                                 fault_exit_after_chunks=fault_exit_after_chunks)
        self.d_spec = WorkerSpec(engine=d_spec, wire=wire,
                                 connector_kwargs=ck,
                                 prefill_chunk=prefill_chunk)
        self.max_retries = max_retries
        self.stall_timeout_s = stall_timeout_s
        self.max_respawns = max_respawns
        self.stats = SchedulerStats()
        self.transfer_stats = TransferStats()     # parent-measured + merged
        self.worker_stats: Dict[str, Dict[str, float]] = {}
        self.worker_pids: Dict[str, int] = {}
        self.stream_failures: List[Tuple[str, str]] = []
        self.crashes: Dict[str, int] = {"P": 0, "D": 0}
        self._ctx = mp.get_context("spawn")
        self._procs: Dict[str, mp.Process] = {}
        self._cmd_qs: Dict[str, Any] = {}
        self._evt_q = None
        self._gen: Dict[str, int] = {"P": 0, "D": 0}   # spawn generations
        # seq → segment of releases sent to P but not yet acked. P
        # piggybacks the highest seq it has processed on its messages
        # home; entries at or below that ack are pruned. On a P crash the
        # remainder is unlinked directly — a release queued to a dead
        # process frees nothing.
        self._released: Dict[int, str] = {}
        self._release_seq = 0
        self._last_seen: Dict[str, float] = {}
        self._pending: collections.deque = collections.deque()
        self._active: Dict[str, _FlightRecord] = {}
        self._requests: Dict[str, Request] = {}
        self._final_stats_expected = 0

    # -- process lifecycle ------------------------------------------------- #
    def start(self, spawn_timeout_s: float = 120.0) -> None:
        self._evt_q = self._ctx.Queue()
        self._spawn("P")
        self._spawn("D")
        self._await_hello({"P", "D"}, spawn_timeout_s)

    def _spawn(self, side: str, fault: bool = True) -> None:
        self._gen[side] += 1
        spec = self.p_spec if side == "P" else self.d_spec
        if side == "P" and not fault:
            spec = dataclasses.replace(spec, fault_exit_after_chunks=None)
            self.p_spec = spec                    # one injected crash only
        cmd_q = self._ctx.Queue()
        target = p_worker.p_main if side == "P" else d_worker.d_main
        proc = self._ctx.Process(target=target,
                                 args=(spec, cmd_q, self._evt_q),
                                 daemon=True, name=f"repro-{side.lower()}")
        proc.start()
        self._procs[side] = proc
        self._cmd_qs[side] = cmd_q
        self._last_seen[side] = time.monotonic()

    def _await_hello(self, sides: set, timeout_s: float) -> None:
        deadline = time.monotonic() + timeout_s
        waiting = set(sides)
        while waiting:
            if time.monotonic() > deadline:
                raise RuntimeError(f"worker(s) {sorted(waiting)} did not "
                                   f"start within {timeout_s:.0f}s")
            msg = self._next_event(timeout=0.2)
            if msg is None:
                continue
            self._handle(msg)
            if isinstance(msg, Hello):
                waiting.discard(msg.src)

    def __enter__(self) -> "TwoProcessRuntime":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- serving ------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.arrival_time = req.arrival_time or time.monotonic()
        self._requests[req.req_id] = req
        self._pending.append(req)
        self.stats.submitted += 1

    def serve(self, requests: List[Request],
              max_wall_s: float = 900.0) -> Dict[str, List[int]]:
        """Drive every request to a terminal state; returns req_id → tokens."""
        for r in requests:
            self.submit(r)
        deadline = time.monotonic() + max_wall_s
        while self._unresolved():
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"two-process serve exceeded {max_wall_s:.0f}s with "
                    f"{self._unresolved()} request(s) unresolved")
            self._dispatch()
            self._check_workers()
            msg = self._next_event(timeout=0.05)
            if msg is not None:
                self._handle(msg)
        return {r.req_id: list(r.output_tokens) for r in requests}

    def _unresolved(self) -> int:
        return sum(1 for r in self._requests.values()
                   if r.state not in (State.FINISHED, State.FAILED))

    def _dispatch(self) -> None:
        """Admission control: D has ``max_batch`` slots; everything else
        waits in the parent's queue."""
        cap = self.d_spec.engine.max_batch
        while self._pending and len(self._active) < cap:
            req = self._pending.popleft()
            if req.state == State.FAILED:
                continue
            patches = req.patches.shape[0] if req.patches is not None else 0
            seq_len = req.prompt_len + patches
            req.state = State.PREFILLING
            rec = _FlightRecord(req=req, attempt=req.retries,
                                p_gen=self._gen["P"])
            self._active[req.req_id] = rec
            # FIFO per queue: BeginStream always precedes its ChunkReady
            self._cmd_qs["D"].put(BeginStream(req, req.retries, seq_len))
            self._cmd_qs["P"].put(SubmitPrefill(req))

    # -- event pump ---------------------------------------------------------- #
    def _next_event(self, timeout: float):
        try:
            return self._evt_q.get(timeout=timeout)
        except queue.Empty:
            return None

    def _handle(self, msg: Any) -> None:
        if isinstance(msg, (Hello, Heartbeat)):
            self._last_seen[msg.src] = time.monotonic()
            if isinstance(msg, Hello):
                self.worker_pids[msg.src] = msg.pid
            elif msg.src == "P":
                self._prune_released(msg.ack_seq)
            return
        if isinstance(msg, WorkerStats):
            self.transfer_stats.merge(msg.transfer)
            self.worker_stats[msg.src] = msg.engine
            self._final_stats_expected -= 1
            return
        if isinstance(msg, (ChunkStaged, PrefillDone, PrefillFailed)):
            self._last_seen["P"] = time.monotonic()
            self._handle_p(msg)
            return
        self._last_seen["D"] = time.monotonic()
        self._handle_d(msg)

    def _rec_for(self, req_id: str, attempt: int) -> Optional[_FlightRecord]:
        rec = self._active.get(req_id)
        if rec is None or rec.attempt != attempt:
            return None
        return rec

    def _prune_released(self, ack_seq: int) -> None:
        """Drop the crash-cleanup record of releases P has confirmed."""
        if ack_seq and self._released:
            self._released = {s: seg for s, seg in self._released.items()
                              if s > ack_seq}

    def _release_on_p(self, key: str,
                      segment: Optional[str] = None) -> None:
        """Tell P it may free a staged key — or, if P is gone, unlink the
        OS segment directly (when its name is known)."""
        proc = self._procs.get("P")
        if proc is not None and proc.is_alive():
            self._release_seq += 1
            if segment is not None:
                self._released[self._release_seq] = segment
            self._cmd_qs["P"].put(ReleaseStaged(key, self._release_seq))
        elif segment is not None:
            _unlink_segment(segment)

    def _handle_p(self, msg: Any) -> None:
        if isinstance(msg, (ChunkStaged, PrefillDone)):
            self._prune_released(msg.ack_seq)
        if isinstance(msg, ChunkStaged):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:                       # stale attempt: free it
                self._release_on_p(msg.key, msg.segment)
                return
            rec.outstanding[msg.key] = msg.segment
            rec.segments[msg.key] = msg.segment
            rec.chunk_keys.append(msg.key)
            rec.stage_spans.append(msg.t_stage)
            rec.compute_spans.append(msg.t_compute)
            rec.req.chunks_streamed += 1
            self.stats.chunks_streamed += 1
            self._cmd_qs["D"].put(ChunkReady(msg.req_id, msg.attempt,
                                             msg.key, msg.segment,
                                             msg.nbytes))
            return
        if isinstance(msg, PrefillDone):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:
                if msg.tail is not None:
                    self._release_on_p(msg.tail["key"], msg.tail["segment"])
                return
            rec.prefill_done = True
            if msg.tail is not None:
                rec.outstanding[msg.tail["key"]] = msg.tail["segment"]
                rec.segments[msg.tail["key"]] = msg.tail["segment"]
            self._cmd_qs["D"].put(FinalizeStream(msg.req_id, msg.attempt,
                                                 msg.first_token,
                                                 msg.seq_len, msg.tail))
            return
        if isinstance(msg, PrefillFailed):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:
                return
            self._abort_flight(rec, f"P-side dispatch failure: {msg.error}")

    def _handle_d(self, msg: Any) -> None:
        if isinstance(msg, ChunkRepaged):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:
                self._release_on_p(msg.key)
                return
            rec.outstanding.pop(msg.key, None)
            rec.repage_spans[msg.key] = msg.t_repage
            if self._gen["P"] == rec.p_gen:       # creator still the live P
                self._release_on_p(msg.key, rec.segments.get(msg.key))
            else:           # creator died: a release would go to the wrong
                segment = rec.segments.get(msg.key)   # process — unlink
                if segment is not None:
                    _unlink_segment(segment)
            return
        if isinstance(msg, TokenEmitted):
            req = self._requests.get(msg.req_id)
            rec = self._rec_for(msg.req_id, msg.attempt)
            if req is None or rec is None:        # stale attempt's token
                return
            req.output_tokens.append(msg.token)
            if msg.first:
                rec.phase = "decode"
                req.state = State.DECODING
                if req.first_token_time is None:
                    req.first_token_time = time.monotonic()
                self.stats.p_dispatches[self.p_spec.engine.name] += 1
                self.stats.d_dispatches[self.d_spec.engine.name] += 1
                self._account_flight(rec)
            return
        if isinstance(msg, RequestDone):
            req = self._requests.get(msg.req_id)
            rec = self._rec_for(msg.req_id, msg.attempt)
            if req is None or rec is None:        # stale attempt finishing
                return
            self._active.pop(msg.req_id, None)
            req.state = State.FINISHED
            req.finish_time = time.monotonic()
            self.stats.finished += 1
            return
        if isinstance(msg, StreamFailed):
            self.stream_failures.append((msg.req_id, msg.error))
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:
                return
            self._abort_flight(rec, msg.error, abort_d=False)

    # -- measured overlap ---------------------------------------------------- #
    def _account_flight(self, rec: _FlightRecord) -> None:
        """Wall-clock handoff accounting for one completed stream: the wire
        interval of chunk *i* is [stage-end_i, repage-start_i]; whatever
        part of it lies under this flight's prefill-compute spans was
        *measured* overlap — true cross-process concurrency, not a model."""
        repaged = [rec.repage_spans.get(k) for k in rec.chunk_keys]
        pairs = [(st, rp) for st, rp in zip(rec.stage_spans, repaged)
                 if rp is not None]
        if not pairs:
            return
        t0 = min(st[0] for st, _ in pairs)
        t1 = max(rp[1] for _, rp in pairs)
        self.transfer_stats.wall_handoff_seconds += t1 - t0
        # chunks can be concurrently in flight, so intersect the *unions*
        # (wire-busy time ∩ compute-busy time) — bounded by the handoff span
        wire = _union([(st[1], max(rp[0], st[1])) for st, rp in pairs])
        compute = _union(rec.compute_spans)
        self.transfer_stats.wall_overlap_seconds += \
            sum(_interval_overlap(w, compute) for w in wire)

    # -- failure handling ----------------------------------------------------- #
    def _abort_flight(self, rec: _FlightRecord, reason: str,
                      abort_d: bool = True) -> None:
        self._active.pop(rec.req.req_id, None)
        if abort_d:
            dproc = self._procs.get("D")
            if dproc is not None and dproc.is_alive():
                self._cmd_qs["D"].put(
                    AbortStream(rec.req.req_id, rec.attempt, reason))
        pproc = self._procs.get("P")
        if pproc is not None and pproc.is_alive() \
                and self._gen["P"] == rec.p_gen:
            for key, segment in rec.outstanding.items():
                self._release_on_p(key, segment)
        else:
            # the staging process is gone (or already replaced): releases
            # would go nowhere — unlink every segment this attempt ever
            # staged (idempotent for the ones P freed before dying)
            for segment in rec.segments.values():
                _unlink_segment(segment)
        rec.outstanding.clear()
        self._requeue(rec.req)

    def _requeue(self, req: Request) -> None:
        if requeue_for_retry(req, self.stats, self.transfer_stats,
                             self.max_retries):
            self._pending.appendleft(req)

    def _check_workers(self) -> None:
        now = time.monotonic()
        for side in ("P", "D"):
            proc = self._procs.get(side)
            if proc is None:
                continue
            if proc.is_alive():
                if now - self._last_seen[side] > self.stall_timeout_s:
                    proc.terminate()              # hung, not dead: make it dead
                    proc.join(timeout=5.0)
                    self._on_crash(side, "stalled past watchdog timeout")
                continue
            self._on_crash(side, f"exited with code {proc.exitcode}")

    def _on_crash(self, side: str, why: str) -> None:
        self.crashes[side] += 1
        self._procs.pop(side, None)
        if side == "P":
            # prefill-phase flights whose stream never fully left P are
            # void: abort the D reservation, unlink the dead attempt's
            # stranded segments, requeue. Flights past PrefillDone are
            # wholly on D's side — let them finish (a lost segment there
            # surfaces as StreamFailed → requeue) rather than requeue a
            # stream D may already be decoding, which would double-serve.
            for rec in [r for r in self._active.values()
                        if r.phase == "prefill" and not r.prefill_done]:
                self._abort_flight(rec, f"P process died mid-stream ({why})")
            # releases queued to the dead P were never processed: unlink
            # those segments directly (no-op for any it freed in time)
            for segment in self._released.values():
                _unlink_segment(segment)
            self._released.clear()
        else:
            # volatile KV died with the node: every non-terminal request
            # restarts from prefill with its prefix appended
            for rec in list(self._active.values()):
                self._abort_flight(rec, f"D process died ({why})",
                                   abort_d=False)
        # a dying worker flushes its event queue before exiting — drain the
        # flushed backlog *before* respawning, so ChunkStaged events from
        # the dead attempt unlink their stranded segments (the stale path
        # in _handle_p) instead of being mistaken for the successor's
        while True:
            msg = self._next_event(timeout=0.1)
            if msg is None:
                break
            self._handle(msg)
        if self._unresolved() == 0:
            return
        if self.crashes[side] > self.max_respawns:
            for r in self._requests.values():
                if r.state not in (State.FINISHED, State.FAILED):
                    r.state = State.FAILED
                    self.stats.failed += 1
            return
        self._spawn(side, fault=False)
        self._await_hello({side}, timeout_s=120.0)

    # -- shutdown -------------------------------------------------------------- #
    def shutdown(self, timeout_s: float = 15.0) -> None:
        self._final_stats_expected = 0
        for side, proc in list(self._procs.items()):
            if proc.is_alive():
                self._cmd_qs[side].put(Shutdown())
                self._final_stats_expected += 1
        deadline = time.monotonic() + timeout_s
        while self._final_stats_expected > 0 and time.monotonic() < deadline:
            msg = self._next_event(timeout=0.2)
            if msg is not None:
                self._handle(msg)
        for proc in self._procs.values():
            proc.join(timeout=5.0)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)
        self._procs.clear()


def serve_two_process(p_spec: EngineSpec, d_spec: EngineSpec,
                      requests: List[Request], **kw
                      ) -> Tuple[Dict[str, List[int]], TwoProcessRuntime]:
    """One-shot convenience: start → serve → shutdown. Returns the token
    streams and the (shut-down) runtime for stats inspection."""
    max_wall_s = kw.pop("max_wall_s", 900.0)
    rt = TwoProcessRuntime(p_spec, d_spec, **kw)
    rt.start()
    try:
        tokens = rt.serve(requests, max_wall_s=max_wall_s)
    finally:
        rt.shutdown()
    return tokens, rt
