"""Multi-instance disaggregated serving runtime (parent/router side).

``ClusterRuntime`` spawns N prefill + M decode worker processes
(``multiprocessing.get_context("spawn")``) from a :class:`ClusterSpec` —
heterogeneous ``EngineSpec``s allowed, per the paper's multi-vendor
setting — each running its own ``Engine`` event loop (:mod:`p_worker`,
:mod:`d_worker`). The parent is the control plane *and the router*:
every prompt goes to the least-loaded P (outstanding estimated prefill
tokens), every stream's D is picked among instances that can admit it by
decode queue depth and free KV-pool bytes (:mod:`repro.serving.router`);
the KV data plane is ``SharedMemoryConnector`` segments staged by the
chosen P and adopted + read by the chosen D, so model bytes never
transit a queue.

    parent (router + control plane, this module)
      │ SubmitPrefill ──▶ P_i        │ Begin/ChunkReady/Finalize ──▶ D_j
      ▼                              ▼
    ┌────────────┐  shm segments  ┌────────────┐
    │ P_0 … P_N  │ ──────────────▶│ D_0 … D_M  │
    │ prefill +  │  (data plane)  │ repage +   │
    │ stage      │                │ decode     │
    └────────────┘                └────────────┘
      │ ChunkStaged/PrefillDone      │ ChunkRepaged/Token/Done/Failed
      └──────────────▶ parent ◀──────┘      (all instance-addressed)

Fault handling generalizes the single-process ``GlobalScheduler``: a P
crash aborts only *that instance's* prefill-phase flights (stranding →
unlinking the dead attempt's segments, requeueing via the shared
``requeue_for_retry``); a D crash loses only that instance's volatile
KV, so its unfinished streams re-prefill with their generated prefix
appended. When the pool has a *surviving* member of the crashed role,
the requeued flights simply re-route to it — no respawn, no global
stall; only a pool left empty respawns (up to ``max_respawns``).
Release-seq/ack bookkeeping is per-P-instance: each P has its own
monotone release counter and piggybacked ack horizon, so one instance's
crash cleanup never touches another's staged segments.

The parent also *measures* the handoff: every ``ChunkStaged`` /
``ChunkRepaged`` carries ``time.monotonic`` intervals (comparable across
processes on one host), from which it computes true wall-clock
wire/compute overlap per flight — ``TransferStats.wall_overlap_seconds``
— and per-instance dispatch counts / heartbeat load snapshots feed the
plan-vs-measured report (:mod:`report`) and the cluster-backed
autoscaler source.
"""
from __future__ import annotations

import collections
import dataclasses
import multiprocessing as mp
import os
import queue
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.transport.base import TransferStats
from repro.serving import router
from repro.serving.multiproc import d_worker, p_worker
from repro.serving.multiproc.messages import (AbortStream, BeginStream,
                                              ChunkReady, ChunkRepaged,
                                              ChunkStaged, ClusterSpec,
                                              EngineSpec, FinalizeStream,
                                              Heartbeat, Hello, PrefillDone,
                                              PrefillFailed, ReleaseStaged,
                                              RequestDone, Shutdown,
                                              StreamAccepted, StreamFailed,
                                              SubmitPrefill, TokenEmitted,
                                              WorkerSpec, WorkerStats)
from repro.serving.engine import PrefillMode
from repro.serving.request import Request, State
from repro.serving.router import (AdmissionConfig, should_admit,
                                  update_ttft_ema)
from repro.serving.scheduler import RuntimeStats, requeue_for_retry


def default_jit_cache_dir() -> Optional[str]:
    """Shared persistent XLA compilation-cache directory for every worker
    process of this host. N workers (and repeat runs) compile each program
    once instead of N times — on small hosts redundant per-process jit
    compilation, not compute, dominated multi-instance wall time.
    Overridable via ``REPRO_JIT_CACHE_DIR`` (empty string disables)."""
    env = os.environ.get("REPRO_JIT_CACHE_DIR")
    if env is not None:
        return env or None
    return os.path.join(tempfile.gettempdir(), "repro-jax-cache")


def _unlink_segment(name: str) -> None:
    """Best-effort unlink of a stranded segment (crashed P's staging)."""
    from multiprocessing import shared_memory
    try:
        seg = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        return
    try:
        seg.close()
        seg.unlink()
    except FileNotFoundError:
        pass


def _interval_overlap(a: Tuple[float, float],
                      spans: List[Tuple[float, float]]) -> float:
    """Length of interval ``a`` covered by the (disjoint) ``spans``."""
    return sum(max(0.0, min(a[1], s1) - max(a[0], s0)) for s0, s1 in spans)


def _union(spans: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Merge intervals into a sorted disjoint union."""
    merged: List[Tuple[float, float]] = []
    for s0, s1 in sorted(spans):
        if merged and s0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], s1))
        elif s1 > s0:
            merged.append((s0, s1))
    return merged


@dataclasses.dataclass
class _Instance:
    """Parent-side state of one worker process (a pool member)."""
    iid: str
    role: str                             # "P" | "D"
    spec: WorkerSpec
    proc: Optional[Any] = None
    cmd_q: Optional[Any] = None
    gen: int = 0                          # spawn generation (respawns bump)
    pid: Optional[int] = None
    hello: bool = False                   # worker reported ready (routable)
    last_seen: float = 0.0
    draining: bool = False                # no new work routed here
    stopping: bool = False                # Shutdown sent, awaiting exit
    load: Dict[str, float] = dataclasses.field(default_factory=dict)
    # router counters, parent-authoritative (heartbeats lag dispatch) —
    # P: outstanding dispatched prefills; D: reserved slots/blocks
    queue_reqs: int = 0
    queue_tokens: int = 0
    active: int = 0
    reserved_blocks: int = 0
    block_bytes: int = 0                  # KV bytes per paged block (est.)
    # P only: seq → segment of releases sent but not yet acked. The P
    # piggybacks the highest seq it has processed on its messages home;
    # entries at or below that ack are pruned. On a crash the remainder
    # is unlinked directly — a release queued to a dead process frees
    # nothing.
    released: Dict[int, str] = dataclasses.field(default_factory=dict)
    release_seq: int = 0
    # D only: prefix-store digest summary from the latest heartbeat —
    # the router's affinity signal (empty when the cache is off or cold)
    prefix_hashes: frozenset = frozenset()

    def alive(self) -> bool:
        return self.proc is not None and self.proc.is_alive()


@dataclasses.dataclass
class _FlightRecord:
    """Parent-side view of one dispatched request."""
    req: Request
    attempt: int
    p_id: str                             # prefill instance serving it
    d_id: str                             # decode instance serving it
    p_gen: int = 0                        # P spawn generation at dispatch
    est_tokens: int = 0                   # router's P-load contribution
    need_blocks: int = 0                  # router's D-pool contribution
    p_settled: bool = False               # P counters decremented
    d_settled: bool = False               # D counters decremented
    phase: str = "prefill"                # prefill → decode
    prefill_done: bool = False
    # prefix-cache mode: SubmitPrefill is deferred until the D posts
    # StreamAccepted (carrying the resident-prefix wire skip); True when
    # the P has been told to start (immediately so with the cache off)
    submitted: bool = True
    # key → segment of chunks staged but not yet released back to P
    outstanding: Dict[str, str] = dataclasses.field(default_factory=dict)
    # key → segment of EVERY chunk this attempt ever staged (never popped;
    # crash cleanup unlinks from here, since a release sent to a dead P is
    # lost and `outstanding` alone under-counts)
    segments: Dict[str, str] = dataclasses.field(default_factory=dict)
    # measured wall-clock intervals (monotonic), per chunk index order
    stage_spans: List[Tuple[float, float]] = dataclasses.field(
        default_factory=list)
    compute_spans: List[Tuple[float, float]] = dataclasses.field(
        default_factory=list)
    repage_spans: Dict[str, Tuple[float, float]] = dataclasses.field(
        default_factory=dict)
    chunk_keys: List[str] = dataclasses.field(default_factory=list)


class ClusterRuntime:
    """N P-processes × M D-processes disaggregated serving loop."""

    def __init__(self, cluster: ClusterSpec, *,
                 wire=None,
                 codec: str = "fixed",
                 connector_kwargs: Optional[Dict[str, Any]] = None,
                 prefill_chunk: Optional[int] = 16,
                 prefill_mode: str = "auto",
                 max_retries: int = 3,
                 stall_timeout_s: float = 120.0,
                 max_respawns: int = 4,
                 admission: Optional[AdmissionConfig] = None,
                 jit_cache_dir: Optional[str] = "auto",
                 fault_exit_after_chunks: Optional[int] = None,
                 fault_exit_after_tokens: Optional[int] = None):
        from repro.core.compat.precision import WireFormat
        self.cluster = cluster
        self._prefix = any(e.prefix_cache for e in cluster.p + cluster.d)
        self._wire = wire or WireFormat("raw", "float32")
        self._codec = codec
        self._ck = dict(connector_kwargs or {})
        self._prefill_chunk = prefill_chunk
        # validated here so a typo fails at construction, not in a worker
        self._prefill_mode = PrefillMode(prefill_mode).value
        self.max_retries = max_retries
        self.stall_timeout_s = stall_timeout_s
        self.max_respawns = max_respawns
        self.admission = admission
        # measured TTFT EMA (arrival → first token), the admission signal
        self.ttft_ema: Optional[float] = None
        self._jit_cache_dir = default_jit_cache_dir() \
            if jit_cache_dir == "auto" else jit_cache_dir
        self.stats = RuntimeStats()
        self.transfer_stats = TransferStats()     # parent-measured + merged
        self.worker_stats: Dict[str, Dict[str, float]] = {}
        self.worker_pids: Dict[str, int] = {}
        self.stream_failures: List[Tuple[str, str]] = []
        self.crashes: Dict[str, int] = {"P": 0, "D": 0}
        self.respawns: Dict[str, int] = {"P": 0, "D": 0}
        self.instance_crashes: Dict[str, int] = {}
        self._ctx = mp.get_context("spawn")
        self._evt_q = None
        self._instances: Dict[str, _Instance] = {}
        self._used_iids: set = set()
        self._pending: collections.deque = collections.deque()
        self._active: Dict[str, _FlightRecord] = {}
        self._requests: Dict[str, Request] = {}
        self._final_stats_expected = 0
        for i, espec in enumerate(cluster.p):
            # fault injection (tests) lands on the first member of a pool
            fault = fault_exit_after_chunks if i == 0 else None
            self._add_member(espec, "P", fault_exit_after_chunks=fault)
        for i, espec in enumerate(cluster.d):
            fault = fault_exit_after_tokens if i == 0 else None
            self._add_member(espec, "D", fault_exit_after_tokens=fault)

    def _add_member(self, espec: EngineSpec, role: str,
                    fault_exit_after_chunks: Optional[int] = None,
                    fault_exit_after_tokens: Optional[int] = None) -> str:
        n = 0
        while f"{role}{n}" in self._used_iids:
            n += 1
        iid = f"{role}{n}"
        self._used_iids.add(iid)
        spec = WorkerSpec(engine=espec, wire=self._wire,
                          codec=self._codec,
                          connector_kwargs=self._ck,
                          prefill_chunk=self._prefill_chunk,
                          prefill_mode=self._prefill_mode,
                          instance_id=iid,
                          jit_cache_dir=self._jit_cache_dir,
                          fault_exit_after_chunks=fault_exit_after_chunks,
                          fault_exit_after_tokens=fault_exit_after_tokens)
        self._instances[iid] = _Instance(
            iid=iid, role=role, spec=spec,
            block_bytes=router.kv_block_bytes(espec.cfg, espec.vendor))
        return iid

    # -- process lifecycle ------------------------------------------------- #
    def start(self, spawn_timeout_s: float = 120.0) -> None:
        self._evt_q = self._ctx.Queue()
        for inst in self._instances.values():
            self._spawn(inst)
        self._await_hello(set(self._instances), spawn_timeout_s)

    def _spawn(self, inst: _Instance) -> None:
        inst.gen += 1
        inst.hello = False
        if inst.gen > 1:
            # a respawn never re-runs the injected fault: one crash only
            inst.spec = dataclasses.replace(inst.spec,
                                            fault_exit_after_chunks=None,
                                            fault_exit_after_tokens=None)
        inst.cmd_q = self._ctx.Queue()
        target = p_worker.p_main if inst.role == "P" else d_worker.d_main
        proc = self._ctx.Process(target=target,
                                 args=(inst.spec, inst.cmd_q, self._evt_q),
                                 daemon=True,
                                 name=f"repro-{inst.iid.lower()}")
        proc.start()
        inst.proc = proc
        inst.last_seen = time.monotonic()

    def _await_hello(self, iids: set, timeout_s: float) -> None:
        deadline = time.monotonic() + timeout_s
        waiting = set(iids)
        while waiting:
            if time.monotonic() > deadline:
                raise RuntimeError(f"worker(s) {sorted(waiting)} did not "
                                   f"start within {timeout_s:.0f}s")
            msg = self._next_event(timeout=0.2)
            if msg is None:
                continue
            self._handle(msg)
            if isinstance(msg, Hello):
                waiting.discard(msg.src)

    def __enter__(self) -> "ClusterRuntime":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- elasticity (autoscaler-facing) ------------------------------------- #
    def add_instance(self, espec: EngineSpec, role: str,
                     wait: bool = True) -> str:
        """Grow the pool by one member; spawns immediately when running.
        ``wait=False`` returns as soon as the process is launched — the
        member becomes routable when its Hello lands (the live-autoscaling
        path: serving must not stall while a new worker imports and
        builds its engine)."""
        if role not in ("P", "D"):
            raise ValueError(f"role must be 'P' or 'D', got {role!r}")
        iid = self._add_member(espec, role)
        if self._evt_q is not None:
            self._spawn(self._instances[iid])
            if wait:
                self._await_hello({iid}, timeout_s=120.0)
        return iid

    def remove_instance(self, iid: str) -> None:
        """Drain a member: stop routing to it; it shuts down once every
        flight referencing it has settled."""
        inst = self._instances.get(iid)
        if inst is None:
            return
        live_same_role = [i for i in self._instances.values()
                          if i.role == inst.role and not i.draining
                          and not i.stopping]
        if len(live_same_role) <= 1:
            raise ValueError(f"cannot drain {iid}: last {inst.role} instance")
        inst.draining = True

    # -- serving ------------------------------------------------------------ #
    def queue_depth(self) -> int:
        """Measured undispatched work: the parent's pending queue plus
        every P's dispatched-but-unprefilled backlog (parent-authoritative
        counters; heartbeats lag the dispatch edge)."""
        return len(self._pending) + sum(i.queue_reqs for i in
                                        self._instances.values()
                                        if i.role == "P")

    def submit(self, req: Request) -> None:
        """Non-blocking enqueue. `is None`, not falsy: an explicit 0.0
        arrival (virtual-clock / epoch-relative schedule) is a legitimate
        timestamp that must survive submit."""
        if req.arrival_time is None:
            req.arrival_time = time.monotonic()
        self._requests[req.req_id] = req
        self._pending.append(req)
        self.stats.submitted += 1

    def reset_latency_measurements(self) -> None:
        """Forget warmup-era latency samples: clear the admission TTFT
        EMA and drop terminal requests from the measured-sample window
        (which feeds the autoscaler's ``recent_ttfts``/``recent_tpots``).
        Call between a warmup pass and a measured run — warmup TTFTs
        include first-use jit compilation and would otherwise bias both
        admission and scaling for the whole run."""
        self.ttft_ema = None
        for rid in [rid for rid, r in self._requests.items()
                    if r.state in (State.FINISHED, State.FAILED,
                                   State.SHED)]:
            del self._requests[rid]

    def try_submit(self, req: Request) -> bool:
        """Admission-controlled non-blocking submit: shed at the door when
        measured queue depth or TTFT-EMA headroom is exhausted
        (``AdmissionConfig``). Shedding happens only here — an admitted
        request is never dropped mid-stream. Returns False (request
        terminal in ``State.SHED``, counted in ``stats.shed``) on shed."""
        if not should_admit(self.admission, self.queue_depth(),
                            self.ttft_ema):
            req.state = State.SHED
            self.stats.shed += 1
            return False
        self.submit(req)
        return True

    def serve(self, requests: List[Request],
              max_wall_s: float = 900.0) -> Dict[str, List[int]]:
        """Drive every request to a terminal state; returns req_id → tokens.

        Closed-loop batch replay: everything is enqueued *now*, so each
        request's TTFT measures from this call (queueing included). For
        arrival-process-driven (open-loop) serving with scheduled arrival
        timestamps, drive ``submit``/``step`` from
        :mod:`repro.serving.loadgen` instead."""
        for r in requests:
            self.submit(r)
        deadline = time.monotonic() + max_wall_s
        while self._unresolved():
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"cluster serve exceeded {max_wall_s:.0f}s with "
                    f"{self._unresolved()} request(s) unresolved")
            self.step(timeout=0.05)
        return {r.req_id: list(r.output_tokens) for r in requests}

    def step(self, timeout: float = 0.05) -> None:
        """One parent-loop iteration: route, police workers, pump events."""
        self._dispatch()
        self._check_workers()
        msg = self._next_event(timeout=timeout)
        if msg is not None:
            self._handle(msg)

    def _unresolved(self) -> int:
        return sum(1 for r in self._requests.values()
                   if r.state not in (State.FINISHED, State.FAILED))

    # -- routing ------------------------------------------------------------- #
    def _routable(self, role: str) -> List[_Instance]:
        # hello gates routing: an instance spawned without waiting
        # (live autoscaling) joins the pool once its worker reports ready
        return [i for i in self._instances.values()
                if i.role == role and i.alive() and i.hello
                and not i.draining and not i.stopping]

    def _p_snapshots(self) -> List[router.PSnapshot]:
        return [router.PSnapshot(i.iid, i.queue_reqs, i.queue_tokens)
                for i in self._routable("P")]

    def _d_snapshots(self, idle: bool = False) -> List[router.DSnapshot]:
        snaps = []
        for i in self._routable("D"):
            e = i.spec.engine
            usable = max(e.num_blocks - 1, 0)     # 1 scratch block reserved
            snaps.append(router.DSnapshot(
                iid=i.iid,
                active=0 if idle else i.active,
                max_batch=e.max_batch,
                free_blocks=usable if idle else usable - i.reserved_blocks,
                block_size=e.vendor.block_size,
                max_blocks_per_seq=-(-e.max_seq_len // e.vendor.block_size),
                max_seq_len=e.max_seq_len,
                block_bytes=i.block_bytes,
                prefix_hashes=i.prefix_hashes))
        return snaps

    def _dispatch(self) -> None:
        """Route as many queued requests as the pools can admit. FIFO with
        head-of-line blocking on D admission — a requeued retry keeps its
        place at the front rather than being starved by fresh arrivals."""
        while self._pending:
            req = self._pending[0]
            if req.state == State.FAILED:
                self._pending.popleft()
                continue
            patches = req.patches.shape[0] if req.patches is not None else 0
            seq_len = req.prompt_len + patches
            p_snaps = self._p_snapshots()
            d_pick = router.pick_d(
                self._d_snapshots(), seq_len, req.max_new_tokens,
                prompt=req.prompt if self._prefix else None)
            if d_pick is None or not p_snaps:
                # nothing can take it *now*; if no D could admit it even
                # idle, it never fits — fail instead of wedging the queue
                if p_snaps and self._routable("D") and router.pick_d(
                        self._d_snapshots(idle=True), seq_len,
                        req.max_new_tokens) is None:
                    self._pending.popleft()
                    req.state = State.FAILED
                    self.stats.failed += 1
                    continue
                return
            self._pending.popleft()
            d_id, need = d_pick
            p_id = router.pick_p(p_snaps)
            p, d = self._instances[p_id], self._instances[d_id]
            req.state = State.PREFILLING
            rec = _FlightRecord(req=req, attempt=req.retries,
                                p_id=p_id, d_id=d_id, p_gen=p.gen,
                                est_tokens=seq_len, need_blocks=need)
            self._active[req.req_id] = rec
            p.queue_reqs += 1
            p.queue_tokens += seq_len
            d.active += 1
            d.reserved_blocks += need
            # FIFO per queue: BeginStream always precedes its ChunkReady
            d.cmd_q.put(BeginStream(req, req.retries, seq_len))
            if self._prefix:
                # hold the prefill until the D reports its resident prefix
                # (StreamAccepted → SubmitPrefill with the wire skip)
                rec.submitted = False
            else:
                p.cmd_q.put(SubmitPrefill(req))

    def _settle_p(self, rec: _FlightRecord) -> None:
        """Drop this flight's contribution to its P's router load (once)."""
        if rec.p_settled:
            return
        rec.p_settled = True
        inst = self._instances.get(rec.p_id)
        if inst is not None:
            inst.queue_reqs = max(inst.queue_reqs - 1, 0)
            inst.queue_tokens = max(inst.queue_tokens - rec.est_tokens, 0)

    def _settle_d(self, rec: _FlightRecord) -> None:
        """Return this flight's slot + paged blocks to its D's router view
        (once)."""
        if rec.d_settled:
            return
        rec.d_settled = True
        inst = self._instances.get(rec.d_id)
        if inst is not None:
            inst.active = max(inst.active - 1, 0)
            inst.reserved_blocks = max(inst.reserved_blocks -
                                       rec.need_blocks, 0)

    # -- event pump ---------------------------------------------------------- #
    def _next_event(self, timeout: float):
        try:
            return self._evt_q.get(timeout=timeout)
        except queue.Empty:
            return None

    def _handle(self, msg: Any) -> None:
        inst = self._instances.get(getattr(msg, "src", ""))
        if inst is not None:
            inst.last_seen = time.monotonic()
        if isinstance(msg, Hello):
            if inst is not None:
                inst.pid = msg.pid
                inst.hello = True
            self.worker_pids[msg.src] = msg.pid
            return
        if isinstance(msg, Heartbeat):
            if inst is not None:
                if inst.role == "P":
                    self._prune_released(inst, msg.ack_seq)
                if msg.load:
                    inst.load = dict(msg.load)
                if msg.prefix_hashes is not None:
                    inst.prefix_hashes = frozenset(msg.prefix_hashes)
            return
        if isinstance(msg, WorkerStats):
            self.transfer_stats.merge(msg.transfer)
            self.worker_stats[msg.src] = msg.engine
            self._final_stats_expected -= 1
            return
        if isinstance(msg, (ChunkStaged, PrefillDone, PrefillFailed)):
            self._handle_p(msg, inst)
            return
        self._handle_d(msg, inst)

    def _rec_for(self, req_id: str, attempt: int) -> Optional[_FlightRecord]:
        rec = self._active.get(req_id)
        if rec is None or rec.attempt != attempt:
            return None
        return rec

    def _prune_released(self, inst: _Instance, ack_seq: int) -> None:
        """Drop the crash-cleanup record of releases this P confirmed."""
        if ack_seq and inst.released:
            inst.released = {s: seg for s, seg in inst.released.items()
                             if s > ack_seq}

    def _release_on(self, inst: Optional[_Instance], key: str,
                    segment: Optional[str] = None) -> None:
        """Tell a P instance it may free a staged key — or, if that
        instance is gone, unlink the OS segment directly (when known)."""
        if inst is not None and inst.alive():
            inst.release_seq += 1
            if segment is not None:
                inst.released[inst.release_seq] = segment
            inst.cmd_q.put(ReleaseStaged(key, inst.release_seq))
        elif segment is not None:
            _unlink_segment(segment)

    def _forward_to_d(self, rec: _FlightRecord, msg: Any) -> None:
        d = self._instances.get(rec.d_id)
        if d is not None and d.alive():
            d.cmd_q.put(msg)
        # a dead D is handled by _on_crash (flight aborted there); dropping
        # the forward here just avoids writing into a dead queue

    def _handle_p(self, msg: Any, inst: Optional[_Instance]) -> None:
        if isinstance(msg, (ChunkStaged, PrefillDone)) and inst is not None:
            self._prune_released(inst, msg.ack_seq)
        if isinstance(msg, ChunkStaged):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:                       # stale attempt: free it
                self._release_on(inst, msg.key, msg.segment)
                return
            rec.outstanding[msg.key] = msg.segment
            rec.segments[msg.key] = msg.segment
            rec.chunk_keys.append(msg.key)
            rec.stage_spans.append(msg.t_stage)
            rec.compute_spans.append(msg.t_compute)
            rec.req.chunks_streamed += 1
            self.stats.chunks_streamed += 1
            self._forward_to_d(rec, ChunkReady(msg.req_id, msg.attempt,
                                               msg.key, msg.segment,
                                               msg.nbytes))
            return
        if isinstance(msg, PrefillDone):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:
                if msg.tail is not None:
                    self._release_on(inst, msg.tail["key"],
                                     msg.tail["segment"])
                return
            rec.prefill_done = True
            self._settle_p(rec)                   # P's queue work is done
            if msg.tail is not None:
                rec.outstanding[msg.tail["key"]] = msg.tail["segment"]
                rec.segments[msg.tail["key"]] = msg.tail["segment"]
            self._forward_to_d(rec, FinalizeStream(msg.req_id, msg.attempt,
                                                   msg.first_token,
                                                   msg.seq_len, msg.tail))
            return
        if isinstance(msg, PrefillFailed):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:
                return
            self._abort_flight(rec, f"P-side dispatch failure: {msg.error}")

    def _handle_d(self, msg: Any, inst: Optional[_Instance]) -> None:
        if isinstance(msg, StreamAccepted):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None or rec.submitted:
                return                            # stale, or cache-off mode
            rec.submitted = True
            p = self._instances.get(rec.p_id)
            if p is not None and p.alive() and p.gen == rec.p_gen:
                p.cmd_q.put(SubmitPrefill(rec.req, msg.wire_skip_tokens))
            else:                                 # P died while we waited
                self._abort_flight(
                    rec, f"P instance {rec.p_id} died before prefill start")
            return
        if isinstance(msg, ChunkRepaged):
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:
                # stale attempt: its abort already released/unlinked every
                # segment it ever staged (complete() is idempotent)
                return
            rec.outstanding.pop(msg.key, None)
            rec.repage_spans[msg.key] = msg.t_repage
            creator = self._instances.get(rec.p_id)
            if creator is not None and creator.gen == rec.p_gen:
                self._release_on(creator, msg.key, rec.segments.get(msg.key))
            else:           # creator died: a release would go to the wrong
                segment = rec.segments.get(msg.key)   # process — unlink
                if segment is not None:
                    _unlink_segment(segment)
            return
        if isinstance(msg, TokenEmitted):
            req = self._requests.get(msg.req_id)
            rec = self._rec_for(msg.req_id, msg.attempt)
            if req is None or rec is None:        # stale attempt's token
                return
            req.output_tokens.append(msg.token)
            req.last_token_time = time.monotonic()
            if msg.first:
                rec.phase = "decode"
                req.state = State.DECODING
                if req.first_token_time is None:
                    req.first_token_time = req.last_token_time
                    ttft = req.ttft()
                    if ttft is not None and self.admission is not None:
                        self.ttft_ema = update_ttft_ema(
                            self.ttft_ema, ttft, self.admission.ema_alpha)
                self.stats.p_dispatches[rec.p_id] += 1
                self.stats.d_dispatches[rec.d_id] += 1
                self._account_flight(rec)
            return
        if isinstance(msg, RequestDone):
            req = self._requests.get(msg.req_id)
            rec = self._rec_for(msg.req_id, msg.attempt)
            if req is None or rec is None:        # stale attempt finishing
                return
            self._active.pop(msg.req_id, None)
            self._settle_p(rec)
            self._settle_d(rec)
            req.state = State.FINISHED
            req.finish_time = time.monotonic()
            self.stats.finished += 1
            return
        if isinstance(msg, StreamFailed):
            self.stream_failures.append((msg.req_id, msg.error))
            rec = self._rec_for(msg.req_id, msg.attempt)
            if rec is None:
                return
            self._abort_flight(rec, msg.error, abort_d=False)

    # -- measured overlap ---------------------------------------------------- #
    def _account_flight(self, rec: _FlightRecord) -> None:
        """Wall-clock handoff accounting for one completed stream: the wire
        interval of chunk *i* is [stage-end_i, repage-start_i]; whatever
        part of it lies under this flight's prefill-compute spans was
        *measured* overlap — true cross-process concurrency, not a model."""
        repaged = [rec.repage_spans.get(k) for k in rec.chunk_keys]
        pairs = [(st, rp) for st, rp in zip(rec.stage_spans, repaged)
                 if rp is not None]
        if not pairs:
            return
        t0 = min(st[0] for st, _ in pairs)
        t1 = max(rp[1] for _, rp in pairs)
        self.transfer_stats.wall_handoff_seconds += t1 - t0
        # chunks can be concurrently in flight, so intersect the *unions*
        # (wire-busy time ∩ compute-busy time) — bounded by the handoff span
        wire = _union([(st[1], max(rp[0], st[1])) for st, rp in pairs])
        compute = _union(rec.compute_spans)
        self.transfer_stats.wall_overlap_seconds += \
            sum(_interval_overlap(w, compute) for w in wire)

    # -- failure handling ----------------------------------------------------- #
    def _abort_flight(self, rec: _FlightRecord, reason: str,
                      abort_d: bool = True) -> None:
        self._active.pop(rec.req.req_id, None)
        self._settle_p(rec)
        self._settle_d(rec)
        if abort_d:
            self._forward_to_d(rec, AbortStream(rec.req.req_id, rec.attempt,
                                                reason))
        p = self._instances.get(rec.p_id)
        if p is not None and p.alive() and p.gen == rec.p_gen:
            for key, segment in rec.outstanding.items():
                self._release_on(p, key, segment)
        else:
            # the staging process is gone (or already replaced): releases
            # would go nowhere — unlink every segment this attempt ever
            # staged (idempotent for the ones P freed before dying)
            for segment in rec.segments.values():
                _unlink_segment(segment)
        rec.outstanding.clear()
        self._requeue(rec.req)

    def _requeue(self, req: Request) -> None:
        if requeue_for_retry(req, self.stats, self.transfer_stats,
                             self.max_retries):
            self._pending.appendleft(req)

    def _check_workers(self) -> None:
        now = time.monotonic()
        for inst in list(self._instances.values()):
            if inst.proc is None:
                continue
            if inst.draining and not inst.stopping and inst.alive() \
                    and not self._references(inst):
                inst.cmd_q.put(Shutdown())
                inst.stopping = True
                continue
            if not inst.alive():
                if inst.stopping:                 # drained: a clean exit
                    inst.proc.join(timeout=5.0)
                    self._instances.pop(inst.iid, None)
                    continue
                self._on_crash(inst, f"exited with code {inst.proc.exitcode}")
                continue
            if now - inst.last_seen > self.stall_timeout_s:
                inst.proc.terminate()             # hung, not dead: make it dead
                inst.proc.join(timeout=5.0)
                self._on_crash(inst, "stalled past watchdog timeout")

    def _references(self, inst: _Instance) -> bool:
        """Does any live flight (or unconfirmed release) still need this
        instance? Gates draining shutdown."""
        if inst.role == "P":
            return bool(inst.released) or any(
                r.p_id == inst.iid for r in self._active.values())
        return any(r.d_id == inst.iid for r in self._active.values())

    def _drain_backlog(self) -> None:
        while True:
            msg = self._next_event(timeout=0.1)
            if msg is None:
                break
            self._handle(msg)

    def _on_crash(self, inst: _Instance, why: str) -> None:
        self.crashes[inst.role] += 1
        self.instance_crashes[inst.iid] = \
            self.instance_crashes.get(inst.iid, 0) + 1
        inst.proc.join(timeout=5.0)
        if inst.role == "P":
            # prefill-phase flights whose stream never fully left this P
            # are void: abort the D reservation, unlink the dead attempt's
            # stranded segments, requeue. Flights past PrefillDone are
            # wholly on D's side — let them finish (a lost segment there
            # surfaces as StreamFailed → requeue) rather than requeue a
            # stream D may already be decoding, which would double-serve.
            # Abort BEFORE draining the dying worker's flushed backlog, so
            # its ChunkStaged events hit the stale path (unlinking their
            # stranded segments) instead of being recorded as live chunks.
            for rec in [r for r in self._active.values()
                        if r.p_id == inst.iid and r.phase == "prefill"
                        and not r.prefill_done]:
                self._abort_flight(
                    rec, f"P instance {inst.iid} died mid-stream ({why})")
            # releases queued to the dead P were never processed: unlink
            # those segments directly (no-op for any it freed in time)
            for segment in inst.released.values():
                _unlink_segment(segment)
            inst.released.clear()
            inst.queue_reqs = inst.queue_tokens = 0
            self._drain_backlog()
        else:
            # drain the dying D's flushed backlog FIRST: tokens and
            # completions it emitted before exiting are real — a stream
            # whose RequestDone is sitting in the backlog must finish,
            # not be requeued (which would decode past max_new_tokens)
            self._drain_backlog()
            # this instance's volatile KV died with it: every non-terminal
            # request it was serving restarts from prefill with its
            # generated prefix appended — other D's streams are untouched
            for rec in [r for r in self._active.values()
                        if r.d_id == inst.iid]:
                self._abort_flight(rec, f"D instance {inst.iid} died ({why})",
                                   abort_d=False)
            inst.active = inst.reserved_blocks = 0
        survivors = [i for i in self._instances.values()
                     if i.role == inst.role and i.iid != inst.iid
                     and i.alive() and not i.draining and not i.stopping]
        if survivors:
            # the pool still has live members: the aborted flights simply
            # re-route there on the next dispatch — no respawn, no stall
            self._instances.pop(inst.iid, None)
            return
        if self._unresolved() == 0:
            self._instances.pop(inst.iid, None)
            return
        if self.crashes[inst.role] > self.max_respawns:
            self._instances.pop(inst.iid, None)
            for r in self._requests.values():
                if r.state not in (State.FINISHED, State.FAILED):
                    r.state = State.FAILED
                    self.stats.failed += 1
            return
        # pool emptied: only now does serving block on a respawn
        self.respawns[inst.role] += 1
        self._spawn(inst)
        self._await_hello({inst.iid}, timeout_s=120.0)

    # -- shutdown -------------------------------------------------------------- #
    def shutdown(self, timeout_s: float = 15.0) -> None:
        """Stop every worker, escalating join → terminate → kill on a
        bounded timeout, then unlink any segment the parent ever learned
        about — a hung worker can leave neither zombies nor stranded
        /dev/shm segments behind this call."""
        if self._evt_q is None:
            return                                # never started / already down
        self._final_stats_expected = 0
        for inst in self._instances.values():
            if inst.alive():
                inst.cmd_q.put(Shutdown())
                self._final_stats_expected += 1
        deadline = time.monotonic() + timeout_s
        while self._final_stats_expected > 0 and time.monotonic() < deadline:
            msg = self._next_event(timeout=0.2)
            if msg is not None:
                self._handle(msg)
        for inst in self._instances.values():
            proc = inst.proc
            if proc is None:
                continue
            proc.join(timeout=5.0)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)
            if proc.is_alive():
                proc.kill()                       # SIGKILL: cannot be ignored
                proc.join(timeout=5.0)
        # workers that exited cleanly unlinked their own staging
        # (connector.close()); for any that had to be terminated/killed,
        # unlink everything the parent knows about (idempotent elsewhere)
        for inst in self._instances.values():
            for segment in inst.released.values():
                _unlink_segment(segment)
            inst.released.clear()
        for rec in self._active.values():
            for segment in rec.segments.values():
                _unlink_segment(segment)
        # drain stragglers (late WorkerStats still merge), then close the
        # queues so no feeder thread outlives the runtime
        while True:
            msg = self._next_event(timeout=0.05)
            if msg is None:
                break
            if isinstance(msg, WorkerStats):
                self._handle(msg)
        for inst in self._instances.values():
            if inst.cmd_q is not None:
                inst.cmd_q.close()
                inst.cmd_q.cancel_join_thread()
                inst.cmd_q = None
            inst.proc = None
        self._evt_q.close()
        self._evt_q.cancel_join_thread()
        self._evt_q = None


class TwoProcessRuntime(ClusterRuntime):
    """1 P-process + 1 D-process: the degenerate cluster, kept as the
    compatibility entry point (instance ids ``P0`` / ``D0``)."""

    def __init__(self, p_spec: EngineSpec, d_spec: EngineSpec, **kw):
        super().__init__(ClusterSpec(p=(p_spec,), d=(d_spec,)), **kw)


def serve_cluster(cluster: ClusterSpec, requests: List[Request], **kw
                  ) -> Tuple[Dict[str, List[int]], ClusterRuntime]:
    """One-shot convenience: start → serve → shutdown. Returns the token
    streams and the (shut-down) runtime for stats inspection."""
    max_wall_s = kw.pop("max_wall_s", 900.0)
    rt = ClusterRuntime(cluster, **kw)
    rt.start()
    try:
        tokens = rt.serve(requests, max_wall_s=max_wall_s)
    finally:
        rt.shutdown()
    return tokens, rt


def serve_two_process(p_spec: EngineSpec, d_spec: EngineSpec,
                      requests: List[Request], **kw
                      ) -> Tuple[Dict[str, List[int]], TwoProcessRuntime]:
    """One-shot convenience for the 1P+1D degenerate cluster."""
    max_wall_s = kw.pop("max_wall_s", 900.0)
    rt = TwoProcessRuntime(p_spec, d_spec, **kw)
    rt.start()
    try:
        tokens = rt.serve(requests, max_wall_s=max_wall_s)
    finally:
        rt.shutdown()
    return tokens, rt
