"""Control-plane protocol of the two-process P/D serving runtime.

Everything here crosses an OS process boundary through
``multiprocessing`` queues, so it is all plain picklable data:

  * :class:`EngineSpec` — how a worker process rebuilds its model
    instance (config + vendor profile + a parameter seed; parameters are
    re-initialized deterministically in the worker instead of being
    shipped over the wire).
  * :class:`WorkerSpec` — one worker's full recipe: engine, wire format,
    KV-connector kwargs, chunking, heartbeat cadence, fault injection.
  * message dataclasses — the control plane proper. The *data* plane
    (KV bytes) never rides these queues: chunks move through
    ``SharedMemoryConnector`` segments, and the control plane only carries
    the segment descriptors (:func:`SharedMemoryConnector.export_descriptor`).

Wire protocol (parent = launcher, P = prefill worker, D = decode worker):

  parent→P   SubmitPrefill · ReleaseStaged · Shutdown
  P→parent   Hello · ChunkStaged · PrefillDone · PrefillFailed ·
             Heartbeat · WorkerStats
  parent→D   BeginStream · ChunkReady · FinalizeStream · AbortStream ·
             Shutdown
  D→parent   Hello · ChunkRepaged · TokenEmitted · RequestDone ·
             StreamFailed · Heartbeat · WorkerStats

Every per-request message carries ``attempt`` (the request's retry
counter at dispatch) so a crashed attempt's stale messages can never be
attributed to its requeued successor.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.compat.precision import WireFormat
from repro.serving.engine import VendorProfile
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Recipe for building one Engine inside a worker process."""
    name: str
    cfg: ModelConfig
    vendor: VendorProfile
    params_seed: int = 0
    num_blocks: int = 256
    max_batch: int = 8
    max_seq_len: int = 512
    role: str = "both"

    def build(self):
        """Materialize the engine (worker-side only: imports jax)."""
        import jax

        from repro.models import model as M
        from repro.serving.engine import Engine
        params = M.init_params(jax.random.key(self.params_seed), self.cfg)
        return Engine(self.name, self.cfg, params, self.vendor,
                      num_blocks=self.num_blocks, max_batch=self.max_batch,
                      max_seq_len=self.max_seq_len, role=self.role)


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Everything one worker process needs, shipped through spawn()."""
    engine: EngineSpec
    wire: WireFormat
    connector_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    prefill_chunk: Optional[int] = 16
    heartbeat_s: float = 0.5
    # fault injection (tests): P exits hard (os._exit) after staging this
    # many chunks — the "process dies without drop()" conformance path
    fault_exit_after_chunks: Optional[int] = None


# --------------------------------------------------------------------- #
# parent → P
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SubmitPrefill:
    req: Request


@dataclasses.dataclass(frozen=True)
class ReleaseStaged:
    """D consumed a chunk: the staging segment's creator may free it.
    ``seq`` is the parent's monotone release counter; P piggybacks the
    highest seq it has *processed* on its next message home (``ack_seq``),
    letting the parent prune its crash-cleanup record of unconfirmed
    releases without any clear-on-heartbeat race."""
    key: str
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class Shutdown:
    pass


# --------------------------------------------------------------------- #
# parent → D
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BeginStream:
    """Reserve a decode slot + paged blocks for an incoming handoff."""
    req: Request
    attempt: int
    seq_len: int


@dataclasses.dataclass(frozen=True)
class ChunkReady:
    """A staged chunk's shared-memory descriptor: adopt + issue_read."""
    req_id: str
    attempt: int
    key: str
    segment: str
    nbytes: int


@dataclasses.dataclass(frozen=True)
class FinalizeStream:
    """All chunks staged: once every pending read re-paged, ship the tail
    (states/cross, if any), activate the slot, emit the first token."""
    req_id: str
    attempt: int
    first_token: int
    seq_len: int
    tail: Optional[Dict[str, Any]]       # export_descriptor of the tail key


@dataclasses.dataclass(frozen=True)
class AbortStream:
    """P-side failure: drop pending reads and free the reservation."""
    req_id: str
    attempt: int
    reason: str = ""


# --------------------------------------------------------------------- #
# workers → parent
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Hello:
    src: str                              # "P" | "D"
    pid: int
    engine_name: str


@dataclasses.dataclass(frozen=True)
class Heartbeat:
    src: str
    ack_seq: int = 0                      # P only: highest release processed


@dataclasses.dataclass(frozen=True)
class ChunkStaged:
    """P staged one chunk. Carries the shared-memory descriptor (for the
    parent to forward to D) plus wall-clock stage/compute intervals
    (time.monotonic — comparable across processes on one host) for the
    launcher's measured-overlap accounting."""
    req_id: str
    attempt: int
    index: int
    key: str
    segment: str
    nbytes: int
    t_stage: Tuple[float, float]
    t_compute: Tuple[float, float]
    ack_seq: int = 0                      # highest ReleaseStaged processed


@dataclasses.dataclass(frozen=True)
class PrefillDone:
    req_id: str
    attempt: int
    first_token: int
    seq_len: int
    chunks: int
    tail: Optional[Dict[str, Any]]
    ack_seq: int = 0                      # highest ReleaseStaged processed


@dataclasses.dataclass(frozen=True)
class PrefillFailed:
    req_id: str
    attempt: int
    error: str


@dataclasses.dataclass(frozen=True)
class ChunkRepaged:
    """D re-paged one chunk (or the tail) into its pools."""
    req_id: str
    attempt: int
    key: str
    t_repage: Tuple[float, float]


@dataclasses.dataclass(frozen=True)
class TokenEmitted:
    req_id: str
    token: int
    attempt: int
    first: bool = False


@dataclasses.dataclass(frozen=True)
class RequestDone:
    req_id: str
    attempt: int


@dataclasses.dataclass(frozen=True)
class StreamFailed:
    """D surfaced a transfer failure (lost segment, adopt failure, abort
    of an in-flight stream) — the scheduler side must requeue."""
    req_id: str
    attempt: int
    error: str


@dataclasses.dataclass(frozen=True)
class WorkerStats:
    """Final accounting a worker ships home at shutdown."""
    src: str
    transfer: Any                         # core.transport.TransferStats
    engine: Dict[str, float]              # EngineStats.as_dict()
