"""Control-plane protocol of the multi-process P/D serving runtime.

Everything here crosses an OS process boundary through
``multiprocessing`` queues, so it is all plain picklable data:

  * :class:`EngineSpec` — how a worker process rebuilds its model
    instance (config + vendor profile + a parameter seed; parameters are
    re-initialized deterministically in the worker instead of being
    shipped over the wire).
  * :class:`ClusterSpec` — an executable N×M topology: the planner's
    instance allocation (``DeploymentPlan.to_cluster_spec``) in
    launchable form.
  * :class:`WorkerSpec` — one worker's full recipe: engine, wire format,
    KV-connector kwargs, chunking, heartbeat cadence, fault injection.
  * message dataclasses — the control plane proper. The *data* plane
    (KV bytes) never rides these queues: chunks move through
    ``SharedMemoryConnector`` segments, and the control plane only carries
    the segment descriptors (:func:`SharedMemoryConnector.export_descriptor`).

Wire protocol (parent = launcher/router, P = a prefill worker, D = a
decode worker — N of the former, M of the latter):

  parent→P   SubmitPrefill · ReleaseStaged · Shutdown
  P→parent   Hello · ChunkStaged · PrefillDone · PrefillFailed ·
             Heartbeat · WorkerStats
  parent→D   BeginStream · ChunkReady · FinalizeStream · AbortStream ·
             Shutdown
  D→parent   Hello · StreamAccepted · ChunkRepaged · TokenEmitted ·
             RequestDone · StreamFailed · Heartbeat · WorkerStats

Every worker→parent message is *instance-addressed*: ``src`` carries the
instance id (``"P0"``, ``"D1"``, …) so the parent's router can attribute
it to the right member of the pool — and every per-request message
carries ``attempt`` (the request's retry counter at dispatch) so a
crashed attempt's stale messages can never be attributed to its requeued
successor. Heartbeats additionally carry a ``load`` snapshot (P: backlog
depth / estimated queued prefill tokens; D: occupied slots / free paged
blocks / free KV-pool bytes) — the measured feed for the router and the
autoscaler.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.compat.precision import WireFormat
from repro.serving.engine import VendorProfile
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Recipe for building one Engine inside a worker process."""
    name: str
    cfg: ModelConfig
    vendor: VendorProfile
    params_seed: int = 0
    num_blocks: int = 256
    max_batch: int = 8
    max_seq_len: int = 512
    role: str = "both"
    prefix_cache: bool = False
    mem_len: int = 0                # encoder memory positions (enc-dec)

    def build(self):
        """Materialize the engine (worker-side only: imports jax)."""
        import jax

        from repro.models import model as M
        from repro.serving.engine import Engine
        params = M.init_params(jax.random.key(self.params_seed), self.cfg)
        return Engine(self.name, self.cfg, params, self.vendor,
                      num_blocks=self.num_blocks, max_batch=self.max_batch,
                      max_seq_len=self.max_seq_len, role=self.role,
                      prefix_cache=self.prefix_cache, mem_len=self.mem_len)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """An executable N×M deployment: N prefill + M decode EngineSpecs
    (heterogeneous vendors allowed — the paper's multi-vendor setting).
    This is what ``DeploymentPlan.to_cluster_spec()`` emits and what
    ``ClusterRuntime`` launches."""
    p: Tuple[EngineSpec, ...]
    d: Tuple[EngineSpec, ...]

    def __post_init__(self):
        object.__setattr__(self, "p", tuple(self.p))
        object.__setattr__(self, "d", tuple(self.d))
        if not self.p or not self.d:
            raise ValueError("ClusterSpec needs at least one prefill and "
                             "one decode instance")
        names = [e.name for e in self.p + self.d]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate instance names in cluster: {names}")

    def ratio(self) -> str:
        return f"{len(self.p)}P{len(self.d)}D"


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Everything one worker process needs, shipped through spawn()."""
    engine: EngineSpec
    wire: WireFormat
    connector_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # chunk wire codec both ends must agree on ("fixed" zero-copy segments
    # or the legacy "pickle" blob)
    codec: str = "fixed"
    prefill_chunk: Optional[int] = 16
    # prefill mode name ("auto" | "incremental" | "monolithic") resolved to
    # repro.serving.engine.PrefillMode inside the worker process — shipped
    # as a string so the spec stays picklable without an engine import
    prefill_mode: str = "auto"
    heartbeat_s: float = 0.5
    # persistent XLA compilation-cache dir shared by every worker process
    # on this host (None disables): N workers compile each jit program
    # once, not N times — see launcher.default_jit_cache_dir
    jit_cache_dir: Optional[str] = None
    # instance id on the control plane (defaults to the engine name; the
    # launcher keeps them unique across the pool)
    instance_id: str = ""
    # fault injection (tests): P exits hard (os._exit) after staging this
    # many chunks — the "process dies without drop()" conformance path
    fault_exit_after_chunks: Optional[int] = None
    # fault injection (tests): D exits hard after emitting this many
    # tokens — the "decode node dies mid-stream, volatile KV lost" path
    fault_exit_after_tokens: Optional[int] = None

    @property
    def iid(self) -> str:
        return self.instance_id or self.engine.name


# --------------------------------------------------------------------- #
# parent → P
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SubmitPrefill:
    req: Request
    # tokens already resident on the stream's D (prefix cache): the P
    # worker computes/replays them but never stages them on the wire
    wire_skip_tokens: int = 0


@dataclasses.dataclass(frozen=True)
class ReleaseStaged:
    """D consumed a chunk: the staging segment's creator may free it.
    ``seq`` is the parent's monotone per-instance release counter; the P
    instance piggybacks the highest seq it has *processed* on its next
    message home (``ack_seq``), letting the parent prune its
    crash-cleanup record of unconfirmed releases without any
    clear-on-heartbeat race."""
    key: str
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class Shutdown:
    pass


# --------------------------------------------------------------------- #
# parent → D
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BeginStream:
    """Reserve a decode slot + paged blocks for an incoming handoff."""
    req: Request
    attempt: int
    seq_len: int


@dataclasses.dataclass(frozen=True)
class ChunkReady:
    """A staged chunk's shared-memory descriptor: adopt + issue_read."""
    req_id: str
    attempt: int
    key: str
    segment: str
    nbytes: int


@dataclasses.dataclass(frozen=True)
class FinalizeStream:
    """All chunks staged: once every pending read re-paged, ship the tail
    (states/cross, if any), activate the slot, emit the first token."""
    req_id: str
    attempt: int
    first_token: int
    seq_len: int
    tail: Optional[Dict[str, Any]]       # export_descriptor of the tail key


@dataclasses.dataclass(frozen=True)
class AbortStream:
    """P-side failure: drop pending reads and free the reservation."""
    req_id: str
    attempt: int
    reason: str = ""


# --------------------------------------------------------------------- #
# workers → parent (all instance-addressed via ``src``)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Hello:
    src: str                              # instance id ("P0", "D1", …)
    pid: int
    engine_name: str
    role: str = ""                        # "P" | "D"


@dataclasses.dataclass(frozen=True)
class Heartbeat:
    """Liveness + measured load. ``load`` is the worker's own view:

      P: ``backlog`` (queued prefills), ``backlog_tokens`` (estimated
         prompt tokens waiting)
      D: ``active`` (occupied slots), ``free_slots``, ``free_blocks``,
         ``free_bytes`` (free KV-pool bytes), ``pending_repage``
    """
    src: str
    ack_seq: int = 0                      # P only: highest release processed
    load: Optional[Dict[str, float]] = None
    # D only: the prefix store's digest summary (chained block hashes) —
    # the parent router scores prefix affinity against it. None when the
    # cache is disabled; a tuple (possibly empty) when enabled.
    prefix_hashes: Optional[Tuple[str, ...]] = None


@dataclasses.dataclass(frozen=True)
class ChunkStaged:
    """P staged one chunk. Carries the shared-memory descriptor (for the
    parent to forward to the stream's D) plus wall-clock stage/compute
    intervals (time.monotonic — comparable across processes on one host)
    for the launcher's measured-overlap accounting."""
    req_id: str
    attempt: int
    index: int
    key: str
    segment: str
    nbytes: int
    t_stage: Tuple[float, float]
    t_compute: Tuple[float, float]
    ack_seq: int = 0                      # highest ReleaseStaged processed
    src: str = ""


@dataclasses.dataclass(frozen=True)
class PrefillDone:
    req_id: str
    attempt: int
    first_token: int
    seq_len: int
    chunks: int
    tail: Optional[Dict[str, Any]]
    ack_seq: int = 0                      # highest ReleaseStaged processed
    src: str = ""


@dataclasses.dataclass(frozen=True)
class PrefillFailed:
    req_id: str
    attempt: int
    error: str
    src: str = ""


@dataclasses.dataclass(frozen=True)
class StreamAccepted:
    """D reserved the stream and reports how many leading prompt tokens
    its prefix store already holds. In prefix-cache mode the parent
    defers ``SubmitPrefill`` until this arrives so the P worker knows
    exactly which chunks to keep off the wire."""
    req_id: str
    attempt: int
    wire_skip_tokens: int = 0
    src: str = ""


@dataclasses.dataclass(frozen=True)
class ChunkRepaged:
    """D re-paged one chunk (or the tail) into its pools."""
    req_id: str
    attempt: int
    key: str
    t_repage: Tuple[float, float]
    src: str = ""


@dataclasses.dataclass(frozen=True)
class TokenEmitted:
    req_id: str
    token: int
    attempt: int
    first: bool = False
    src: str = ""


@dataclasses.dataclass(frozen=True)
class RequestDone:
    req_id: str
    attempt: int
    src: str = ""


@dataclasses.dataclass(frozen=True)
class StreamFailed:
    """D surfaced a transfer failure (lost segment, adopt failure, abort
    of an in-flight stream) — the scheduler side must requeue."""
    req_id: str
    attempt: int
    error: str
    src: str = ""


@dataclasses.dataclass(frozen=True)
class WorkerStats:
    """Final accounting a worker ships home at shutdown."""
    src: str
    transfer: Any                         # core.transport.TransferStats
    engine: Dict[str, float]              # EngineStats.as_dict()
