"""Plan-vs-measured reporting for the cluster runtime.

The planner's joint optimization (``plan_deployment``) predicts a
topology — instance counts, per-instance capacity, stage latencies —
from closed-form models. The cluster runtime *measures* the same
quantities while serving: per-instance dispatch counts, heartbeat load
snapshots, worker engine/transfer stats, request TTFTs. This module puts
the two side by side so the joint optimization can be validated against
the running system, and quantifies how evenly the router spread work
(the utilization-imbalance metric the router benchmark tracks).

Stdlib-only and duck-typed over the runtime: it reads the attributes
``ClusterRuntime`` exposes (``stats``, ``worker_stats``,
``transfer_stats``, ``crashes``, ``respawns``) without importing it, so
the planner layer can consume reports without a serving-layer import.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not values:
        return 0.0
    xs = sorted(values)
    idx = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[idx]


def imbalance(counts: Dict[str, int]) -> float:
    """(max − min) / mean over per-instance work counts — 0.0 means the
    router spread work perfectly evenly, 2.0 (for 2 instances) means one
    instance did everything."""
    if not counts:
        return 0.0
    vals = list(counts.values())
    mean = sum(vals) / len(vals)
    if mean <= 0:
        return 0.0
    return (max(vals) - min(vals)) / mean


def ttfts_s(requests: List[Any]) -> List[float]:
    """Measured time-to-first-token per finished request."""
    out = []
    for r in requests:
        if r.first_token_time is not None and r.arrival_time is not None:
            out.append(r.first_token_time - r.arrival_time)
    return out


def tpots_s(requests: List[Any]) -> List[float]:
    """Measured per-output-token latency per finished request."""
    return [t for t in (r.tpot() for r in requests) if t is not None]


def goodput_rps(requests: List[Any], wall_s: float,
                slo_ttft_s: Optional[float] = None,
                slo_tpot_s: Optional[float] = None) -> float:
    """Finished requests that met *every* configured SLO, per second —
    the metric an open-loop run optimizes (raw throughput counts
    SLO-violating responses nobody would wait for)."""
    if not wall_s:
        return 0.0
    good = 0
    for r in requests:
        if r.finish_time is None:
            continue
        ttft, tpot = r.ttft(), r.tpot()
        if slo_ttft_s is not None and (ttft is None or ttft > slo_ttft_s):
            continue
        if slo_tpot_s is not None and (tpot is None or tpot > slo_tpot_s):
            continue
        good += 1
    return good / wall_s


def slo_section(requests: List[Any], wall_s: float,
                slo_ttft_s: Optional[float] = None,
                slo_tpot_s: Optional[float] = None) -> Dict[str, Any]:
    """Latency-distribution + goodput summary of an open-loop run: TTFT
    and TPOT at p50/p95/p99 over the *scheduled-arrival* accounting, and
    goodput under the configured SLOs."""
    tt, tp = ttfts_s(requests), tpots_s(requests)
    sec: Dict[str, Any] = {
        "wall_s": wall_s,
        "ttft_p50_s": percentile(tt, 50),
        "ttft_p95_s": percentile(tt, 95),
        "ttft_p99_s": percentile(tt, 99),
        "tpot_p50_s": percentile(tp, 50),
        "tpot_p95_s": percentile(tp, 95),
        "tpot_p99_s": percentile(tp, 99),
    }
    if slo_ttft_s is not None or slo_tpot_s is not None:
        sec["slo"] = {"ttft_s": slo_ttft_s, "tpot_s": slo_tpot_s}
        sec["goodput_rps"] = goodput_rps(requests, wall_s,
                                         slo_ttft_s, slo_tpot_s)
    return sec


def measured_section(runtime: Any, requests: List[Any],
                     wall_s: Optional[float] = None) -> Dict[str, Any]:
    """What the cluster actually did, per instance and in aggregate."""
    p_disp = dict(runtime.stats.p_dispatches)
    d_disp = dict(runtime.stats.d_dispatches)
    tt = ttfts_s(requests)
    sec: Dict[str, Any] = {
        "n_prefill": len(p_disp),
        "n_decode": len(d_disp),
        "submitted": runtime.stats.submitted,
        "finished": runtime.stats.finished,
        "failed": runtime.stats.failed,
        "shed": getattr(runtime.stats, "shed", 0),
        "requeues": runtime.stats.requeues,
        "crashes": dict(runtime.crashes),
        "respawns": dict(getattr(runtime, "respawns", {})),
        "p_dispatches": p_disp,
        "d_dispatches": d_disp,
        "p_imbalance": imbalance(p_disp),
        "d_imbalance": imbalance(d_disp),
        "ttft_p50_s": percentile(tt, 50),
        "ttft_p95_s": percentile(tt, 95),
        "worker_stats": dict(runtime.worker_stats),
        "transfer": {
            "chunks": runtime.transfer_stats.chunks,
            "retries": runtime.transfer_stats.retries,
            "wall_handoff_seconds":
                runtime.transfer_stats.wall_handoff_seconds,
            "wall_overlap_seconds":
                runtime.transfer_stats.wall_overlap_seconds,
            "prefix_hit_tokens": runtime.transfer_stats.prefix_hit_tokens,
            "bytes_saved": runtime.transfer_stats.bytes_saved,
            # wire vs raw payload bytes: the int8 wire's compression and
            # the fixed-layout format's header overhead both show up here
            "wire_bytes": runtime.transfer_stats.bytes_moved,
            "payload_bytes": runtime.transfer_stats.payload_bytes,
            "wire_compression": runtime.transfer_stats.wire_compression,
            # link congestion: modeled fair-share delay plus the measured
            # read wall time delivered under concurrency
            "congested_seconds": runtime.transfer_stats.congested_seconds,
            "contended_read_seconds":
                runtime.transfer_stats.contended_read_seconds,
            "concurrent_reads_peak":
                runtime.transfer_stats.concurrent_reads_peak,
        },
    }
    # integrated-baseline honesty metrics, aggregated over workers:
    # prefill seconds that stalled decode-ready work on role="both"
    # engines (the interference disaggregation removes — ~0 on a disagg
    # topology), requests that silently could not use resume/replay, and
    # prompt tokens recovered from mid-stream snapshots after failures
    ws = runtime.worker_stats.values()
    sec["contention_stall_seconds"] = sum(
        w.get("contention_stall_seconds", 0.0) for w in ws)
    sec["resume_unsupported"] = int(sum(
        w.get("resume_unsupported", 0) for w in ws))
    sec["resumed_tokens"] = int(sum(
        w.get("resumed_tokens", 0) for w in ws))
    # measured prefix-cache hit ratio: wire tokens skipped over prompt
    # tokens submitted — the honest counterpart of the planner's assumed
    # FrameworkModel.prefix_cache_hit
    prompt_tokens = sum(getattr(r, "prompt_len", 0) for r in requests)
    sec["prefix_hit_ratio"] = (
        runtime.transfer_stats.prefix_hit_tokens / prompt_tokens
        if prompt_tokens else 0.0)
    if wall_s:
        sec["wall_s"] = wall_s
        sec["measured_qps"] = runtime.stats.finished / wall_s
    return sec


def plan_section(plan: Any) -> Dict[str, Any]:
    """The planner's predictions, in the same units as the measurement."""
    return {
        "model": plan.model,
        "ratio": plan.ratio(),
        "n_prefill": plan.n_prefill,
        "n_decode": plan.n_decode,
        "p_hw": plan.p_hw,
        "d_hw": plan.d_hw,
        "predicted_ttft_s": plan.prefill.latency_s,
        "predicted_tpot_s": plan.decode.latency_s,
        "p_instance_qps": plan.prefill.instance_capacity,
        "d_instance_qps": plan.decode.instance_capacity,
        "qps_capacity": plan.qps_capacity,
        "cost_per_hour": plan.cost_per_hour,
    }


def plan_vs_measured(runtime: Any, requests: List[Any],
                     plan: Any = None,
                     wall_s: Optional[float] = None,
                     sim_summary: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
    """Full post-run report: measured cluster behaviour, optionally laid
    against the ``DeploymentPlan`` that launched it (with deltas where
    the two describe the same quantity). ``sim_summary`` — a
    ``SimResult.summary()`` dict from the event sim run in the same mode
    (disagg/integrated) — adds the modeled-vs-measured decode-stall
    comparison for the integrated baseline."""
    rep: Dict[str, Any] = {"measured": measured_section(runtime, requests,
                                                        wall_s)}
    if plan is not None:
        rep["plan"] = plan_section(plan)
        m = rep["measured"]
        rep["deltas"] = {
            "n_prefill": m["n_prefill"] - plan.n_prefill,
            "n_decode": m["n_decode"] - plan.n_decode,
            "ttft_p50_vs_predicted_s":
                m["ttft_p50_s"] - plan.prefill.latency_s,
        }
        if "measured_qps" in m:
            rep["deltas"]["qps_vs_capacity"] = \
                m["measured_qps"] - plan.qps_capacity
    if sim_summary is not None and "contention_stall_s" in sim_summary:
        rep["sim"] = dict(sim_summary)
        rep.setdefault("deltas", {})["contention_stall_vs_modeled_s"] = \
            rep["measured"]["contention_stall_seconds"] - \
            sim_summary["contention_stall_s"]
    return rep


def format_report(rep: Dict[str, Any]) -> str:
    """Readable multi-line rendering for CLI output."""
    m = rep["measured"]
    lines = ["== measured ==",
             f"  topology     {m['n_prefill']}P{m['n_decode']}D"
             f"  finished {m['finished']}/{m['submitted']}"
             f"  requeues {m['requeues']}"
             f"  crashes P={m['crashes'].get('P', 0)}"
             f" D={m['crashes'].get('D', 0)}",
             f"  ttft         p50 {m['ttft_p50_s'] * 1e3:.1f} ms"
             f"  p95 {m['ttft_p95_s'] * 1e3:.1f} ms",
             f"  p dispatches {m['p_dispatches']}"
             f"  (imbalance {m['p_imbalance']:.2f})",
             f"  d dispatches {m['d_dispatches']}"
             f"  (imbalance {m['d_imbalance']:.2f})"]
    if m["transfer"].get("wire_bytes"):
        t = m["transfer"]
        lines.append(
            f"  wire         {t['wire_bytes']} B moved for "
            f"{t['payload_bytes']} B of KV "
            f"(ratio {t['wire_compression']:.2f}, "
            f"peak {t['concurrent_reads_peak']} concurrent reads, "
            f"{t['contended_read_seconds'] * 1e3:.1f} ms read under "
            f"contention)")
    if m["transfer"].get("prefix_hit_tokens"):
        lines.append(
            f"  prefix cache {m['transfer']['prefix_hit_tokens']} wire "
            f"tokens skipped (hit ratio {m['prefix_hit_ratio']:.2f}, "
            f"{m['transfer']['bytes_saved']} B saved)")
    if m.get("contention_stall_seconds"):
        lines.append(
            f"  contention   {m['contention_stall_seconds'] * 1e3:.1f} ms "
            f"decode stalled behind prefill (integrated baseline)")
    if m.get("resumed_tokens") or m.get("resume_unsupported"):
        lines.append(
            f"  resume       {m.get('resumed_tokens', 0)} tokens recovered "
            f"from snapshots, {m.get('resume_unsupported', 0)} requests "
            f"fell back to full recompute")
    if "measured_qps" in m:
        lines.append(f"  throughput   {m['measured_qps']:.2f} req/s "
                     f"over {m['wall_s']:.1f} s")
    if "plan" in rep:
        p = rep["plan"]
        lines += ["== planned ==",
                  f"  topology     {p['ratio']}  ({p['p_hw']} → {p['d_hw']})",
                  f"  ttft         {p['predicted_ttft_s'] * 1e3:.1f} ms"
                  f"  capacity {p['qps_capacity']:.2f} req/s"
                  f"  cost ${p['cost_per_hour']:.2f}/h"]
        d = rep["deltas"]
        lines.append(f"== deltas ==\n  n_p {d['n_prefill']:+d}"
                     f"  n_d {d['n_decode']:+d}"
                     f"  ttft_p50 {d['ttft_p50_vs_predicted_s'] * 1e3:+.1f} ms")
    return "\n".join(lines)
