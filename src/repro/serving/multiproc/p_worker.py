"""P-instance worker process: one prefill member of the cluster runtime.

Runs the same protocol as the in-process ``PrefillFlightLoop``, but as a
real OS event loop: receive a request, drive its ``PrefillStream`` chunk
by chunk, encode each chunk through the ``DisaggPipeline`` and *stage* it
into this process's ``SharedMemoryConnector``, then post the segment
descriptor on the control plane. A D process adopts the segment and
reads it; staging is freed only when the parent relays D's consumption
(``ReleaseStaged``) — which is also the staging pool's backpressure: when
the pinned pool is full, the P loop blocks on release messages instead of
overrunning the pool.

All messages home carry this worker's instance id (``src``), and every
heartbeat carries the measured backlog (queued prefills + their estimated
prompt tokens) — the load signal the parent's router balances on.
"""
from __future__ import annotations

import collections
import os
import queue
import time
from typing import Any, Deque

from repro.serving.multiproc.messages import (ChunkStaged, Heartbeat, Hello,
                                              PrefillDone, PrefillFailed,
                                              ReleaseStaged, Shutdown,
                                              SubmitPrefill, WorkerSpec,
                                              WorkerStats)


class _ShutdownRequested(Exception):
    pass


def _est_tokens(req) -> int:
    patches = req.patches.shape[0] if req.patches is not None else 0
    return req.prompt_len + patches


class PWorker:
    """Event loop state of one prefill worker."""

    def __init__(self, spec: WorkerSpec, cmd_q, evt_q):
        from repro.serving.multiproc.jit_cache import enable_jit_cache
        enable_jit_cache(spec.jit_cache_dir)  # before any jit touches XLA

        from repro.core.disagg import DisaggPipeline
        from repro.core.transport import SharedMemoryConnector
        self.spec = spec
        self.iid = spec.iid
        self.cmd_q = cmd_q
        self.evt_q = evt_q
        self.engine = spec.engine.build()
        self.connector = SharedMemoryConnector(**spec.connector_kwargs)
        self.pipeline = DisaggPipeline(self.connector, spec.wire,
                                       codec=spec.codec)
        self.backlog: Deque[SubmitPrefill] = collections.deque()
        self.staged_chunks = 0
        self.release_ack = 0              # highest ReleaseStaged.seq done
        self.stop = False

    # -- control plane ---------------------------------------------------- #
    def _handle(self, msg: Any) -> None:
        if isinstance(msg, Shutdown):
            self.stop = True
            raise _ShutdownRequested
        if isinstance(msg, ReleaseStaged):
            self.connector.complete(msg.key)     # unlink: D consumed it
            self.release_ack = max(self.release_ack, msg.seq)
            return
        if isinstance(msg, SubmitPrefill):
            self.backlog.append(msg)
            return

    def _pump_cmds(self, timeout: float) -> bool:
        """Process one waiting command; True if one arrived."""
        try:
            msg = self.cmd_q.get(timeout=timeout)
        except queue.Empty:
            return False
        self._handle(msg)
        return True

    def _drain_cmds_nowait(self, limit: int = 64) -> None:
        """Process whatever commands are already queued. Called between
        chunks so ReleaseStaged (freeing consumed segments) and Shutdown
        don't starve while the backlog keeps this loop busy."""
        for _ in range(limit):
            try:
                msg = self.cmd_q.get_nowait()
            except queue.Empty:
                return
            self._handle(msg)

    def _load(self) -> dict:
        """Measured backlog snapshot for the heartbeat."""
        return {"backlog": float(len(self.backlog)),
                "backlog_tokens": float(sum(_est_tokens(m.req)
                                            for m in self.backlog))}

    # -- data plane -------------------------------------------------------- #
    def _stage_with_backpressure(self, key: str, wire_chunk, meta,
                                 stall_s: float = 30.0) -> int:
        """Stage a chunk; when the pinned pool is full, block on the
        control plane for ``ReleaseStaged`` (D consumed earlier chunks)
        until there is room — the cross-process flow-control loop."""
        deadline = time.monotonic() + stall_s
        while True:
            try:
                return self.connector.stage(key, wire_chunk, meta)
            except MemoryError:
                if time.monotonic() > deadline:
                    raise
                if self._pump_cmds(timeout=0.05):
                    deadline = time.monotonic() + stall_s

    def _run_flight(self, req, wire_skip: int = 0) -> None:
        """Stream one request's prefill: compute chunk → encode → stage →
        announce, then the tail + PrefillDone. ``wire_skip`` leading
        tokens (already resident on the stream's D via its prefix store)
        are computed/replayed but never encoded or staged."""
        from repro.serving.engine import PrefillMode, slice_kv_entries
        spec, eng = self.spec, self.engine
        attempt = req.retries
        meta = {"seq_len": 0, "tp_p": eng.vendor.tp, "wire": self.pipeline.wire}
        skipped_tokens = sent_tokens = sent_bytes = 0
        try:
            stream = eng.prefill_stream(req, spec.prefill_chunk,
                                        mode=PrefillMode(spec.prefill_mode))
            meta["seq_len"] = stream.seq_len
            index = 0
            while True:
                t_c0 = time.monotonic()
                chunk = stream.next_chunk()
                t_c1 = time.monotonic()
                if chunk is None:
                    break
                if not chunk["kv"] and chunk["length"] == 0:
                    # progress marker: a compute chunk that produced no
                    # wire rows (states-only family, or a sliding chunk
                    # below the window floor) — nothing to stage
                    self._drain_cmds_nowait()
                    continue
                start, length = chunk["start"], chunk["length"]
                if wire_skip > start:
                    cut = min(wire_skip, start + length) - start
                    skipped_tokens += cut
                    self.connector.stats.prefix_hit_tokens += cut
                    if start + length <= wire_skip:
                        # fully resident on D: nothing for the wire
                        self._maybe_fault_exit()
                        self._drain_cmds_nowait()
                        continue
                    chunk = dict(chunk,
                                 kv=slice_kv_entries(chunk["kv"], wire_skip,
                                                     start + length),
                                 start=wire_skip,
                                 length=start + length - wire_skip)
                sent_tokens += chunk["length"]
                wire_chunk = self.pipeline.encode_chunk(eng, chunk)
                key = f"{req.req_id}@{eng.name}#t{attempt}c{index}"
                t_s0 = time.monotonic()
                nbytes = self._stage_with_backpressure(key, wire_chunk, meta)
                t_s1 = time.monotonic()
                self.evt_q.put(ChunkStaged(
                    req.req_id, attempt, index, key,
                    self.connector.segment_name(key), nbytes,
                    (t_s0, t_s1), (t_c0, t_c1),
                    ack_seq=self.release_ack, src=self.iid))
                index += 1
                self.staged_chunks += 1
                sent_bytes += nbytes
                self._maybe_fault_exit()
                self._drain_cmds_nowait()
            if skipped_tokens and sent_tokens and sent_bytes:
                # price the skipped tokens at this flight's measured
                # bytes/token on this wire format
                self.connector.stats.bytes_saved += int(
                    sent_bytes / sent_tokens * skipped_tokens)
            tail_pkg = stream.tail_package()
            tail = None
            if tail_pkg.get("states") or tail_pkg.get("cross"):
                tkey = f"{req.req_id}@{eng.name}#t{attempt}tail"
                self._stage_with_backpressure(
                    tkey, {"states": tail_pkg["states"],
                           "cross": tail_pkg["cross"]}, meta)
                tail = self.connector.export_descriptor(tkey)
            self.evt_q.put(PrefillDone(req.req_id, attempt,
                                       int(stream.first_token),
                                       stream.seq_len, index, tail,
                                       ack_seq=self.release_ack,
                                       src=self.iid))
        except _ShutdownRequested:
            raise
        except Exception as e:                    # noqa: BLE001 — report home
            self.evt_q.put(PrefillFailed(req.req_id, attempt, repr(e),
                                         src=self.iid))

    def _maybe_fault_exit(self) -> None:
        fault = self.spec.fault_exit_after_chunks
        if fault is not None and self.staged_chunks >= fault:
            # die *hard*, mid-stream: no atexit, no finalizers — the staged
            # segments are stranded exactly as a SIGKILL'd node strands its
            # registered RDMA buffers. Flush the event queue first so the
            # parent's view matches what really got staged.
            self.evt_q.close()
            self.evt_q.join_thread()
            os._exit(3)

    # -- main loop ---------------------------------------------------------- #
    def run(self) -> None:
        self.evt_q.put(Hello(self.iid, os.getpid(), self.engine.name,
                             role="P"))
        try:
            while not self.stop:
                if self.backlog:
                    m = self.backlog.popleft()
                    self._run_flight(m.req, m.wire_skip_tokens)
                    continue
                if not self._pump_cmds(timeout=self.spec.heartbeat_s):
                    self.evt_q.put(Heartbeat(self.iid,
                                             ack_seq=self.release_ack,
                                             load=self._load()))
        except _ShutdownRequested:
            pass
        self.evt_q.put(WorkerStats(self.iid, self.connector.stats,
                                   self.engine.stats.as_dict()))
        self.connector.close()


def p_main(spec: WorkerSpec, cmd_q, evt_q) -> None:
    """Process entry point (must be importable for spawn)."""
    PWorker(spec, cmd_q, evt_q).run()
