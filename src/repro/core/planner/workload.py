"""Workload descriptors: QPS, context lengths, SLOs (paper §IV-V)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Workload:
    qps: float                    # requests / second, cluster-wide
    input_len: int                # prompt tokens (paper: 256..1024)
    output_len: int               # generated tokens
    slo_ttft_s: float = 2.0       # L_ttft
    slo_tpot_s: float = 0.1       # L_tpot
    encoder_len: int = 0          # encoder positions (audio frames / image
                                  # patches) run as a P-side preamble; 0 for
                                  # text-only families

    def label(self) -> str:
        base = f"{self.input_len}+{self.output_len} QPS{self.qps:g}"
        return f"{base} enc{self.encoder_len}" if self.encoder_len else base


# the paper's experimental points
PAPER_CONTEXTS = [(256, 256), (512, 512), (512, 1024), (1024, 1024)]
FIG6 = [Workload(qps=2.0, input_len=i, output_len=o)
        for (i, o) in PAPER_CONTEXTS]
FIG7 = Workload(qps=2.0, input_len=256, output_len=256)
FIG8 = Workload(qps=3.0, input_len=1024, output_len=1024)
FIG9 = Workload(qps=3.0, input_len=512, output_len=1024)
FIG10 = Workload(qps=2.0, input_len=1024, output_len=1024)
