"""Layered inference-system simulator (paper §III-D, Fig. 5).

Layers, bottom-up, exactly as the paper draws them:

  1. *theoretical model*   — per-layer operator list from the transformer
     structure (FLOPs, weight bytes, activation bytes, KV bytes), no
     hardware or framework effects. `layer_ops`.
  2. *hardware features*   — data alignment (head padding under TP),
     VRAM management (page rounding), dtype widths. `align_ops`.
  3. *framework features*  — prefix-cache hit ratio, chunked prefill,
     scheduling overhead per step. `FrameworkModel`.
  4. *operator libraries*  — computing operator library (roofline op time
     with launch overhead) and communication operator library (ring
     all-reduce / all-gather / p2p). `op_time`, `comm`.
  5. *latency & VRAM model* — l_p (TTFT), l_d (TPOT), m_p, m_d feeding the
     joint optimizer. `InstanceModel`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ATTN, RECURRENT, SSD, ModelConfig
from repro.core.planner.hardware import HardwareSpec


# --------------------------------------------------------------------------- #
# Parallel strategy
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ParallelStrategy:
    dp: int = 1
    tp: int = 1
    pp: int = 1
    ep: int = 1         # expert parallel (must divide tp; folded into tp ranks)

    @property
    def gpus(self) -> int:
        return self.dp * self.tp * self.pp

    def label(self) -> str:
        return f"dp{self.dp}tp{self.tp}pp{self.pp}ep{self.ep}"


# --------------------------------------------------------------------------- #
# Layer 1: theoretical operator list
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class Op:
    name: str
    flops: float = 0.0
    weight_bytes: float = 0.0    # parameters streamed from VRAM
    act_bytes: float = 0.0       # activation/KV traffic from/to VRAM
    kind: str = "gemm"           # gemm | attn | mem | elementwise


def _dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if "16" in cfg.param_dtype else 4


def layer_ops(cfg: ModelConfig, kind: str, mode: str, tokens: int,
              kv_len: int, moe_layer: bool, wbytes: int) -> List[Op]:
    """Theoretical per-layer ops. ``tokens``: S (prefill) or B (decode);
    ``kv_len``: attention context length."""
    d, h, kv, hd = cfg.d_model, cfg.num_heads, max(cfg.num_kv_heads, 1), cfg.hd
    t = tokens
    ops: List[Op] = []
    if kind == SSD:
        s = cfg.ssm
        di, nh, g = s.d_inner(d), s.n_heads(d), s.n_groups
        e_in = d * (2 * di + 2 * g * s.d_state + nh)
        ops.append(Op("ssd_in", 2 * t * e_in, e_in * wbytes, t * d * wbytes))
        q = min(s.chunk_size, max(t, 1))
        scan_flops = 2 * t * nh * s.head_dim * s.d_state * 2 + \
            (2 * t * q * nh * (s.d_state + s.head_dim) if mode == "prefill" else 0)
        state_bytes = nh * s.head_dim * s.d_state * 4
        ops.append(Op("ssd_scan", scan_flops, 0.0,
                      (t * di + state_bytes) * wbytes, kind="attn"))
        ops.append(Op("ssd_out", 2 * t * di * d, di * d * wbytes,
                      t * di * wbytes))
        return ops
    if kind == RECURRENT:
        r = cfg.recurrent
        w = r.lru_width or d
        e_in = 2 * d * w
        ops.append(Op("lru_in", 2 * t * e_in, e_in * wbytes, t * d * wbytes))
        ops.append(Op("lru_gates", 2 * t * w * w * 2, 2 * w * w * wbytes,
                      t * w * wbytes))
        ops.append(Op("lru_scan", 8 * t * w, 0.0, (t * w + w) * wbytes,
                      kind="mem"))
        ops.append(Op("lru_out", 2 * t * w * d, w * d * wbytes, t * w * wbytes))
        ops.append(Op("mlp", 3 * 2 * t * d * cfg.d_ff, 3 * d * cfg.d_ff * wbytes,
                      t * (d + cfg.d_ff) * wbytes))
        return ops
    # attention layer
    if cfg.attention_kind == "mla":
        m = cfg.mla
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        e_q = d * h * qk_hd
        e_dkv = d * (m.kv_lora_rank + m.qk_rope_head_dim)
        e_ukv = m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
        e_o = h * m.v_head_dim * d
        ops.append(Op("mla_proj", 2 * t * (e_q + e_dkv + e_o),
                      (e_q + e_dkv + e_o) * wbytes, t * d * wbytes))
        if mode == "prefill":
            ops.append(Op("mla_up", 2 * t * e_ukv, e_ukv * wbytes,
                          t * m.kv_lora_rank * wbytes))
            attn_flops = 2 * t * kv_len * h * (qk_hd + m.v_head_dim)
            kv_bytes = kv_len * (m.kv_lora_rank + m.qk_rope_head_dim) * h and \
                t * h * (qk_hd + m.v_head_dim) * wbytes
            ops.append(Op("attn", attn_flops, 0.0, kv_bytes, kind="attn"))
        else:
            # absorbed decode: latent-space attention
            absorb = 2 * t * h * m.qk_nope_head_dim * m.kv_lora_rank * 2
            attn_flops = 2 * t * kv_len * h * \
                (m.kv_lora_rank + m.qk_rope_head_dim + m.kv_lora_rank)
            kv_bytes = t * kv_len * (m.kv_lora_rank + m.qk_rope_head_dim) * wbytes
            ops.append(Op("mla_absorb", absorb, e_ukv * wbytes, 0.0))
            ops.append(Op("attn", attn_flops, 0.0, kv_bytes, kind="attn"))
    else:
        e_qkv = d * (h + 2 * kv) * hd
        e_o = h * hd * d
        ops.append(Op("qkv_o", 2 * t * (e_qkv + e_o),
                      (e_qkv + e_o) * wbytes, t * d * wbytes))
        ctx = kv_len
        if cfg.attention_kind == "sliding" and cfg.sliding_window:
            ctx = min(kv_len, cfg.sliding_window)
        attn_flops = 2 * t * ctx * h * hd * 2
        kv_bytes = t * ctx * 2 * kv * hd * wbytes if mode == "decode" else \
            t * h * hd * wbytes
        ops.append(Op("attn", attn_flops, 0.0, kv_bytes, kind="attn"))
    # FFN
    if moe_layer and cfg.is_moe:
        e = cfg.moe
        ops.append(Op("router", 2 * t * d * e.num_experts,
                      d * e.num_experts * 4, t * d * wbytes))
        act_w = 3 * d * e.d_ff_expert * (e.top_k + e.num_shared_experts)
        touched = min(e.num_experts, max(t * e.top_k, 1) if mode == "decode"
                      else e.num_experts)
        stream_w = 3 * d * e.d_ff_expert * (touched + e.num_shared_experts)
        ops.append(Op("moe_mlp", 2 * t * act_w, stream_w * wbytes,
                      t * (d + e.d_ff_expert) * wbytes))
    elif cfg.d_ff:
        ops.append(Op("mlp", 3 * 2 * t * d * cfg.d_ff,
                      3 * d * cfg.d_ff * wbytes, t * (d + cfg.d_ff) * wbytes))
    return ops


def embedding_ops(cfg: ModelConfig, tokens: int, wbytes: int) -> List[Op]:
    return [Op("lm_head", 2 * tokens * cfg.d_model * cfg.vocab_size,
               cfg.d_model * cfg.vocab_size * wbytes,
               tokens * cfg.vocab_size * wbytes)]


# --------------------------------------------------------------------------- #
# Layer 2: hardware feature alignment
# --------------------------------------------------------------------------- #
def align_ops(cfg: ModelConfig, ops: List[Op], strat: ParallelStrategy
              ) -> List[Op]:
    """Shard each op across TP, with data-alignment padding: a head count
    that does not divide tp is padded up (the GSPMD behaviour, and the
    vendor alignment issue the paper's compat module covers)."""
    tp = strat.tp
    out = []
    pad = 1.0
    if cfg.num_heads % tp:
        pad = (math.ceil(cfg.num_heads / tp) * tp) / cfg.num_heads
    for op in ops:
        f = op.flops / tp
        w = op.weight_bytes / tp
        a = op.act_bytes / tp if op.kind == "attn" else op.act_bytes
        if op.kind in ("gemm", "attn"):
            f *= pad
            w *= pad if op.name.startswith(("qkv", "attn", "mla")) else 1.0
        out.append(Op(op.name, f, w, a, op.kind))
    return out


def page_rounded_kv_bytes(cfg: ModelConfig, seq_len: int, block_size: int,
                          wbytes: int) -> float:
    """VRAM management layer: paged allocation rounds up to block_size."""
    blocks = math.ceil(max(seq_len, 1) / block_size)
    alloc = blocks * block_size
    caps = cfg.prefill_capabilities()
    if caps.latent_kv:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    elif not caps.kv_on_wire:
        s = cfg.ssm
        return s.n_heads(cfg.d_model) * s.head_dim * s.d_state * 4 * cfg.num_layers
    else:
        per_tok = 2 * max(cfg.num_kv_heads, 1) * cfg.hd
        if cfg.attention_kind == "sliding" and cfg.sliding_window:
            alloc = min(alloc, math.ceil(cfg.sliding_window / block_size)
                        * block_size)
    n_attn = sum(1 for k in cfg.layer_kinds() if k == ATTN)
    n_other = cfg.num_layers - n_attn
    state = 0.0
    if cfg.recurrent is not None:
        w = cfg.recurrent.lru_width or cfg.d_model
        state = n_other * w * 4
    return n_attn * alloc * per_tok * wbytes + state


# --------------------------------------------------------------------------- #
# Layer 3: framework features
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FrameworkModel:
    prefix_cache_hit: float = 0.0     # fraction of prompt FLOPs skipped
    sched_overhead_s: float = 3e-4    # per engine step (batching, host)
    kernel_launch_s: float = 6e-6     # per fused op
    chunked_prefill: bool = False     # stream KV chunk-wise during prefill
    prefill_chunk_tokens: int = 512   # chunk size when chunked_prefill
    weight_dtype_bytes: int = 2

    def __post_init__(self):
        # an out-of-range fraction silently yields a nonsense effective
        # sequence length (s_eff) in prefill_latency; 1.0 would claim the
        # whole prompt is cached — prefill always computes ≥ 1 token
        if not 0.0 <= self.prefix_cache_hit < 1.0:
            raise ValueError(
                "FrameworkModel.prefix_cache_hit must be in [0.0, 1.0): "
                f"got {self.prefix_cache_hit!r} (it is the fraction of "
                "prompt tokens served from the prefix cache; at least the "
                "final token is always computed)")

    def handoff_exposed_seconds(self, prefill_s: float, transfer_s: float,
                                input_len: int) -> float:
        """P→D wire time left on the critical path after the prefill.

        Monolithic transmission exposes the whole transfer. With chunked
        streaming (the serving stack's StreamedHandoff), chunk i's wire
        time hides under chunk i+1's compute: only the last chunk's
        transfer — or, when the wire is the bottleneck, the un-hidden
        residue of the pipelined stream — remains exposed."""
        if not self.chunked_prefill or transfer_s <= 0 or prefill_s <= 0:
            return transfer_s
        n = max(1, math.ceil(input_len / max(self.prefill_chunk_tokens, 1)))
        per_chunk_xfer = transfer_s / n
        per_chunk_comp = prefill_s / n
        return max(per_chunk_xfer,
                   per_chunk_comp + transfer_s - prefill_s)


# --------------------------------------------------------------------------- #
# Layer 4: operator libraries
# --------------------------------------------------------------------------- #
def op_time(op: Op, hw: HardwareSpec, fw: FrameworkModel) -> float:
    bytes_total = op.weight_bytes + op.act_bytes
    t_compute = op.flops / hw.eff_flops
    t_memory = bytes_total / hw.eff_hbm
    return max(t_compute, t_memory) + fw.kernel_launch_s


def allreduce_time(nbytes: float, tp: int, hw: HardwareSpec) -> float:
    if tp <= 1 or nbytes <= 0:
        return 0.0
    wire = 2.0 * (tp - 1) / tp * nbytes
    return wire / hw.eff_link + 2e-6 * math.log2(tp)


def p2p_time(nbytes: float, hw: HardwareSpec) -> float:
    return nbytes / hw.eff_link + 2e-6


def connector_wire_time(nbytes: float, caps, *, concurrent: int = 1) -> float:
    """P→D wire entry of the communication operator library, sourced from a
    KV connector's ``capabilities()`` (fixed latency + bytes/bandwidth)
    instead of a hard-coded bandwidth constant. ``caps`` is any object with
    the :class:`repro.core.transport.ConnectorCapabilities` shape.

    The connector-declared fixed per-chunk codec overhead
    (``header_bytes``) rides on the payload. ``concurrent`` models the
    declared link arbitration for simultaneous flights: a fair-share link
    divides bandwidth (each flight sees ``bw / n``, one setup latency); an
    exclusive link serializes (the last read waits out the others)."""
    if nbytes <= 0:
        return 0.0
    wire_bytes = nbytes + getattr(caps, "header_bytes", 0)
    xfer = wire_bytes / (caps.bandwidth_gbps * 1e9)
    if concurrent > 1:
        if getattr(caps, "link_sharing", "exclusive") == "fair":
            return caps.fixed_latency_s + concurrent * xfer
        return concurrent * (caps.fixed_latency_s + xfer)
    return caps.fixed_latency_s + xfer


def connector_chunk_tokens(caps, per_token_wire_bytes: float,
                           default: int = 512) -> int:
    """Streaming chunk size (tokens) honoring the connector's preferred
    wire granularity. Falls back to ``default`` when the connector
    declares none (``chunk_bytes == 0``) — or when the granularity is
    smaller than a single token's wire bytes, where honoring it would
    degenerate to 1-token chunks instead of a comparable regime."""
    if caps is None or getattr(caps, "chunk_bytes", 0) <= 0 \
            or per_token_wire_bytes <= 0 \
            or caps.chunk_bytes < per_token_wire_bytes:
        return default
    return int(caps.chunk_bytes // per_token_wire_bytes)


def alltoall_time(nbytes: float, ep: int, hw: HardwareSpec) -> float:
    if ep <= 1:
        return 0.0
    return (nbytes * (ep - 1) / ep) / hw.eff_link + 2e-6 * math.log2(ep)


# --------------------------------------------------------------------------- #
# Layer 5: latency + VRAM model (feeds the paper's Eq. 1-6)
# --------------------------------------------------------------------------- #
class InstanceModel:
    """Performance model of one model instance on one hardware type."""

    def __init__(self, cfg: ModelConfig, hw: HardwareSpec,
                 strat: ParallelStrategy,
                 fw: Optional[FrameworkModel] = None,
                 kv_block_size: int = 16):
        self.cfg = cfg
        self.hw = hw
        self.strat = strat
        self.fw = fw or FrameworkModel()
        self.kv_block = kv_block_size
        self.wb = _dtype_bytes(cfg)

    # -- Eq. (2): l_p ------------------------------------------------------ #
    def prefill_latency(self, seq_len: int, encoder_tokens: int = 0) -> float:
        """``encoder_tokens``: encoder positions (audio frames / image
        patches) run as a non-resumable P-side preamble before token
        chunks. For enc-dec families this adds the encoder stack's cost
        (``encoder_layers`` attention layers over the source length); for
        vision frontends the patch rows join the decoded sequence itself,
        so they extend the effective prefill length instead."""
        cfg, strat = self.cfg, self.strat
        s_eff = int(seq_len * (1.0 - self.fw.prefix_cache_hit))
        total = 0.0
        comm = 0.0
        if encoder_tokens > 0 and cfg.prefill_capabilities().encoder_preamble:
            if cfg.is_enc_dec:
                enc_ops: List[Op] = []
                for _ in range(cfg.encoder_layers):
                    enc_ops.extend(layer_ops(cfg, ATTN, "prefill",
                                             encoder_tokens, encoder_tokens,
                                             False, self.wb))
                for o in align_ops(cfg, enc_ops, strat):
                    total += op_time(o, self.hw, self.fw)
                comm += 2 * cfg.encoder_layers * allreduce_time(
                    encoder_tokens * cfg.d_model * self.wb, strat.tp, self.hw)
            else:
                s_eff += encoder_tokens
        for i, kind in enumerate(cfg.layer_kinds()):
            moe_layer = cfg.is_moe and i >= (cfg.moe.first_dense_layers or 0)
            ops = layer_ops(cfg, kind, "prefill", s_eff, s_eff, moe_layer,
                            self.wb)
            ops = align_ops(cfg, ops, strat)
            total += sum(op_time(o, self.hw, self.fw) for o in ops)
            act = s_eff * cfg.d_model * self.wb
            comm += 2 * allreduce_time(act, strat.tp, self.hw)
            if moe_layer and strat.ep > 1:
                comm += 2 * alltoall_time(
                    s_eff * cfg.moe.top_k * cfg.d_model * self.wb / strat.ep,
                    strat.ep, self.hw)
        for o in embedding_ops(cfg, s_eff, self.wb):
            total += op_time(o, self.hw, self.fw) / strat.tp
        comm += (strat.pp - 1) * p2p_time(s_eff * cfg.d_model * self.wb, self.hw)
        return total + comm + self.fw.sched_overhead_s

    # -- Eq. (5): l_d ------------------------------------------------------ #
    def decode_latency(self, batch: int, kv_len: int) -> float:
        cfg, strat = self.cfg, self.strat
        total = 0.0
        comm = 0.0
        for i, kind in enumerate(cfg.layer_kinds()):
            moe_layer = cfg.is_moe and i >= (cfg.moe.first_dense_layers or 0)
            ops = layer_ops(cfg, kind, "decode", batch, kv_len, moe_layer,
                            self.wb)
            ops = align_ops(cfg, ops, strat)
            total += sum(op_time(o, self.hw, self.fw) for o in ops)
            act = batch * cfg.d_model * self.wb
            comm += 2 * allreduce_time(act, strat.tp, self.hw)
            if moe_layer and strat.ep > 1:
                comm += 2 * alltoall_time(
                    batch * cfg.moe.top_k * cfg.d_model * self.wb / strat.ep,
                    strat.ep, self.hw)
        for o in embedding_ops(cfg, batch, self.wb):
            total += op_time(o, self.hw, self.fw) / strat.tp
        comm += (strat.pp - 1) * p2p_time(batch * cfg.d_model * self.wb, self.hw)
        return total + comm + self.fw.sched_overhead_s

    # -- Eq. (3)/(6): m_p, m_d --------------------------------------------- #
    def weight_bytes_per_gpu(self) -> float:
        strat = self.cfg, self.strat
        n = self.cfg.param_count()
        shard = self.strat.tp * self.strat.pp
        if self.cfg.is_moe and self.strat.ep > 1:
            pass  # experts already inside tp shards (ep | tp)
        return n * self.wb / shard

    def activation_bytes_per_gpu(self, tokens: int) -> float:
        cfg = self.cfg
        widest = max(cfg.d_ff, cfg.d_model * 4,
                     (cfg.moe.d_ff_expert * cfg.moe.top_k) if cfg.is_moe else 0)
        return 4.0 * tokens * (cfg.d_model + widest / self.strat.tp) * self.wb

    def kv_bytes_per_gpu(self, batch: int, seq_len: int) -> float:
        full = page_rounded_kv_bytes(self.cfg, seq_len, self.kv_block, self.wb)
        kvh = max(self.cfg.num_kv_heads, 1)
        tp_share = min(self.strat.tp, kvh)
        return batch * full / (tp_share * self.strat.pp)

    def vram_prefill(self, seq_len: int, concurrent: int = 1) -> float:
        return (self.weight_bytes_per_gpu()
                + concurrent * self.activation_bytes_per_gpu(seq_len)
                + concurrent * self.kv_bytes_per_gpu(1, seq_len))

    def vram_decode(self, batch: int, seq_len: int) -> float:
        return (self.weight_bytes_per_gpu()
                + self.activation_bytes_per_gpu(batch)
                + self.kv_bytes_per_gpu(batch, seq_len))

    def fits(self, vram_bytes: float) -> bool:
        return vram_bytes <= self.hw.hbm_bytes * 0.92   # runtime reserve

    # -- instance-level throughput ------------------------------------------ #
    def max_decode_batch(self, seq_len: int, cap: int = 512) -> int:
        lo, hi = 0, cap
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.fits(self.vram_decode(mid, seq_len)):
                lo = mid
            else:
                hi = mid - 1
        return lo

    def prefill_qps_capacity(self, seq_len: int, microbatches: int = 4) -> float:
        l = self.prefill_latency(seq_len)
        pp = self.strat.pp
        pipe_eff = microbatches / (microbatches + pp - 1)
        return self.strat.dp * pp * pipe_eff / l

    def decode_token_capacity(self, batch: int, kv_len: int) -> float:
        return self.strat.dp * batch / self.decode_latency(batch, kv_len)
