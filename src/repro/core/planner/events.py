"""Discrete-event simulation of the serving cluster.

Drives the paper's experiments (Figs. 6-10) on top of the layered latency
model. Every instance is a single shared resource (one GPU timeline):

  * ``disagg``    — P instances run prefill only; D instances run
    continuous-batching decode only; a KV transfer (bytes/NIC) sits between.
  * ``integrated``— each instance runs BOTH stages with prefill-priority:
    an arriving prefill runs before the next decode step (the paper's
    baseline), so decode stalls and, under load, prefill queueing blows up
    TTFT — the interference the paper sets out to remove.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.planner.simulator import InstanceModel, connector_wire_time
from repro.core.planner.workload import Workload


def kv_wire_bytes_per_token(cfg: ModelConfig, wbytes: int = 2) -> int:
    """Canonical per-token P→D wire bytes across all attention layers —
    the single source for both the event sim's transfer time and the
    connector-granularity chunk sizing. Family-awareness routes through
    ``cfg.prefill_capabilities()``: latent-KV families ship the latent
    cache, states-only families (no KV on the wire) ship no per-token
    bytes at all (their recurrent state travels once in the tail
    package, amortized to ~0 per token)."""
    caps = cfg.prefill_capabilities()
    if not caps.kv_on_wire:
        per_tok = 0
    elif caps.latent_kv:
        per_tok = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * wbytes
    else:
        per_tok = 2 * max(cfg.num_kv_heads, 1) * cfg.hd * wbytes
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    return per_tok * max(n_attn, 1)


@dataclasses.dataclass
class SimRequest:
    rid: int
    arrival: float
    input_len: int
    output_len: int
    prefill_start: float = -1.0
    first_token: float = -1.0
    tokens_emitted: int = 0
    finish: float = -1.0

    def ttft(self) -> float:
        return self.first_token - self.arrival

    def tpot(self) -> float:
        n = max(self.tokens_emitted - 1, 1)
        return (self.finish - self.first_token) / n


@dataclasses.dataclass
class SimResult:
    requests: List[SimRequest]
    duration: float
    total_tokens: int
    p_busy: float = 0.0
    d_busy: float = 0.0
    #: seconds of prefill compute executed on ``role="both"`` instances
    #: while decode work sat active/waiting on the same timeline — the
    #: modeled decode stall the paper's disagg design removes. Compare
    #: against the measured ``EngineStats.contention_stall_seconds``.
    contention_stall_s: float = 0.0

    def _done(self) -> List[SimRequest]:
        return [r for r in self.requests if r.finish >= 0]

    def ttft_mean(self) -> float:
        d = self._done()
        return float(np.mean([r.ttft() for r in d])) if d else float("inf")

    def ttft_p99(self) -> float:
        d = self._done()
        return float(np.percentile([r.ttft() for r in d], 99)) if d else float("inf")

    def tpot_mean(self) -> float:
        d = self._done()
        return float(np.mean([r.tpot() for r in d])) if d else float("inf")

    def throughput_tok_s(self) -> float:
        return self.total_tokens / self.duration

    def completed(self) -> int:
        return len(self._done())

    def goodput_req_s(self, wl: "Workload") -> float:
        """Requests/s finishing within BOTH SLOs (throughput under SLO
        constraints — the comparison regime of the paper's Figs. 9-10)."""
        ok = [r for r in self._done()
              if r.ttft() <= wl.slo_ttft_s and r.tpot() <= wl.slo_tpot_s]
        return len(ok) / self.duration

    def goodput_tok_s(self, wl: "Workload") -> float:
        ok = [r for r in self._done()
              if r.ttft() <= wl.slo_ttft_s and r.tpot() <= wl.slo_tpot_s]
        return sum(r.tokens_emitted for r in ok) / self.duration

    def slo_attainment(self, wl: "Workload") -> float:
        d = self._done()
        if not d:
            return 0.0
        ok = [r for r in d
              if r.ttft() <= wl.slo_ttft_s and r.tpot() <= wl.slo_tpot_s]
        return len(ok) / len(d)

    def summary(self) -> Dict[str, float]:
        return {"ttft_mean_s": self.ttft_mean(), "ttft_p99_s": self.ttft_p99(),
                "tpot_mean_s": self.tpot_mean(),
                "throughput_tok_s": self.throughput_tok_s(),
                "completed": float(self.completed()),
                "contention_stall_s": self.contention_stall_s}


class _Instance:
    """One GPU timeline. role: 'prefill' | 'decode' | 'both'."""

    def __init__(self, name: str, model: InstanceModel, role: str,
                 max_batch: int):
        self.name = name
        self.model = model
        self.role = role
        self.max_batch = max_batch
        self.prefill_q: List[SimRequest] = []
        self.decode_active: List[SimRequest] = []
        self.decode_wait: List[SimRequest] = []
        self.busy_prefill = 0.0
        self.busy_decode = 0.0
        self.stall = 0.0            # prefill time while decode work waited
        self.working = False

    # queue-depth proxies for routing
    def p_load(self) -> float:
        return len(self.prefill_q)

    def d_load(self) -> float:
        return (len(self.decode_active) + len(self.decode_wait)) / \
            max(self.max_batch, 1)


def simulate(cfg: ModelConfig, wl: Workload, *,
             p_model: InstanceModel, d_model: InstanceModel,
             n_prefill: int = 1, n_decode: int = 1,
             mode: str = "disagg", duration_s: float = 120.0,
             transfer_gbps: float = 25.0, connector_caps=None,
             poisson: bool = False,
             seed: int = 0, max_batch_cap: int = 256,
             drain: bool = True) -> SimResult:
    """In ``integrated`` mode the (p_model, n_prefill) pair describes the
    first integrated pool and (d_model, n_decode) the second — pass the same
    hardware sets as the disagg run for a cost-fair comparison.

    ``connector_caps``: a KV connector's ``capabilities()`` descriptor —
    when given, the P→D wire time is sourced from it (bandwidth + fixed
    per-read latency) instead of the bare ``transfer_gbps`` constant."""
    rng = np.random.default_rng(seed)
    arrivals: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / wl.qps) if poisson else 1.0 / wl.qps
        if t >= duration_s:
            break
        arrivals.append(t)
    reqs = [SimRequest(i, a, wl.input_len, wl.output_len)
            for i, a in enumerate(arrivals)]

    seq_total = wl.input_len + wl.output_len
    if mode == "integrated":
        insts = [
            _Instance(f"I{i}", p_model if i < n_prefill else d_model, "both",
                      max(min((p_model if i < n_prefill else d_model)
                              .max_decode_batch(seq_total), max_batch_cap), 1))
            for i in range(n_prefill + n_decode)]
        p_pool = insts
        d_pool = insts
    else:
        p_pool = [_Instance(f"P{i}", p_model, "prefill", 0)
                  for i in range(n_prefill)]
        d_pool = [_Instance(f"D{i}", d_model, "decode",
                            max(min(d_model.max_decode_batch(seq_total),
                                    max_batch_cap), 1))
                  for i in range(n_decode)]
        insts = p_pool + d_pool

    # P→D wire bytes per request (canonical KV of the prompt). Encoder
    # preamble families also ship per-position KV for the encoder output:
    # enc-dec streams cross-KV rows (one set per decoder layer — same
    # width as self-KV), vision frontends prepend patch rows to the
    # decoded sequence itself.
    wire_len = wl.input_len
    if cfg.prefill_capabilities().encoder_preamble:
        wire_len += wl.encoder_len
    kv_bytes = kv_wire_bytes_per_token(cfg) * wire_len
    if mode != "disagg":
        xfer = 0.0
    elif connector_caps is not None:
        xfer = connector_wire_time(kv_bytes, connector_caps)
    else:
        xfer = kv_bytes / (transfer_gbps * 1e9)

    evq: List[Tuple[float, int, str, object]] = []
    counter = 0

    def push(when: float, kind: str, payload) -> None:
        nonlocal counter
        counter += 1
        heapq.heappush(evq, (when, counter, kind, payload))

    for r in reqs:
        push(r.arrival, "arrive", r)

    total_tokens = 0
    end = duration_s if not drain else duration_s + 3600.0

    def kick(inst: _Instance, now: float) -> None:
        if not inst.working:
            inst.working = True
            push(now, "work", inst)

    while evq:
        now, _, kind, payload = heapq.heappop(evq)
        if now > end:
            break
        if kind == "arrive":
            r: SimRequest = payload
            pi = min(p_pool, key=lambda i: i.p_load())
            pi.prefill_q.append(r)
            kick(pi, now)
        elif kind == "admit":
            r, src = payload
            if mode == "integrated":
                di = src                      # decode where it prefilled
            else:
                di = min(d_pool, key=lambda i: i.d_load())
            di.decode_wait.append(r)
            kick(di, now)
        elif kind == "work":
            inst: _Instance = payload
            # prefill-priority (the paper's baseline behaviour)
            if inst.role in ("prefill", "both") and inst.prefill_q:
                r = inst.prefill_q.pop(0)
                dt = inst.model.prefill_latency(r.input_len,
                                                encoder_tokens=wl.encoder_len)
                inst.busy_prefill += dt
                if inst.role == "both" and (inst.decode_active or
                                            inst.decode_wait):
                    inst.stall += dt
                r.prefill_start = now
                r.first_token = now + dt       # first token from prefill
                r.tokens_emitted = 1
                total_tokens += 1
                if r.tokens_emitted >= r.output_len:
                    r.finish = now + dt
                else:
                    # chunked streaming overlaps the wire with chunk compute
                    # (serving stack's StreamedHandoff); only the exposed
                    # residue delays admission to the D pool. Families the
                    # engine cannot chunk-compute ship after the whole
                    # prefill — full wire time exposed.
                    if cfg.supports_chunked_prefill:
                        exposed = inst.model.fw.handoff_exposed_seconds(
                            dt, xfer, r.input_len)
                    else:
                        exposed = xfer
                    push(now + dt + exposed, "admit", (r, inst))
                push(now + dt, "work", inst)
                continue
            if inst.role in ("decode", "both") and \
                    (inst.decode_active or inst.decode_wait):
                while inst.decode_wait and \
                        len(inst.decode_active) < inst.max_batch:
                    inst.decode_active.append(inst.decode_wait.pop(0))
                batch = len(inst.decode_active)
                kv = float(np.mean([q.input_len + q.tokens_emitted
                                    for q in inst.decode_active]))
                dt = inst.model.decode_latency(batch, int(kv))
                inst.busy_decode += dt
                total_tokens += batch
                finished = []
                for q in inst.decode_active:
                    q.tokens_emitted += 1
                    if q.tokens_emitted >= q.output_len:
                        q.finish = now + dt
                        finished.append(q)
                inst.decode_active = [q for q in inst.decode_active
                                      if q not in finished]
                push(now + dt, "work", inst)
                continue
            inst.working = False

    dur = max(duration_s,
              max((r.finish for r in reqs if r.finish > 0), default=0.0))
    pb = sum(i.busy_prefill for i in insts)
    db = sum(i.busy_decode for i in insts)
    return SimResult(requests=reqs, duration=dur, total_tokens=total_tokens,
                     p_busy=pb / (len(insts) * dur),
                     d_busy=db / (len(insts) * dur),
                     contention_stall_s=sum(i.stall for i in insts))
