"""Joint optimization of parallel strategy and P:D instance allocation
(paper §III-C / §IV) — a serial two-stage global search.

Stage 1 (Eq. 1): over (dp, tp, pp, ep), maximize per-GPU prefill throughput
  T_p / (dp·tp·pp)  s.t.  (c1) l_p ≤ L_ttft   (c2) m_p ≤ M_p

Stage 2 (Eq. 4): over (dp, tp, pp, ep, Y), maximize per-instance decode
throughput  Σ_y T_y^d / Y  s.t.  (c1) l_d ≤ L_tpot  (c2) m_d ≤ M_d,
with total D capacity covering the stage-1 (P-side) admitted rate.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.planner.hardware import HardwareSpec
from repro.core.planner.simulator import (FrameworkModel, InstanceModel,
                                          ParallelStrategy)
from repro.core.planner.workload import Workload


@dataclasses.dataclass
class StageResult:
    strategy: ParallelStrategy
    latency_s: float              # l_p or l_d at the operating point
    vram_gb: float
    per_gpu_throughput: float     # stage-1 objective (req/s/GPU)
    instance_capacity: float      # req/s per instance
    batch: int = 0                # decode operating batch (stage 2)
    candidates_evaluated: int = 0
    rejected_slo: int = 0
    rejected_vram: int = 0


@dataclasses.dataclass
class DeploymentPlan:
    model: str
    workload: Workload
    p_hw: str
    d_hw: str
    prefill: StageResult
    decode: StageResult
    n_prefill: int
    n_decode: int                 # Y
    cost_per_hour: float
    qps_capacity: float

    def ratio(self) -> str:
        return f"{self.n_prefill}P{self.n_decode}D"

    def to_cluster_spec(self, cfg: ModelConfig, *,
                        p_vendor=None, d_vendor=None,
                        params_seed: int = 0, num_blocks: int = 256,
                        max_batch: int = 8, max_seq_len: int = 512,
                        num_p: Optional[int] = None,
                        num_d: Optional[int] = None):
        """Make the plan executable: the chosen instance counts and TP
        degrees as a ``ClusterSpec`` the multi-process ``ClusterRuntime``
        launches unmodified. ``num_p``/``num_d`` override the planned
        counts (the CLI's ``--num-p/--num-d``); vendors default to one
        profile per stage named after the planned hardware, with the KV
        shard TP clamped to a divisor of the model's KV heads (stored KV
        is sharded by head, so the planner's TP may exceed what the KV
        layout can express)."""
        # imported here: the serving layer imports the planner for
        # plan-vs-measured reporting, so a module-level import would cycle
        from repro.serving.engine import VendorProfile
        from repro.serving.multiproc.messages import ClusterSpec, EngineSpec
        if p_vendor is None:
            p_vendor = VendorProfile(
                self.p_hw, tp=_kv_tp(cfg, self.prefill.strategy.tp),
                hardware=self.p_hw)
        if d_vendor is None:
            d_vendor = VendorProfile(
                self.d_hw, tp=_kv_tp(cfg, self.decode.strategy.tp),
                hardware=self.d_hw)
        n_p = self.n_prefill if num_p is None else num_p
        n_d = self.n_decode if num_d is None else num_d
        common = dict(cfg=cfg, params_seed=params_seed,
                      num_blocks=num_blocks, max_batch=max_batch,
                      max_seq_len=max_seq_len)
        return ClusterSpec(
            p=tuple(EngineSpec(name=f"{self.p_hw}-p{i}", vendor=p_vendor,
                               role="prefill", **common)
                    for i in range(n_p)),
            d=tuple(EngineSpec(name=f"{self.d_hw}-d{i}", vendor=d_vendor,
                               role="decode", **common)
                    for i in range(n_d)))


def _kv_tp(cfg: ModelConfig, want: int) -> int:
    """Largest KV-shard TP ≤ the planned TP that divides the model's KV
    heads (1 for latent-KV families: the latent cache is not
    head-sharded)."""
    if cfg.prefill_capabilities().latent_kv:
        return 1
    heads = max(cfg.num_kv_heads, 1)
    return max(t for t in range(1, max(want, 1) + 1) if heads % t == 0)


def _strategy_space(cfg: ModelConfig, hw: HardwareSpec,
                    max_gpus: int) -> List[ParallelStrategy]:
    tps = [t for t in (1, 2, 4, 8) if t <= max_gpus]
    pps = [p for p in (1, 2, 4) if p <= max_gpus]
    dps = [d for d in (1, 2, 4, 8) if d <= max_gpus]
    eps = [1]
    if cfg.is_moe:
        eps = sorted({e for e in (1, 2, 4, 8)
                      if cfg.moe.num_experts % e == 0})
    out = []
    for dp, tp, pp, ep in itertools.product(dps, tps, pps, eps):
        if dp * tp * pp > max_gpus:
            continue
        if ep > 1 and tp % ep != 0:
            continue
        out.append(ParallelStrategy(dp=dp, tp=tp, pp=pp, ep=ep))
    return out


def optimize_prefill(cfg: ModelConfig, hw: HardwareSpec, wl: Workload,
                     max_gpus: int = 8,
                     fw: Optional[FrameworkModel] = None) -> StageResult:
    """Stage 1: Eq. (1) global search."""
    best: Optional[StageResult] = None
    n_eval = n_slo = n_vram = 0
    for strat in _strategy_space(cfg, hw, max_gpus):
        n_eval += 1
        m = InstanceModel(cfg, hw, strat, fw)
        l_p = m.prefill_latency(wl.input_len)
        if l_p > wl.slo_ttft_s:                        # (c1)
            n_slo += 1
            continue
        vram = m.vram_prefill(wl.input_len, concurrent=1)
        if not m.fits(vram):                           # (c2)
            n_vram += 1
            continue
        cap = m.prefill_qps_capacity(wl.input_len)
        per_gpu = cap / strat.gpus
        cand = StageResult(strategy=strat, latency_s=l_p,
                           vram_gb=vram / (1 << 30),
                           per_gpu_throughput=per_gpu,
                           instance_capacity=cap)
        if best is None or cand.per_gpu_throughput > best.per_gpu_throughput:
            best = cand
    if best is None:
        raise ValueError(
            f"no feasible prefill strategy for {cfg.name} on {hw.name} "
            f"(TTFT SLO {wl.slo_ttft_s}s, {wl.input_len} tokens)")
    best.candidates_evaluated = n_eval
    best.rejected_slo = n_slo
    best.rejected_vram = n_vram
    return best


def optimize_decode(cfg: ModelConfig, hw: HardwareSpec, wl: Workload,
                    required_qps: float, max_gpus: int = 8,
                    fw: Optional[FrameworkModel] = None
                    ) -> Tuple[StageResult, int]:
    """Stage 2: Eq. (4) global search (strategy × operating batch × Y)."""
    seq = wl.input_len + wl.output_len
    best: Optional[Tuple[StageResult, int]] = None
    n_eval = n_slo = n_vram = 0
    for strat in _strategy_space(cfg, hw, max_gpus):
        n_eval += 1
        m = InstanceModel(cfg, hw, strat, fw)
        bmax = m.max_decode_batch(seq)
        if bmax < 1:
            n_vram += 1
            continue
        # largest batch still meeting the TPOT SLO (l_d grows with batch)
        batch, l_d = 0, float("inf")
        for b in sorted({1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, bmax}):
            if b > bmax:
                break
            l = m.decode_latency(b, seq)
            if l <= wl.slo_tpot_s:
                batch, l_d = b, l
        if batch == 0:                                  # (c1)
            n_slo += 1
            continue
        vram = m.vram_decode(batch, seq)
        if not m.fits(vram):                            # (c2)
            n_vram += 1
            continue
        inst_qps = strat.dp * batch / l_d / wl.output_len
        y = max(1, math.ceil(required_qps / inst_qps))
        cand = StageResult(strategy=strat, latency_s=l_d,
                           vram_gb=vram / (1 << 30),
                           per_gpu_throughput=inst_qps / strat.gpus,
                           instance_capacity=inst_qps, batch=batch)
        # objective: max mean per-instance throughput; tie-break on fewer
        # GPUs total (Y × gpus) = cost
        key = (cand.instance_capacity, -(y * strat.gpus))
        if best is None or key > (best[0].instance_capacity,
                                  -(best[1] * best[0].strategy.gpus)):
            best = (cand, y)
    if best is None:
        raise ValueError(
            f"no feasible decode strategy for {cfg.name} on {hw.name} "
            f"(TPOT SLO {wl.slo_tpot_s}s, seq {seq})")
    best[0].candidates_evaluated = n_eval
    best[0].rejected_slo = n_slo
    best[0].rejected_vram = n_vram
    return best


def plan_deployment(cfg: ModelConfig, wl: Workload, p_hw: HardwareSpec,
                    d_hw: HardwareSpec, max_gpus_per_instance: int = 8,
                    fw: Optional[FrameworkModel] = None) -> DeploymentPlan:
    """Serial two-stage optimization: P first (QPS-driven), then D sized to
    match the P side's admitted rate (the paper's coupling)."""
    s1 = optimize_prefill(cfg, p_hw, wl, max_gpus_per_instance, fw)
    n_p = max(1, math.ceil(wl.qps / s1.instance_capacity))
    admitted = min(wl.qps, n_p * s1.instance_capacity)
    s2, y = optimize_decode(cfg, d_hw, wl, admitted, max_gpus_per_instance, fw)
    cost = (n_p * s1.strategy.gpus * p_hw.cost_per_hour
            + y * s2.strategy.gpus * d_hw.cost_per_hour)
    cap = min(n_p * s1.instance_capacity, y * s2.instance_capacity)
    return DeploymentPlan(model=cfg.name, workload=wl, p_hw=p_hw.name,
                          d_hw=d_hw.name, prefill=s1, decode=s2,
                          n_prefill=n_p, n_decode=y, cost_per_hour=cost,
                          qps_capacity=cap)
