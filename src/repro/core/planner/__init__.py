"""Deployment planner: layered simulator + two-stage joint optimization."""
from repro.core.planner.hardware import GPU_A, GPU_B, TPU_V5E, HardwareSpec  # noqa: F401
from repro.core.planner.optimizer import (DeploymentPlan, optimize_decode,   # noqa: F401
                                          optimize_prefill, plan_deployment)
from repro.core.planner.simulator import (FrameworkModel, InstanceModel,     # noqa: F401
                                          ParallelStrategy)
from repro.core.planner.workload import Workload                             # noqa: F401
