"""Hardware descriptors for the planner's operator libraries.

The paper's experimental platform: GPU A (80 GB, 312 TFLOPS) used for
decode, GPU B (32 GB, 512 TFLOPS) used for prefill. We carry both, plus the
TPU v5e target of the dry-run/roofline (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI) so one planner serves both studies.

Discount factors λ (compute), α (HBM), β (network) are the paper's Eq. (2)/(5)
efficiency knobs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    tflops: float               # dense bf16/fp16 peak, TFLOP/s
    hbm_gb: float               # VRAM capacity (M_p / M_d in the paper)
    hbm_gbps: float             # VRAM bandwidth, GB/s
    link_gbps: float            # intra-instance interconnect per link, GB/s
    scaleout_gbps: float        # NIC for P→D KV transfer, GB/s
    compute_discount: float = 0.55   # λ
    hbm_discount: float = 0.75       # α
    net_discount: float = 0.80       # β
    cost_per_hour: float = 1.0

    @property
    def eff_flops(self) -> float:
        return self.tflops * 1e12 * self.compute_discount

    @property
    def eff_hbm(self) -> float:
        return self.hbm_gbps * 1e9 * self.hbm_discount

    @property
    def eff_link(self) -> float:
        return self.link_gbps * 1e9 * self.net_discount

    @property
    def hbm_bytes(self) -> float:
        return self.hbm_gb * (1 << 30)


REGISTRY: Dict[str, HardwareSpec] = {}


def register(spec: HardwareSpec) -> HardwareSpec:
    REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> HardwareSpec:
    return REGISTRY[name]


# --- the paper's two vendors (§V: "GPU A (80G, 312TFLOPS)" decode-side,
# "GPU B (32G, 512TFLOPS)" prefill-side). Bandwidths are representative of
# the classes these specs imply (A100-80G-class HBM2e vs a compute-dense
# 32 GB part with weaker memory).
GPU_A = register(HardwareSpec(
    name="gpu-a", tflops=312.0, hbm_gb=80.0, hbm_gbps=2039.0,
    link_gbps=300.0, scaleout_gbps=25.0, cost_per_hour=2.2))
GPU_B = register(HardwareSpec(
    name="gpu-b", tflops=512.0, hbm_gb=32.0, hbm_gbps=1000.0,
    link_gbps=200.0, scaleout_gbps=25.0, cost_per_hour=1.6))

# --- TPU targets (dry-run / roofline constants)
TPU_V5E = register(HardwareSpec(
    name="tpu-v5e", tflops=197.0, hbm_gb=16.0, hbm_gbps=819.0,
    link_gbps=50.0, scaleout_gbps=25.0, cost_per_hour=1.2))
TPU_V5P = register(HardwareSpec(
    name="tpu-v5p", tflops=459.0, hbm_gb=95.0, hbm_gbps=2765.0,
    link_gbps=100.0, scaleout_gbps=25.0, cost_per_hour=4.2))
