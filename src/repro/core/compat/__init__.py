"""Heterogeneous compatible transmission module (paper §III-B)."""
from repro.core.compat import layout, parallel_align, precision  # noqa: F401
from repro.core.compat.precision import WireFormat                # noqa: F401
