"""Heterogeneous parallel-strategy alignment component (paper §III-B-3, Fig. 4).

P and D instances run different TP degrees. Each TP rank of P holds a KV
shard of kv_heads/tp_p heads; D ranks need kv_heads/tp_d heads. The
component computes, for every D rank, which P shards (or which slices of a
P shard) to read:

  tp_p > tp_d  → each D rank COMBINES tp_p/tp_d P shards   (Fig. 4 left)
  tp_p < tp_d  → each P shard SPLITS into tp_d/tp_p slices (Fig. 4 right)

MLA latent caches are replicated across TP ranks (attention runs in the
shared latent space), so alignment degenerates to rank 0 → broadcast; the
same holds for SSM/RG-LRU states sharded on heads — they realign with the
identical head-axis arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Read plan for one D rank: list of (p_rank, head_lo, head_hi) slices
    in P-shard-local head coordinates."""
    d_rank: int
    reads: Tuple[Tuple[int, int, int], ...]


def plan_realign(kv_heads: int, tp_p: int, tp_d: int) -> List[ShardPlan]:
    """Static read plan (control-plane): which P shard slices feed each D rank."""
    assert kv_heads % tp_p == 0, (kv_heads, tp_p)
    assert kv_heads % tp_d == 0, (kv_heads, tp_d)
    per_p = kv_heads // tp_p
    per_d = kv_heads // tp_d
    plans = []
    for d in range(tp_d):
        lo, hi = d * per_d, (d + 1) * per_d      # global head range wanted
        reads = []
        for p in range(tp_p):
            plo, phi = p * per_p, (p + 1) * per_p
            s, e = max(lo, plo), min(hi, phi)
            if s < e:
                reads.append((p, s - plo, e - plo))
        plans.append(ShardPlan(d_rank=d, reads=tuple(reads)))
    return plans


def realign_shards(shards_p: Sequence[jax.Array], tp_d: int) -> List[jax.Array]:
    """Execute the plan on canonical shards.

    shards_p: tp_p arrays of (S, kv_heads/tp_p, hd) → tp_d arrays of
    (S, kv_heads/tp_d, hd). Combine = concat, split = slice (paper Fig. 4)."""
    tp_p = len(shards_p)
    kv_heads = sum(s.shape[1] for s in shards_p)
    plans = plan_realign(kv_heads, tp_p, tp_d)
    out = []
    for plan in plans:
        parts = [shards_p[p][:, lo:hi] for (p, lo, hi) in plan.reads]
        out.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts, 1))
    return out


def realign_replicated(shard_p0: jax.Array, tp_d: int) -> List[jax.Array]:
    """MLA latent / replicated state: rank-0 read, broadcast to all D ranks."""
    return [shard_p0 for _ in range(tp_d)]


def transfer_pairs(kv_heads: int, tp_p: int, tp_d: int
                   ) -> List[Tuple[int, int, int]]:
    """(p_rank, d_rank, heads_moved) edges — drives the TransferEngine's
    point-to-point schedule and the planner's cross-instance traffic model."""
    edges = []
    for plan in plan_realign(kv_heads, tp_p, tp_d):
        for (p, lo, hi) in plan.reads:
            edges.append((p, plan.d_rank, hi - lo))
    return edges
