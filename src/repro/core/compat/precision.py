"""Precision alignment component (paper §III-B).

P and D vendors may not share a native KV dtype. The paper's component is a
dtype cast at the transfer boundary; beyond the paper we add an optional
int8 wire format (per-head absmax scales) that halves transfer bytes for a
bf16↔bf16 pair — flagged explicitly as `wire="int8"`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """On-the-wire representation of canonical KV (S, kv, hd)."""
    kind: str = "raw"          # "raw" (cast) | "int8" (quantized, beyond-paper)
    dtype: str = "bfloat16"    # wire dtype for kind == "raw"

    def bytes_per_element(self) -> float:
        if self.kind == "int8":
            return 1.0 + 4.0 / 64  # scales amortized (one fp32 per 64 elems min)
        return jnp.dtype(self.dtype).itemsize


def encode_wire(kv_canon: jax.Array, wire: WireFormat
                ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """canonical (S, kv, hd) → (payload, scales|None)."""
    if wire.kind == "raw":
        return kv_canon.astype(jnp.dtype(wire.dtype)), None
    if wire.kind == "int8":
        absmax = jnp.max(jnp.abs(kv_canon.astype(jnp.float32)), axis=-1,
                         keepdims=True)                       # (S, kv, 1)
        scale = jnp.maximum(absmax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(kv_canon.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.float32)
    raise ValueError(f"unknown wire kind {wire.kind!r}")


def decode_wire(payload: jax.Array, scales: Optional[jax.Array],
                wire: WireFormat, target_dtype) -> jax.Array:
    """(payload, scales) → canonical (S, kv, hd) in the D instance's dtype."""
    if wire.kind == "raw":
        return payload.astype(target_dtype)
    if wire.kind == "int8":
        return (payload.astype(jnp.float32) * scales).astype(target_dtype)
    raise ValueError(f"unknown wire kind {wire.kind!r}")


def wire_bytes(kv_canon_shape: Tuple[int, ...], wire: WireFormat) -> int:
    n = 1
    for d in kv_canon_shape:
        n *= d
    return int(n * wire.bytes_per_element())


def cast_error_bound(src_dtype, wire: WireFormat) -> float:
    """Worst-case relative error introduced at the boundary (used by tests
    and by the planner's accuracy guardrail)."""
    if wire.kind == "int8":
        return 1.0 / 127.0
    eps = {jnp.float32: 2 ** -24, jnp.bfloat16: 2 ** -8,
           jnp.float16: 2 ** -11}
    return float(eps.get(jnp.dtype(wire.dtype).type, 2 ** -8))
