"""Precision alignment component (paper §III-B).

P and D vendors may not share a native KV dtype. The paper's component is a
dtype cast at the transfer boundary; beyond the paper we add an optional
int8 wire format (per-head absmax scales) that halves transfer bytes for a
bf16↔bf16 pair — flagged explicitly as `wire="int8"`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """On-the-wire representation of canonical KV (S, kv, hd)."""
    kind: str = "raw"          # "raw" (cast) | "int8" (quantized, beyond-paper)
    dtype: str = "bfloat16"    # wire dtype for kind == "raw"

    def bytes_per_element(self) -> float:
        if self.kind == "int8":
            return 1.0 + 4.0 / 64  # scales amortized (one fp32 per 64 elems min)
        return jnp.dtype(self.dtype).itemsize


def encode_wire(kv_canon: jax.Array, wire: WireFormat
                ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """canonical (S, kv, hd) → (payload, scales|None)."""
    if wire.kind == "raw":
        return kv_canon.astype(jnp.dtype(wire.dtype)), None
    if wire.kind == "int8":
        absmax = jnp.max(jnp.abs(kv_canon.astype(jnp.float32)), axis=-1,
                         keepdims=True)                       # (S, kv, 1)
        scale = jnp.maximum(absmax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(kv_canon.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.float32)
    raise ValueError(f"unknown wire kind {wire.kind!r}")


def decode_wire(payload: jax.Array, scales: Optional[jax.Array],
                wire: WireFormat, target_dtype) -> jax.Array:
    """(payload, scales) → canonical (S, kv, hd) in the D instance's dtype."""
    if wire.kind == "raw":
        return payload.astype(target_dtype)
    if wire.kind == "int8":
        return (payload.astype(jnp.float32) * scales).astype(target_dtype)
    raise ValueError(f"unknown wire kind {wire.kind!r}")


def encode_wire_into(src: np.ndarray, wire: WireFormat, out: np.ndarray,
                     scales_out: Optional[np.ndarray] = None) -> None:
    """Host-side single-pass encode of canonical KV directly into a
    destination buffer view (the zero-copy wire's write path).

    ``out`` is a view over the wire segment with the wire dtype; for the
    int8 wire ``scales_out`` is the fp32 scale view with a trailing axis of
    1. Bit-identical to :func:`encode_wire` (same absmax/round/clip math in
    float32, IEEE-deterministic), asserted by the wire conformance tests.
    """
    if wire.kind == "raw":
        np.copyto(out, src, casting="unsafe")
        return
    if wire.kind == "int8":
        x = np.asarray(src, dtype=np.float32)
        absmax = np.max(np.abs(x), axis=-1, keepdims=True)
        scale = (np.maximum(absmax, np.float32(1e-8))
                 / np.float32(127.0)).astype(np.float32)
        np.copyto(scales_out, scale.reshape(scales_out.shape))
        np.copyto(out, np.clip(np.round(x / scale), -127, 127),
                  casting="unsafe")
        return
    raise ValueError(f"unknown wire kind {wire.kind!r}")


def wire_payload_dtype(wire: WireFormat) -> np.dtype:
    """numpy dtype of the wire payload slab."""
    if wire.kind == "int8":
        return np.dtype(np.int8)
    return jnp.dtype(wire.dtype)


def wire_bytes(kv_canon_shape: Tuple[int, ...], wire: WireFormat) -> int:
    n = 1
    for d in kv_canon_shape:
        n *= d
    return int(n * wire.bytes_per_element())


def cast_error_bound(src_dtype, wire: WireFormat) -> float:
    """Worst-case relative error introduced at the boundary (used by tests
    and by the planner's accuracy guardrail)."""
    if wire.kind == "int8":
        return 1.0 / 127.0
    eps = {jnp.float32: 2 ** -24, jnp.bfloat16: 2 ** -8,
           jnp.float16: 2 ** -11}
    return float(eps.get(jnp.dtype(wire.dtype).type, 2 ** -8))
