"""VRAM-management alignment component (paper §III-B-2, Fig. 3).

Different vendors page their KV with different block sizes and tensor
layouts. The paper's general method: convert to a one-dimensional tensor
before transmission (erasing layout), then re-materialize in the target
instance's layout after transmission.

`extract_canonical` / `materialize` implement exactly that against the paged
pools of `repro.serving.paged_cache`; `convert` is the pure
layout×blocksize×dtype bridge used by tests and by the Pallas `kv_repack`
kernel's oracle.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.serving.paged_cache import (KVPageSpec, gather_sequence,
                                       pages_from_canonical, scatter_sequence)


def extract_canonical(spec: KVPageSpec, pool: jax.Array,
                      block_ids: jax.Array, seq_len: int) -> jax.Array:
    """P-side: pages → canonical 1-D wire tensor (the paper's flatten step).

    Returns (seq_len * kv * hd,) flat array (layout fully erased)."""
    kv = gather_sequence(spec, pool, block_ids, seq_len)
    return kv.reshape(-1)


def materialize(spec: KVPageSpec, pool: jax.Array, block_ids: jax.Array,
                flat: jax.Array, seq_len: int) -> jax.Array:
    """D-side: canonical 1-D wire tensor → pages in the D instance's layout."""
    kv = flat.reshape(seq_len, spec.kv_heads, spec.head_dim)
    return scatter_sequence(spec, pool, block_ids, kv)


def convert(src: KVPageSpec, dst: KVPageSpec, src_pages: jax.Array,
            seq_len: int) -> jax.Array:
    """Pure conversion: src-layout pages of one sequence → dst-layout pages.

    src_pages: (nb_src, *src.page_shape()). Returns (nb_dst, *dst.page_shape()).
    Head geometry must match (same kv_heads × head_dim); block size and axis
    layout may differ — this is the Fig. 3 conversion.
    """
    assert src.kv_heads == dst.kv_heads and src.head_dim == dst.head_dim, \
        "head geometry mismatch is handled by parallel_align, not layout"
    from repro.serving.paged_cache import pages_to_canonical
    canon = pages_to_canonical(src, src_pages)              # (nb, bs, kv, hd)
    flat = canon.reshape(-1, src.kv_heads, src.head_dim)[:seq_len]
    nb_dst = dst.blocks_for(seq_len)
    pad = nb_dst * dst.block_size - seq_len
    flat = jnp.pad(flat.astype(dst.jdtype), ((0, pad), (0, 0), (0, 0)))
    canon_dst = flat.reshape(nb_dst, dst.block_size, dst.kv_heads, dst.head_dim)
    return pages_from_canonical(dst, canon_dst)


def transfer_shapes(src: KVPageSpec, dst: KVPageSpec,
                    seq_len: int) -> Tuple[int, int, int]:
    """(flat_elements, src_blocks, dst_blocks) for planning/accounting."""
    flat = seq_len * src.kv_heads * src.head_dim
    return flat, src.blocks_for(seq_len), dst.blocks_for(seq_len)
