"""Elastic P:D pool autoscaler — the paper's 'adjust the P-D instance
ratio' knob made dynamic (§IV benefit scenario #1).

Policy, evaluated per control tick against SLO headroom:
  * TTFT pressure  (pending prefills per routable P > p_queue_high, or
    TTFT EMA > slo_ttft × pressure)  → add a P instance
  * TPOT pressure  (decode slot utilization > d_util_high, or TPOT EMA >
    slo_tpot × pressure)             → add a D instance
  * sustained idleness (utilization < low watermark for `cooldown` ticks)
    → drain the newest surplus instance (never below the planner's
    baseline ratio)

The planner's DeploymentPlan provides the baseline (n_prefill, n_decode);
the autoscaler never scales below it — the static optimum is the floor,
the dynamics handle bursts. Instances are created through a user factory
(on a real cluster: pod allocation + weight loading; here: Engine()).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.serving.engine import Engine
from repro.serving.scheduler import GlobalScheduler


@dataclasses.dataclass
class AutoscalerConfig:
    slo_ttft_s: float = 1.0
    slo_tpot_s: float = 0.1
    pressure: float = 0.8          # act at 80% of the SLO
    p_queue_high: float = 2.0      # pending prefills per routable P
    d_util_high: float = 0.85      # occupied decode slots fraction
    low_util: float = 0.15
    cooldown_ticks: int = 20       # hysteresis for both grow and shrink
    max_p: int = 8
    max_d: int = 8


@dataclasses.dataclass
class AutoscalerStats:
    grew_p: int = 0
    grew_d: int = 0
    drained: int = 0


class PDAutoscaler:
    def __init__(self, scheduler: GlobalScheduler,
                 p_factory: Callable[[str], Engine],
                 d_factory: Callable[[str], Engine],
                 baseline_p: int = 1, baseline_d: int = 1,
                 config: Optional[AutoscalerConfig] = None):
        self.sched = scheduler
        self.p_factory = p_factory
        self.d_factory = d_factory
        self.baseline_p = baseline_p
        self.baseline_d = baseline_d
        self.cfg = config or AutoscalerConfig()
        self.stats = AutoscalerStats()
        self._counter = 0
        self._idle_ticks = 0
        self._last_grow = -10**9
        self._tick = 0

    # -- observations ------------------------------------------------------ #
    def _routable_p(self) -> List[Engine]:
        return self.sched._routable(self.sched.p_pool)

    def _routable_d(self) -> List[Engine]:
        return self.sched._routable(self.sched.d_pool)

    def p_queue_depth(self) -> float:
        ps = self._routable_p()
        return len(self.sched.pending) / max(len(ps), 1)

    def d_utilization(self) -> float:
        ds = self._routable_d()
        if not ds:
            return 1.0
        return sum(e.load() for e in ds) / len(ds)

    # -- control ------------------------------------------------------------ #
    def tick(self) -> Optional[str]:
        """Run one control decision. Returns the action taken, if any."""
        self._tick += 1
        cfg = self.cfg
        cooled = (self._tick - self._last_grow) >= cfg.cooldown_ticks
        ttfts = [r.ttft() for r in self.sched.finished[-16:]
                 if r.ttft() is not None]
        tpots = [r.tpot() for r in self.sched.finished[-16:]
                 if r.tpot() is not None]
        ttft = max(ttfts) if ttfts else 0.0
        tpot = max(tpots) if tpots else 0.0

        if (self.p_queue_depth() > cfg.p_queue_high
                or ttft > cfg.slo_ttft_s * cfg.pressure) \
                and len(self._routable_p()) < cfg.max_p and cooled:
            name = f"P-auto{self._counter}"
            self._counter += 1
            self.sched.add_instance(self.p_factory(name), role="prefill")
            self.stats.grew_p += 1
            self._last_grow = self._tick
            return f"grow-p:{name}"

        if (self.d_utilization() > cfg.d_util_high
                or tpot > cfg.slo_tpot_s * cfg.pressure) \
                and len(self._routable_d()) < cfg.max_d and cooled:
            name = f"D-auto{self._counter}"
            self._counter += 1
            self.sched.add_instance(self.d_factory(name), role="decode")
            self.stats.grew_d += 1
            self._last_grow = self._tick
            return f"grow-d:{name}"

        # shrink: sustained idleness, never below the planner baseline
        busy = self.d_utilization() > cfg.low_util \
            or self.p_queue_depth() > 0
        self._idle_ticks = 0 if busy else self._idle_ticks + 1
        if self._idle_ticks >= cfg.cooldown_ticks:
            self._idle_ticks = 0
            surplus_d = [n for n in self.sched.d_pool
                         if n.startswith("D-auto")
                         and n not in self.sched._draining]
            surplus_p = [n for n in self.sched.p_pool
                         if n.startswith("P-auto")
                         and n not in self.sched._draining]
            if len(self._routable_d()) > self.baseline_d and surplus_d:
                self.sched.remove_instance(surplus_d[-1])
                self.stats.drained += 1
                return f"drain:{surplus_d[-1]}"
            if len(self._routable_p()) > self.baseline_p and surplus_p:
                self.sched.remove_instance(surplus_p[-1])
                self.stats.drained += 1
                return f"drain:{surplus_p[-1]}"
        return None
