"""Elastic P:D pool autoscaler — the paper's 'adjust the P-D instance
ratio' knob made dynamic (§IV benefit scenario #1).

Policy, evaluated per control tick against SLO headroom:
  * TTFT pressure  (pending prefills per routable P > p_queue_high, or
    TTFT EMA > slo_ttft × pressure)  → add a P instance
  * TPOT pressure  (decode slot utilization > d_util_high, or TPOT EMA >
    slo_tpot × pressure)             → add a D instance
  * sustained idleness (utilization < low watermark for `cooldown` ticks)
    → drain the newest surplus instance (never below the planner's
    baseline ratio)

The planner's DeploymentPlan provides the baseline (n_prefill, n_decode);
the autoscaler never scales below it — the static optimum is the floor,
the dynamics handle bursts.

The controller is decoupled from where the load numbers come from by a
:class:`LoadSource`: :class:`SchedulerLoadSource` reads the in-process
``GlobalScheduler`` (engines in this process, ``Engine.load()`` is
callable), while :class:`ClusterLoadSource` reads the multi-process
``ClusterRuntime`` — *measured* queue depth and slot occupancy from
worker heartbeats plus the parent's own dispatch bookkeeping, with
grow/drain mapped onto ``add_instance``/``remove_instance`` (spawning
and draining real worker processes). Instances are created through a
user factory (on a real cluster: pod allocation + weight loading; here:
``Engine()`` / ``EngineSpec``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class AutoscalerConfig:
    slo_ttft_s: float = 1.0
    slo_tpot_s: float = 0.1
    pressure: float = 0.8          # act at 80% of the SLO
    p_queue_high: float = 2.0      # pending prefills per routable P
    d_util_high: float = 0.85      # occupied decode slots fraction
    low_util: float = 0.15
    cooldown_ticks: int = 20       # hysteresis for both grow and shrink
    max_p: int = 8
    max_d: int = 8


@dataclasses.dataclass
class AutoscalerStats:
    grew_p: int = 0
    grew_d: int = 0
    drained: int = 0


class LoadSource:
    """What the controller observes and actuates, independent of runtime."""

    def num_p(self) -> int:
        raise NotImplementedError

    def num_d(self) -> int:
        raise NotImplementedError

    def p_queue_depth(self) -> float:
        """Pending prefills per routable P instance."""
        raise NotImplementedError

    def d_utilization(self) -> float:
        """Mean occupied-slot fraction across routable D instances."""
        raise NotImplementedError

    def recent_ttfts(self) -> List[float]:
        raise NotImplementedError

    def recent_tpots(self) -> List[float]:
        raise NotImplementedError

    def grow(self, name: str, role: str, factory: Callable[[str], Any]) -> None:
        raise NotImplementedError

    def surplus(self, role: str) -> List[str]:
        """Autoscaler-added instances (newest last) eligible for draining."""
        raise NotImplementedError

    def drain(self, name: str) -> None:
        raise NotImplementedError


class SchedulerLoadSource(LoadSource):
    """In-process backend: the ``GlobalScheduler``'s pools and queue."""

    def __init__(self, scheduler):
        self.sched = scheduler

    def _routable_p(self):
        return self.sched._routable(self.sched.p_pool)

    def _routable_d(self):
        return self.sched._routable(self.sched.d_pool)

    def num_p(self) -> int:
        return len(self._routable_p())

    def num_d(self) -> int:
        return len(self._routable_d())

    def p_queue_depth(self) -> float:
        return len(self.sched.pending) / max(self.num_p(), 1)

    def d_utilization(self) -> float:
        ds = self._routable_d()
        if not ds:
            return 1.0
        return sum(e.load() for e in ds) / len(ds)

    def recent_ttfts(self) -> List[float]:
        return [r.ttft() for r in self.sched.finished[-16:]
                if r.ttft() is not None]

    def recent_tpots(self) -> List[float]:
        return [r.tpot() for r in self.sched.finished[-16:]
                if r.tpot() is not None]

    def grow(self, name: str, role: str,
             factory: Callable[[str], Any]) -> None:
        self.sched.add_instance(
            factory(name), role="prefill" if role == "P" else "decode")

    def surplus(self, role: str) -> List[str]:
        pool = self.sched.p_pool if role == "P" else self.sched.d_pool
        return [n for n in pool if n.startswith(f"{role}-auto")
                and n not in self.sched._draining]

    def drain(self, name: str) -> None:
        self.sched.remove_instance(name)


class ClusterLoadSource(LoadSource):
    """Multi-process backend: the ``ClusterRuntime``'s *measured* load —
    worker heartbeats (each P reports its backlog, each D its occupied
    slots) plus the parent's pending queue and dispatch bookkeeping —
    actuated through real process spawn/drain. Factories here return
    ``EngineSpec``s, not ``Engine``s: the engine is built inside the new
    worker process."""

    def __init__(self, runtime):
        self.rt = runtime
        self._added: Dict[str, str] = {}      # iid → role, newest last

    def _members(self, role: str) -> list:
        """Pool members counted against max_p/max_d and the drain floor:
        includes instances still booting (spawned without waiting, Hello
        not yet seen). Counting only *routable* members would re-trigger
        grow every cooldown for the whole boot window — on slow hosts
        that is many seconds — overshooting the cap with processes whose
        imports then starve the serving loop."""
        return [i for i in self.rt._instances.values()
                if i.role == role and i.alive()
                and not i.draining and not i.stopping]

    def num_p(self) -> int:
        return len(self._members("P"))

    def num_d(self) -> int:
        return len(self._members("D"))

    def p_queue_depth(self) -> float:
        """Parent's undispatched queue + each P's heartbeat-reported
        backlog (work dispatched but not yet prefilled), per routable P."""
        ps = self.rt._routable("P")
        backlog = len(self.rt._pending) + sum(
            int(i.load.get("backlog", i.queue_reqs)) for i in ps)
        return backlog / max(len(ps), 1)

    def d_utilization(self) -> float:
        ds = self.rt._routable("D")
        if not ds:
            return 1.0
        total = 0.0
        for i in ds:
            cap = max(i.spec.engine.max_batch, 1)
            # heartbeat-measured occupancy when fresh, parent's reserved
            # count otherwise (heartbeats lag the dispatch edge)
            total += max(i.load.get("active", 0.0), float(i.active)) / cap
        return total / len(ds)

    def recent_ttfts(self) -> List[float]:
        """Newest 16 first-token latencies, *including in-flight streams*.
        Sampling finished requests only biased the EMA toward short,
        already-completed requests and reacted a full request-length late
        under ramp — a request that just got its first token after 5 s of
        queueing is exactly the signal scale-up must see."""
        live = [r for r in self.rt._requests.values()
                if r.first_token_time is not None]
        live.sort(key=lambda r: r.first_token_time)
        return [t for t in (r.ttft() for r in live[-16:]) if t is not None]

    def recent_tpots(self) -> List[float]:
        """Newest 16 per-token latencies, including in-flight streams
        (``tpot_live`` uses the last emitted token as the endpoint)."""
        live = [r for r in self.rt._requests.values()
                if r.first_token_time is not None]
        live.sort(key=lambda r: r.last_token_time
                  or r.finish_time or r.first_token_time)
        return [t for t in (r.tpot_live() for r in live[-16:])
                if t is not None]

    def grow(self, name: str, role: str,
             factory: Callable[[str], Any]) -> None:
        # non-blocking: the worker becomes routable when its Hello lands;
        # a live run must keep serving while the new process boots
        iid = self.rt.add_instance(factory(name), role, wait=False)
        self._added[iid] = role

    def surplus(self, role: str) -> List[str]:
        return [iid for iid, r in self._added.items()
                if r == role and iid in self.rt._instances
                and not self.rt._instances[iid].draining]

    def drain(self, name: str) -> None:
        self.rt.remove_instance(name)
        self._added.pop(name, None)


class PDAutoscaler:
    def __init__(self, scheduler,
                 p_factory: Callable[[str], Any],
                 d_factory: Callable[[str], Any],
                 baseline_p: int = 1, baseline_d: int = 1,
                 config: Optional[AutoscalerConfig] = None):
        # accept either a raw GlobalScheduler (compat) or any LoadSource
        self.src = scheduler if isinstance(scheduler, LoadSource) \
            else SchedulerLoadSource(scheduler)
        self.sched = getattr(self.src, "sched", None)
        self.p_factory = p_factory
        self.d_factory = d_factory
        self.baseline_p = baseline_p
        self.baseline_d = baseline_d
        self.cfg = config or AutoscalerConfig()
        self.stats = AutoscalerStats()
        self._counter = 0
        self._idle_ticks = 0
        self._last_grow = -10**9
        self._tick = 0

    # -- control ------------------------------------------------------------ #
    def tick(self) -> Optional[str]:
        """Run one control decision. Returns the action taken, if any."""
        self._tick += 1
        cfg, src = self.cfg, self.src
        cooled = (self._tick - self._last_grow) >= cfg.cooldown_ticks
        ttfts = src.recent_ttfts()
        tpots = src.recent_tpots()
        ttft = max(ttfts) if ttfts else 0.0
        tpot = max(tpots) if tpots else 0.0

        if (src.p_queue_depth() > cfg.p_queue_high
                or ttft > cfg.slo_ttft_s * cfg.pressure) \
                and src.num_p() < cfg.max_p and cooled:
            name = f"P-auto{self._counter}"
            self._counter += 1
            src.grow(name, "P", self.p_factory)
            self.stats.grew_p += 1
            self._last_grow = self._tick
            return f"grow-p:{name}"

        if (src.d_utilization() > cfg.d_util_high
                or tpot > cfg.slo_tpot_s * cfg.pressure) \
                and src.num_d() < cfg.max_d and cooled:
            name = f"D-auto{self._counter}"
            self._counter += 1
            src.grow(name, "D", self.d_factory)
            self.stats.grew_d += 1
            self._last_grow = self._tick
            return f"grow-d:{name}"

        # shrink: sustained idleness, never below the planner baseline
        busy = src.d_utilization() > cfg.low_util \
            or src.p_queue_depth() > 0
        self._idle_ticks = 0 if busy else self._idle_ticks + 1
        if self._idle_ticks >= cfg.cooldown_ticks:
            self._idle_ticks = 0
            surplus_d = src.surplus("D")
            surplus_p = src.surplus("P")
            if src.num_d() > self.baseline_d and surplus_d:
                src.drain(surplus_d[-1])
                self.stats.drained += 1
                return f"drain:{surplus_d[-1]}"
            if src.num_p() > self.baseline_p and surplus_p:
                src.drain(surplus_p[-1])
                self.stats.drained += 1
                return f"drain:{surplus_p[-1]}"
        return None
