"""P/D disaggregation orchestrator — the paper's §III system glue.

``DisaggPipeline`` moves one finished prefill from a P instance to a D
instance through the three alignment components:

  1. precision  (``compat.precision``)  — wire dtype / int8 wire
  2. VRAM mgmt  (``compat.layout``)     — flatten-to-1D, re-page re-layout
  3. parallel   (``compat.parallel_align``) — TP merge/split of KV shards

The same pipeline with P == D and a raw wire is the *integrated* baseline
(prefill materializes into the local pools with no conversion), which is
what the paper's Figs. 9–10 compare against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import parallel_align, precision
from repro.core.compat.precision import WireFormat
from repro.core.kv_transfer import TransferEngine
from repro.serving import paged_cache as PC
from repro.serving.engine import Engine
from repro.serving.request import Request


def _chronological(k: np.ndarray, pos: np.ndarray) -> Tuple[np.ndarray, int]:
    """Ring-buffer shard (count, cap, kv, hd) + pos (count, cap) →
    chronological (count, cap, kv, hd) and the absolute start position."""
    order = np.argsort(pos[0])                    # same order across layers
    return k[:, order], int(pos[0][order[0]])


class DisaggPipeline:
    def __init__(self, transfer: TransferEngine,
                 wire: Optional[WireFormat] = None):
        self.transfer = transfer
        self.wire = wire or WireFormat(kind="raw", dtype="bfloat16")

    # ------------------------------------------------------------------ #
    # P side: package → wire
    # ------------------------------------------------------------------ #
    def encode_package(self, p_engine: Engine, package: Dict[str, Any]
                       ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        tp_p = p_engine.vendor.tp
        out_kv = []
        for kind, gi, pi, entry in package["kv"]:
            if kind == "mla":
                # latent cache is TP-replicated — ship rank-0 copy only
                ckv = np.asarray(entry["ckv"])       # (count, S, lora)
                kpe = np.asarray(entry["kpe"])
                pl_c, sc_c = precision.encode_wire(
                    jnp.asarray(ckv)[..., None, :].reshape(-1, 1, ckv.shape[-1]),
                    self.wire)
                pl_p, sc_p = precision.encode_wire(
                    jnp.asarray(kpe)[..., None, :].reshape(-1, 1, kpe.shape[-1]),
                    self.wire)
                out_kv.append({"kind": "mla", "gi": gi, "pi": pi,
                               "count": ckv.shape[0], "seq": ckv.shape[1],
                               "start": 0,
                               "payloads": [pl_c, pl_p],
                               "scales": [sc_c, sc_p]})
                continue
            k, v = np.asarray(entry["k"]), np.asarray(entry["v"])
            start = 0
            if "pos" in entry and k.shape[1] < np.max(entry["pos"]) + 1:
                k, start = _chronological(k, np.asarray(entry["pos"]))
                v, _ = _chronological(np.asarray(entry["v"]),
                                      np.asarray(entry["pos"]))
            count, s, kv_heads, hd = k.shape
            # TP shard split (P's parallel strategy), per Fig. 4
            shards_k = np.split(k, tp_p, axis=2)
            shards_v = np.split(v, tp_p, axis=2)
            payloads, scales = [], []
            for sh in shards_k + shards_v:
                pl, sc = precision.encode_wire(
                    jnp.asarray(sh).reshape(-1, sh.shape[2], hd), self.wire)
                payloads.append(pl)
                scales.append(sc)
            out_kv.append({"kind": "kv", "gi": gi, "pi": pi, "count": count,
                           "seq": s, "start": start, "tp_p": tp_p,
                           "payloads": payloads, "scales": scales})
        wire_pkg = {"kv": out_kv, "states": package["states"],
                    "cross": package["cross"]}
        meta = {"first_token": package["first_token"],
                "seq_len": package["seq_len"], "tp_p": tp_p,
                "wire": self.wire}
        return wire_pkg, meta

    # ------------------------------------------------------------------ #
    # D side: wire → pools
    # ------------------------------------------------------------------ #
    def materialize(self, d_engine: Engine, slot: int, block_ids: np.ndarray,
                    payload: Dict[str, Any], meta: Dict[str, Any]) -> None:
        cfg = d_engine.cfg
        tp_d = d_engine.vendor.tp
        wire: WireFormat = meta["wire"]
        caches = [list(g) for g in d_engine.caches]
        bids = jnp.asarray(block_ids, jnp.int32)

        for entry in payload["kv"]:
            gi, pi = entry["gi"], entry["pi"]
            count, s, start = entry["count"], entry["seq"], entry["start"]
            if entry["kind"] == "mla":
                spec_c = d_engine.specs["ckv"]
                spec_p = d_engine.specs["kpe"]
                ckv = precision.decode_wire(entry["payloads"][0],
                                            entry["scales"][0], wire,
                                            spec_c.jdtype)
                kpe = precision.decode_wire(entry["payloads"][1],
                                            entry["scales"][1], wire,
                                            spec_p.jdtype)
                ckv = ckv.reshape(count, s, 1, spec_c.head_dim)
                kpe = kpe.reshape(count, s, 1, spec_p.head_dim)
                pools = caches[gi][pi]
                caches[gi][pi] = dict(
                    pools,
                    ckv_pool=self._write_pages(spec_c, pools["ckv_pool"],
                                               bids, ckv, start),
                    kpe_pool=self._write_pages(spec_p, pools["kpe_pool"],
                                               bids, kpe, start))
                continue
            spec = d_engine.specs["kv"]
            tp_p = entry["tp_p"]
            half = tp_p
            dec = [precision.decode_wire(pl, sc, wire, spec.jdtype)
                   for pl, sc in zip(entry["payloads"], entry["scales"])]
            shards_k = [d.reshape(count, s, -1, spec.head_dim)
                        for d in dec[:half]]
            shards_v = [d.reshape(count, s, -1, spec.head_dim)
                        for d in dec[half:]]
            # parallel-strategy alignment (merge/split), then assemble the
            # full head set for this (tp=1 runtime) D engine's pools.
            k_d = jnp.concatenate(
                parallel_align.realign_shards(
                    [s_.reshape(count * s, -1, spec.head_dim) for s_ in shards_k],
                    tp_d), axis=1).reshape(count, s, -1, spec.head_dim)
            v_d = jnp.concatenate(
                parallel_align.realign_shards(
                    [s_.reshape(count * s, -1, spec.head_dim) for s_ in shards_v],
                    tp_d), axis=1).reshape(count, s, -1, spec.head_dim)
            pools = caches[gi][pi]
            caches[gi][pi] = dict(
                pools,
                k_pool=self._write_pages(spec, pools["k_pool"], bids, k_d, start),
                v_pool=self._write_pages(spec, pools["v_pool"], bids, v_d, start))

        # recurrent / SSM states: place rows at the slot
        for _, gi, pi, state in payload["states"]:
            caches[gi][pi] = d_engine._place_fn(caches[gi][pi], state, slot)
        # enc-dec cross attention memory
        for gi, pi, cr in payload["cross"]:
            c = dict(caches[gi][pi])
            for name in ("cross_k", "cross_v", "mem_len"):
                c[name] = c[name].at[:, slot].set(
                    jnp.asarray(cr[name]).astype(c[name].dtype))
            caches[gi][pi] = c

        d_engine.caches = tuple(tuple(g) for g in caches)

    @staticmethod
    def _write_pages(spec: PC.KVPageSpec, pool: jax.Array, block_ids,
                     canon: jax.Array, start: int) -> jax.Array:
        """canon: (count, S, kv, hd) holding absolute positions
        [start, start+S) → scatter into pages (vmapped over layer count)."""
        bs = spec.block_size
        lo_block = start // bs
        front = start - lo_block * bs
        if front:
            canon = jnp.pad(canon, ((0, 0), (front, 0), (0, 0), (0, 0)))
        s_tot = canon.shape[1]
        nb = -(-s_tot // bs)
        use = block_ids[lo_block:lo_block + nb]
        return jax.vmap(lambda pl, cn: PC.scatter_sequence(spec, pl, use, cn)
                        )(pool, canon)

    # ------------------------------------------------------------------ #
    # Full handoff
    # ------------------------------------------------------------------ #
    def handoff(self, req: Request, p_engine: Engine, d_engine: Engine
                ) -> Dict[str, Any]:
        """prefill-package → stage → read → materialize. Returns meta."""
        package = p_engine.prefill(req)
        wire_pkg, meta = self.encode_package(p_engine, package)
        key = f"{req.req_id}@{p_engine.name}"
        nbytes = self.transfer.stage(key, wire_pkg, meta)
        payload, meta = self.transfer.read(key)
        payload = jax.tree.map(
            lambda x: jnp.asarray(x) if isinstance(x, np.ndarray) else x,
            payload)

        def materialize_fn(engine, slot, bids, _pkg):
            self.materialize(engine, slot, bids, payload, meta)

        d_engine.add_sequence(req, {"first_token": meta["first_token"],
                                    "seq_len": meta["seq_len"]},
                              materialize_fn)
        self.transfer.complete(key)
        meta["bytes"] = nbytes
        return meta
