"""P/D disaggregation orchestrator — the paper's §III system glue.

``DisaggPipeline`` moves prefill KV from a P instance to a D instance
through the three alignment components:

  1. precision  (``compat.precision``)  — wire dtype / int8 wire
  2. VRAM mgmt  (``compat.layout``)     — flatten-to-1D, re-page re-layout
  3. parallel   (``compat.parallel_align``) — TP merge/split of KV shards

Two handoff shapes share the same encode/materialize core:

  * ``handoff``          — monolithic: whole-prompt prefill, one wire
    payload, one re-page (the paper's baseline transmission).
  * ``begin_handoff`` / ``StreamedHandoff`` — chunked streaming: the D slot
    is reserved up front, each prefill chunk's KV is encoded and staged
    into the pinned pool while the next chunk computes, and the D instance
    re-pages chunks as they land (Mooncake-style layer/chunk-wise
    streaming); ``finalize`` ships recurrent/cross state and activates the
    slot. Per-token wire encodings (raw cast, per-token-per-head int8
    scales) make chunk splitting lossless, so streaming lands bit-identical
    pool contents vs the monolithic wire.

The wire itself is a pluggable :class:`~repro.core.transport.KVConnector`:
``send_chunk`` stages a chunk and *issues* an async read
(:class:`~repro.core.transport.TransferHandle`); ``poll_reads`` re-pages
chunks whose handles report complete. With an instant backend (inproc/shm)
a chunk is re-paged in the tick it was sent; with a modeled-latency
backend (rdma) handles complete over later ticks and the scheduler runs
decode steps while chunks are still on the wire.

The same pipeline with P == D and a raw wire is the *integrated* baseline
(prefill materializes into the local pools with no conversion), which is
what the paper's Figs. 9–10 compare against.
"""
from __future__ import annotations

import collections
import functools
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import parallel_align, precision
from repro.core.compat.precision import WireFormat
from repro.core.transport import KVConnector, TransferHandle, WireChunk
from repro.kernels import ops as kops
from repro.serving import paged_cache as PC
from repro.serving.engine import (Engine, kv_entries_with_start,
                                  slice_kv_entries)
from repro.serving.request import Request


def _to_device(payload):
    """Staged wire payload (host numpy) → device arrays for materialize."""
    return jax.tree.map(
        lambda x: jnp.asarray(x) if isinstance(x, np.ndarray) else x, payload)


def _repage_pool_body(spec: PC.KVPageSpec, pool: jax.Array, block_ids,
                      canon: jax.Array, lo_block, *, front: int, rmw: bool,
                      kernel: bool) -> jax.Array:
    """Single-pass re-page of canon (count, S, kv, hd) landing ``front``
    rows into block ``lo_block``'s first page, vmapped over the layer
    count. ``lo_block`` is *traced* and ``front`` (= start % block_size)
    static: chunks at different absolute starts share one compiled
    program as long as their in-page offset matches — streaming a long
    prompt compiles per (chunk shape, offset-in-page), not per chunk.

    Unlike the legacy rmw path — which reads back *every* touched page
    and splices — the overlay scatter only reads the first/last partial
    page (jnp path) or merges partial rows inside the Pallas kernel
    (``kernel=True``), so interior pages move exactly once."""
    bs = spec.block_size
    s = canon.shape[1]
    s_tot = front + s
    nb = -(-s_tot // bs)
    use = jax.lax.dynamic_slice_in_dim(block_ids, lo_block, nb)
    if not rmw:
        if front:
            canon = jnp.pad(canon, ((0, 0), (front, 0), (0, 0), (0, 0)))
        return jax.vmap(lambda pl, cn: PC.scatter_sequence(spec, pl, use, cn)
                        )(pool, canon)
    if kernel:
        cp = jnp.pad(canon, ((0, 0), (front, nb * bs - s_tot),
                             (0, 0), (0, 0)))
        cp = cp.reshape(canon.shape[0], nb, bs, spec.kv_heads, spec.head_dim)
        return jax.vmap(lambda pl, cn: kops.scatter_pages_overlay(
            spec, pl, use, cn, front=front, seq_len=s))(pool, cp)
    return jax.vmap(lambda pl, cn: PC.scatter_sequence_overlay(
        spec, pl, use, cn, front))(pool, canon)


_repage_pool = jax.jit(_repage_pool_body,
                       static_argnames=("spec", "front", "rmw", "kernel"))


@functools.partial(jax.jit, static_argnames=("spec", "wire", "tp_p", "tp_d",
                                             "count", "front", "rmw",
                                             "kernel"))
def _repage_kv_entry(spec: PC.KVPageSpec, k_pool: jax.Array,
                     v_pool: jax.Array, block_ids, pay, sc, lo_block, *,
                     wire: WireFormat, tp_p: int, tp_d: int, count: int,
                     front: int, rmw: bool, kernel: bool):
    """One compiled program per (chunk shape, in-page offset): dequantize
    the whole shard-major slab (2·tp_p, count, S, kvs, hd) in one pass,
    realign TP shards, overlay-scatter both pools. The landing block
    index rides in traced ``lo_block`` so successive chunks of a stream
    reuse the same executable."""
    sc_j = None if sc is None else sc.reshape(pay.shape[:-1] + (1,))
    dec = precision.decode_wire(pay, sc_j, wire, spec.jdtype)
    s = pay.shape[2]
    dec = dec.reshape(2 * tp_p, count * s, -1, spec.head_dim)
    k_d = jnp.concatenate(
        parallel_align.realign_shards(list(dec[:tp_p]), tp_d),
        axis=1).reshape(count, s, -1, spec.head_dim)
    v_d = jnp.concatenate(
        parallel_align.realign_shards(list(dec[tp_p:]), tp_d),
        axis=1).reshape(count, s, -1, spec.head_dim)
    return (_repage_pool_body(spec, k_pool, block_ids, k_d, lo_block,
                              front=front, rmw=rmw, kernel=kernel),
            _repage_pool_body(spec, v_pool, block_ids, v_d, lo_block,
                              front=front, rmw=rmw, kernel=kernel))


@functools.partial(jax.jit, static_argnames=("spec", "wire", "count",
                                             "front", "rmw", "kernel"))
def _repage_mla_part(spec: PC.KVPageSpec, pool: jax.Array, block_ids,
                     pay, sc, lo_block, *, wire: WireFormat, count: int,
                     front: int, rmw: bool, kernel: bool) -> jax.Array:
    sc_j = None if sc is None else sc.reshape(pay.shape[0], 1, 1)
    d = precision.decode_wire(pay, sc_j, wire, spec.jdtype)
    d = d.reshape(count, -1, 1, spec.head_dim)
    return _repage_pool_body(spec, pool, block_ids, d, lo_block,
                             front=front, rmw=rmw, kernel=kernel)


# chunk wire codecs: "fixed" stages zero-copy WireChunks (fixed binary
# layout, single-pass vectorized re-page); "pickle" is the legacy pytree
# blob (kept as the parity/compat baseline)
CODECS = ("fixed", "pickle")


class DisaggPipeline:
    def __init__(self, transfer: KVConnector,
                 wire: Optional[WireFormat] = None,
                 codec: str = "fixed", repage_kernel: bool = False):
        assert codec in CODECS, codec
        self.transfer = transfer
        self.wire = wire or WireFormat(kind="raw", dtype="bfloat16")
        self.codec = codec
        # route the chunk re-page scatter through the Pallas overlay kernel
        # (partial blocks merge inside the kernel) instead of the jnp path
        self.repage_kernel = repage_kernel

    # ------------------------------------------------------------------ #
    # P side: package → wire
    # ------------------------------------------------------------------ #
    def _encode_entry(self, tp_p: int, kind: str, gi: int, pi: int,
                      ent: Dict[str, Any]) -> Dict[str, Any]:
        """One normalized KV entry (chronological, with absolute start) →
        wire entry. Row-wise encodings keep this chunk-split invariant."""
        if kind == "mla":
            # latent cache is TP-replicated — ship rank-0 copy only
            ckv, kpe = np.asarray(ent["ckv"]), np.asarray(ent["kpe"])
            pl_c, sc_c = precision.encode_wire(
                jnp.asarray(ckv)[..., None, :].reshape(-1, 1, ckv.shape[-1]),
                self.wire)
            pl_p, sc_p = precision.encode_wire(
                jnp.asarray(kpe)[..., None, :].reshape(-1, 1, kpe.shape[-1]),
                self.wire)
            return {"kind": "mla", "gi": gi, "pi": pi,
                    "count": ckv.shape[0], "seq": ckv.shape[1],
                    "start": ent["start"],
                    "payloads": [pl_c, pl_p], "scales": [sc_c, sc_p]}
        k, v = np.asarray(ent["k"]), np.asarray(ent["v"])
        count, s, _kv_heads, hd = k.shape
        # TP shard split (P's parallel strategy), per Fig. 4
        shards_k = np.split(k, tp_p, axis=2)
        shards_v = np.split(v, tp_p, axis=2)
        payloads, scales = [], []
        for sh in shards_k + shards_v:
            pl, sc = precision.encode_wire(
                jnp.asarray(sh).reshape(-1, sh.shape[2], hd), self.wire)
            payloads.append(pl)
            scales.append(sc)
        return {"kind": "kv", "gi": gi, "pi": pi, "count": count,
                "seq": s, "start": ent["start"], "tp_p": tp_p,
                "payloads": payloads, "scales": scales}

    def encode_package(self, p_engine: Engine, package: Dict[str, Any]
                       ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        tp_p = p_engine.vendor.tp
        out_kv = [self._encode_entry(tp_p, kind, gi, pi, ent)
                  for kind, gi, pi, ent in
                  kv_entries_with_start(package["kv"])]
        wire_pkg = {"kv": out_kv, "states": package["states"],
                    "cross": package["cross"]}
        meta = {"first_token": package["first_token"],
                "seq_len": package["seq_len"], "tp_p": tp_p,
                "wire": self.wire}
        return wire_pkg, meta

    def encode_chunk(self, p_engine: Engine, chunk: Dict[str, Any]):
        """One prefill chunk ({"kv": normalized entries}) → wire chunk.

        Fixed codec: returns a *planned* :class:`WireChunk` — no KV bytes
        move here; the connector executes the slab plan straight into its
        segment (``write_into``), so the encode is a dtype cast / quantize
        through buffer views with no pickle and no intermediate blob."""
        tp_p = p_engine.vendor.tp
        if self.codec == "fixed":
            return WireChunk.from_entries(chunk["kv"], self.wire, tp_p,
                                          seq_len=chunk.get("length", 0))
        return {"kv": [self._encode_entry(tp_p, kind, gi, pi, ent)
                       for kind, gi, pi, ent in chunk["kv"]]}

    # ------------------------------------------------------------------ #
    # D side: wire → pools
    # ------------------------------------------------------------------ #
    def materialize(self, d_engine: Engine, slot: int, block_ids: np.ndarray,
                    payload: Dict[str, Any], meta: Dict[str, Any], *,
                    rmw: bool = False) -> None:
        """Re-page wire KV entries (and any states/cross rows) into the D
        instance's pools. ``rmw`` preserves the untouched rows of partially
        covered blocks — required when streaming chunks whose boundaries do
        not align with the D vendor's block size."""
        if isinstance(payload, WireChunk):
            self._materialize_wire(d_engine, slot, block_ids, payload,
                                   rmw=rmw)
            return
        tp_d = d_engine.vendor.tp
        wire: WireFormat = meta["wire"]
        caches = [list(g) for g in d_engine.caches]
        bids = jnp.asarray(block_ids, jnp.int32)

        for entry in payload.get("kv", []):
            gi, pi = entry["gi"], entry["pi"]
            count, s, start = entry["count"], entry["seq"], entry["start"]
            if entry["kind"] == "mla":
                spec_c = d_engine.specs["ckv"]
                spec_p = d_engine.specs["kpe"]
                ckv = precision.decode_wire(entry["payloads"][0],
                                            entry["scales"][0], wire,
                                            spec_c.jdtype)
                kpe = precision.decode_wire(entry["payloads"][1],
                                            entry["scales"][1], wire,
                                            spec_p.jdtype)
                ckv = ckv.reshape(count, s, 1, spec_c.head_dim)
                kpe = kpe.reshape(count, s, 1, spec_p.head_dim)
                pools = caches[gi][pi]
                caches[gi][pi] = dict(
                    pools,
                    ckv_pool=self._write_pages(spec_c, pools["ckv_pool"],
                                               bids, ckv, start, rmw=rmw),
                    kpe_pool=self._write_pages(spec_p, pools["kpe_pool"],
                                               bids, kpe, start, rmw=rmw))
                continue
            spec = d_engine.specs["kv"]
            tp_p = entry["tp_p"]
            half = tp_p
            dec = [precision.decode_wire(pl, sc, wire, spec.jdtype)
                   for pl, sc in zip(entry["payloads"], entry["scales"])]
            shards_k = [d.reshape(count, s, -1, spec.head_dim)
                        for d in dec[:half]]
            shards_v = [d.reshape(count, s, -1, spec.head_dim)
                        for d in dec[half:]]
            # parallel-strategy alignment (merge/split), then assemble the
            # full head set for this (tp=1 runtime) D engine's pools.
            k_d = jnp.concatenate(
                parallel_align.realign_shards(
                    [s_.reshape(count * s, -1, spec.head_dim) for s_ in shards_k],
                    tp_d), axis=1).reshape(count, s, -1, spec.head_dim)
            v_d = jnp.concatenate(
                parallel_align.realign_shards(
                    [s_.reshape(count * s, -1, spec.head_dim) for s_ in shards_v],
                    tp_d), axis=1).reshape(count, s, -1, spec.head_dim)
            pools = caches[gi][pi]
            caches[gi][pi] = dict(
                pools,
                k_pool=self._write_pages(spec, pools["k_pool"], bids, k_d,
                                         start, rmw=rmw),
                v_pool=self._write_pages(spec, pools["v_pool"], bids, v_d,
                                         start, rmw=rmw))

        # recurrent / SSM states: place rows at the slot
        for _, gi, pi, state in payload.get("states", []):
            caches[gi][pi] = d_engine._place_fn(caches[gi][pi], state, slot)
        # enc-dec cross attention memory
        for gi, pi, cr in payload.get("cross", []):
            c = dict(caches[gi][pi])
            for name in ("cross_k", "cross_v", "mem_len"):
                c[name] = c[name].at[:, slot].set(
                    jnp.asarray(cr[name]).astype(c[name].dtype))
            caches[gi][pi] = c

        d_engine.caches = tuple(tuple(g) for g in caches)

    def _materialize_wire(self, d_engine: Engine, slot: int,
                          block_ids: np.ndarray, chunk: WireChunk, *,
                          rmw: bool = False) -> None:
        """Fixed-codec fast path: one vectorized decode + one scatter per
        pool, per chunk entry.

        The chunk's kv slab is already shard-major (2·tp_p, count, S, kvs,
        hd) — all shards of all layers in one contiguous view — so a single
        ``decode_wire`` dequantizes the whole entry (vs per-shard decode
        loops), and the re-page is one ``scatter_sequence_overlay`` per
        pool with boundary-only read-modify-write (vs readback of every
        touched page). Bit-identical to the legacy per-entry path."""
        tp_d = d_engine.vendor.tp
        wire = chunk.wire
        caches = [list(g) for g in d_engine.caches]
        bids = jnp.asarray(block_ids, jnp.int32)
        kernel = self.repage_kernel

        for entry in chunk.entries():
            gi, pi = entry["gi"], entry["pi"]
            count, s, start = entry["count"], entry["seq"], entry["start"]
            if entry["kind"] == "mla":
                pools = caches[gi][pi]
                new = {}
                for pay, sc, name in zip(entry["payloads"], entry["scales"],
                                         ("ckv", "kpe")):
                    spec_m = d_engine.specs[name]
                    new[name + "_pool"] = _repage_mla_part(
                        spec_m, pools[name + "_pool"], bids,
                        jnp.array(pay),   # copy: don't alias the segment
                        None if sc is None else jnp.array(sc),
                        start // spec_m.block_size, wire=wire, count=count,
                        front=start % spec_m.block_size, rmw=rmw,
                        kernel=kernel)
                caches[gi][pi] = dict(pools, **new)
                continue
            spec = d_engine.specs["kv"]
            tp_p = entry["tp_p"]
            pay = entry["payload"]           # (2·tp_p, count, S, kvs, hd)
            sc = entry["scales"]
            pools = caches[gi][pi]
            k_pool, v_pool = _repage_kv_entry(
                spec, pools["k_pool"], pools["v_pool"], bids,
                jnp.array(pay),      # copy: don't alias the shm segment
                None if sc is None else jnp.array(sc),
                start // spec.block_size,
                wire=wire, tp_p=tp_p, tp_d=tp_d, count=count,
                front=start % spec.block_size, rmw=rmw, kernel=kernel)
            caches[gi][pi] = dict(pools, k_pool=k_pool, v_pool=v_pool)

        d_engine.caches = tuple(tuple(g) for g in caches)

    @staticmethod
    def _write_pages_vec(spec: PC.KVPageSpec, pool: jax.Array, block_ids,
                         canon: jax.Array, start: int, *, rmw: bool = False,
                         kernel: bool = False) -> jax.Array:
        """Jit-compiled single-pass re-page (see
        :func:`_repage_pool_body`); one compiled program per
        (spec, chunk shape, in-page offset)."""
        return _repage_pool(spec, pool, jnp.asarray(block_ids, jnp.int32),
                            jnp.asarray(canon), start // spec.block_size,
                            front=start % spec.block_size, rmw=rmw,
                            kernel=kernel)

    @staticmethod
    def _write_pages(spec: PC.KVPageSpec, pool: jax.Array, block_ids,
                     canon: jax.Array, start: int, *,
                     rmw: bool = False) -> jax.Array:
        """canon: (count, S, kv, hd) holding absolute positions
        [start, start+S) → scatter into pages (vmapped over layer count).

        Whole-sequence writes zero-fill block padding; ``rmw`` reads the
        touched pages back and overlays only [start, start+S), so a later
        chunk cannot clobber an earlier chunk sharing its first block."""
        bs = spec.block_size
        lo_block = start // bs
        front = start - lo_block * bs
        s_tot = front + canon.shape[1]
        nb = -(-s_tot // bs)
        use = block_ids[lo_block:lo_block + nb]
        if not rmw:
            if front:
                canon = jnp.pad(canon, ((0, 0), (front, 0), (0, 0), (0, 0)))
            return jax.vmap(lambda pl, cn: PC.scatter_sequence(spec, pl, use, cn)
                            )(pool, canon)

        def wr(pl, cn):
            cur = PC.pages_to_canonical(spec, pl[use])       # (nb, bs, kv, hd)
            flat = cur.reshape(nb * bs, spec.kv_heads, spec.head_dim)
            flat = jax.lax.dynamic_update_slice(
                flat, cn.astype(flat.dtype), (front, 0, 0))
            pages = PC.pages_from_canonical(
                spec, flat.reshape(nb, bs, spec.kv_heads, spec.head_dim))
            return pl.at[use].set(pages)

        return jax.vmap(wr)(pool, canon)

    # ------------------------------------------------------------------ #
    # Monolithic handoff (baseline transmission)
    # ------------------------------------------------------------------ #
    def handoff(self, req: Request, p_engine: Engine, d_engine: Engine
                ) -> Dict[str, Any]:
        """prefill-package → stage → issue_read → wait → materialize.

        Synchronous by construction: the monolithic wire has nothing to
        overlap, so ``wait()`` force-completes the read (with a modeled
        backend the whole wire time lands exposed). Returns meta."""
        self.transfer.register(p_engine.name, role="prefill")
        self.transfer.register(d_engine.name, role="decode")
        package = p_engine.prefill(req)
        wire_pkg, meta = self.encode_package(p_engine, package)
        # retry-unique key: a failed handoff leaves no stale staging to
        # collide with the requeued attempt
        key = f"{req.req_id}@{p_engine.name}#t{req.retries}"
        nbytes = self.transfer.stage(key, wire_pkg, meta)
        try:
            payload, meta = self.transfer.issue_read(key).wait()
            payload = _to_device(payload)

            def materialize_fn(engine, slot, bids, _pkg):
                self.materialize(engine, slot, bids, payload, meta)

            d_engine.add_sequence(req, {"first_token": meta["first_token"],
                                        "seq_len": meta["seq_len"]},
                                  materialize_fn)
        except Exception:
            self.transfer.drop(key)    # free the pinned staging on failure
            raise
        self.transfer.complete(key)
        meta["bytes"] = nbytes
        return meta

    # ------------------------------------------------------------------ #
    # Streamed chunked handoff (overlapped transmission)
    # ------------------------------------------------------------------ #
    def begin_handoff(self, req: Request, p_engine: Engine, d_engine: Engine,
                      seq_len: int,
                      compute_overlapped: bool = False) -> "StreamedHandoff":
        """Reserve the D slot/blocks and open a chunk stream for ``req``.

        ``compute_overlapped``: the chunks come from an *incremental*
        prefill, so each chunk's wire time hides under the next chunk's
        compute (credited to TransferStats.overlap_modeled_seconds). A
        monolithic-compute stream ships after all P compute finished —
        nothing to hide under, no overlap credit."""
        return StreamedHandoff(self, req, p_engine, d_engine, seq_len,
                               compute_overlapped=compute_overlapped)

    def handoff_streamed(self, req: Request, p_engine: Engine,
                         d_engine: Engine,
                         chunk_tokens: Optional[int] = None,
                         chunked_compute: Optional[bool] = None,
                         mode=None) -> Dict[str, Any]:
        """Drive a full streamed handoff synchronously (tests / examples;
        the global scheduler advances the same protocol tick by tick)."""
        stream = p_engine.prefill_stream(req, chunk_tokens, chunked_compute,
                                         mode=mode)
        h = self.begin_handoff(req, p_engine, d_engine, stream.seq_len,
                               compute_overlapped=stream.chunked_compute)
        try:
            while True:
                chunk = stream.next_chunk()
                if chunk is None:
                    break
                if not chunk["kv"] and chunk["length"] == 0:
                    continue            # compute-only progress marker
                h.send_chunk(chunk)
                h.poll_reads()          # re-page whatever the wire delivered
            return h.finalize(stream.first_token, stream.tail_package())
        except Exception:
            h.abort()
            raise


class StreamedHandoff:
    """State of one in-flight chunked P→D handoff.

    Lifecycle: reserve (ctor) → (``send_chunk`` | ``poll_reads``)×N →
    ``finalize`` | ``abort``. ``send_chunk`` encodes one chunk, stages it
    into the pinned pool, and *issues* an async wire read; ``poll_reads``
    re-pages chunks whose :class:`TransferHandle` reports complete — the
    D-side re-page runs on its own tick budget, decoupled from wire time.
    Chunks re-page in issue order (the wire is an ordered channel), so a
    later chunk never lands before an earlier one that shares a block."""

    def __init__(self, pipeline: DisaggPipeline, req: Request,
                 p_engine: Engine, d_engine: Engine, seq_len: int, *,
                 compute_overlapped: bool = False):
        self.pipeline = pipeline
        self.req = req
        self.p_engine = p_engine
        self.d_engine = d_engine
        self.seq_len = seq_len
        self.compute_overlapped = compute_overlapped
        pipeline.transfer.register(p_engine.name, role="prefill")
        pipeline.transfer.register(d_engine.name, role="decode")
        self.slot, self.block_ids = d_engine.reserve_sequence(
            req, seq_len, use_prefix_cache=True)
        # prefix tokens already resident on D: chunks below this position
        # never touch the wire (send_chunk slices / drops them)
        self.wire_skip = d_engine.slot_prefix_tokens[self.slot]
        self.meta = {"seq_len": seq_len, "tp_p": p_engine.vendor.tp,
                     "wire": pipeline.wire}
        self.chunks_sent = 0
        self.chunks_repaged = 0
        self.bytes = 0
        self._skipped_tokens = 0
        self._sent_tokens = 0
        self._pending: Deque[Tuple[str, TransferHandle, float, float]] = \
            collections.deque()
        self._chunk_modeled: List[float] = []
        self._chunk_compute: List[float] = []
        # wall-clock (measured) handoff timings — time.monotonic so the
        # same accounting is comparable across OS processes on one host
        self._t_first_stage: Optional[float] = None
        self._t_last_repage: Optional[float] = None
        self._chunk_wall_pending: List[float] = []
        self._closed = False

    # -- wire side -------------------------------------------------------- #
    def can_send(self) -> bool:
        """Channel has room for another issued-but-unread chunk (the
        connector's ``max_inflight`` capability, not a constant here).
        The channel is shared: concurrent flights throttle against the
        connector's *global* in-flight count, not their own queue."""
        caps = self.pipeline.transfer.capabilities()
        return self.pipeline.transfer.inflight_reads() < caps.max_inflight

    def pending_reads(self) -> int:
        """Chunks issued on the wire but not yet re-paged on D."""
        return len(self._pending)

    def send_chunk(self, chunk: Dict[str, Any]) -> int:
        """Encode → stage → issue the wire read for one chunk. Returns its
        staged bytes. If the channel is full, force-completes the oldest
        read first (blocking send — its wire time lands exposed)."""
        assert not self._closed, "send_chunk on a closed handoff"
        if self.d_engine.failed:
            raise RuntimeError(f"instance {self.d_engine.name} is down")
        start, length = chunk["start"], chunk["length"]
        if self.wire_skip > start:
            skipped = min(self.wire_skip, start + length) - start
            self._skipped_tokens += skipped
            self.pipeline.transfer.stats.prefix_hit_tokens += skipped
            if start + length <= self.wire_skip:
                return 0               # fully resident on D: skip the wire
            chunk = dict(chunk,
                         kv=slice_kv_entries(chunk["kv"], self.wire_skip,
                                             start + length),
                         start=self.wire_skip,
                         length=start + length - self.wire_skip)
        self._sent_tokens += chunk["length"]
        while not self.can_send():
            if not self._repage_head(force=True):
                break                  # channel held by other flights —
        #                                issue_read below surfaces the limit
        tr = self.pipeline.transfer
        wire_chunk = self.pipeline.encode_chunk(self.p_engine, chunk)
        key = f"{self.req.req_id}@{self.p_engine.name}" \
              f"#t{self.req.retries}c{self.chunks_sent}"
        if self._t_first_stage is None:
            self._t_first_stage = time.monotonic()
        nbytes = tr.stage(key, wire_chunk, self.meta)
        try:
            handle = tr.issue_read(key)
        except Exception:
            tr.drop(key)
            raise
        self._pending.append((key, handle,
                              chunk.get("compute_seconds", 0.0),
                              time.monotonic()))
        self.chunks_sent += 1
        self.bytes += nbytes
        return nbytes

    # -- D side ----------------------------------------------------------- #
    def _repage_head(self, force: bool = False) -> bool:
        """Re-page the oldest pending chunk if its read completed (or
        unconditionally when ``force``). Returns True if it re-paged."""
        if not self._pending:
            return False
        key, handle, compute_s, t_issue = self._pending[0]
        if not force and not handle.poll():
            return False
        if self.d_engine.failed:
            raise RuntimeError(f"instance {self.d_engine.name} is down")
        tr = self.pipeline.transfer
        payload, meta = handle.wait()
        self.pipeline.materialize(self.d_engine, self.slot, self.block_ids,
                                  _to_device(payload), meta, rmw=True)
        if hasattr(payload, "release"):
            payload.release()      # drop zero-copy views before the segment
            #                        backing this chunk is closed
        tr.complete(key)
        tr.stats.chunks += 1
        self._chunk_modeled.append(tr.modeled_latency(handle.nbytes))
        self._chunk_compute.append(compute_s)
        self._t_last_repage = time.monotonic()
        self._chunk_wall_pending.append(self._t_last_repage - t_issue)
        self._pending.popleft()
        self.chunks_repaged += 1
        return True

    def poll_reads(self, budget: Optional[int] = None) -> int:
        """Re-page up to ``budget`` completed chunks (None = every chunk
        whose handle polls complete). The scheduler calls this with its
        per-tick re-page budget — separate from the chunk-send budget."""
        done = 0
        while (budget is None or done < budget) and self._repage_head():
            done += 1
        return done

    def drain(self) -> int:
        """Force-complete and re-page every pending read (sync fallback)."""
        done = 0
        while self._repage_head(force=True):
            done += 1
        return done

    def finalize(self, first_token: int, tail_package: Dict[str, Any]
                 ) -> Dict[str, Any]:
        """Ship recurrent/cross state, activate the D slot, account overlap."""
        assert not self._closed
        self.drain()
        tr = self.pipeline.transfer
        if tail_package.get("states") or tail_package.get("cross"):
            key = f"{self.req.req_id}@{self.p_engine.name}" \
                  f"#t{self.req.retries}tail"
            nbytes = tr.stage(key, {"states": tail_package["states"],
                                    "cross": tail_package["cross"]},
                              self.meta)
            payload, meta = tr.issue_read(key).wait()
            self.pipeline.materialize(self.d_engine, self.slot,
                                      self.block_ids, _to_device(payload),
                                      meta)
            tr.complete(key)
            self.bytes += nbytes
        self.d_engine.activate_sequence(self.slot, first_token, self.seq_len)
        # incremental compute: chunk i's wire time hides under chunk i+1's
        # compute, but only as much of it as that compute can cover — on a
        # wire-bound link most of the transfer stays exposed (same residue
        # the planner's handoff_exposed_seconds models). Monolithic compute
        # ships after all P compute: no overlap credit at all.
        if self.compute_overlapped:
            tr.stats.overlap_modeled_seconds += sum(
                min(xfer, comp) for xfer, comp in
                zip(self._chunk_modeled[:-1], self._chunk_compute[1:]))
            # measured counterpart: wall time a chunk actually spent pending
            # on the wire, capped by the next chunk's compute wall time. On
            # an instant in-process wire this is ~0 (nothing truly ran
            # concurrently); in the two-process runtime the launcher
            # measures real cross-process concurrency instead.
            tr.stats.wall_overlap_seconds += sum(
                min(pend, comp) for pend, comp in
                zip(self._chunk_wall_pending[:-1], self._chunk_compute[1:]))
        if self._t_first_stage is not None and self._t_last_repage is not None:
            tr.stats.wall_handoff_seconds += \
                self._t_last_repage - self._t_first_stage
        if self._skipped_tokens and self._sent_tokens and self.bytes:
            # the flight's own measured bytes/token prices what the
            # skipped tokens would have cost on this wire format
            tr.stats.bytes_saved += int(
                self.bytes / self._sent_tokens * self._skipped_tokens)
        self._closed = True
        return {"first_token": first_token, "seq_len": self.seq_len,
                "tp_p": self.meta["tp_p"], "wire": self.pipeline.wire,
                "bytes": self.bytes, "chunks": self.chunks_sent}

    def abort(self) -> None:
        """Failure path: drop staged-but-unread chunks and free the D
        reservation (their handles fail with TransferError if waited)."""
        if self._closed:
            return
        self._closed = True
        tr = self.pipeline.transfer
        while self._pending:
            key, handle, _comp, _t = self._pending.popleft()
            handle.cancel()
            tr.drop(key)
        self.d_engine.abort_reservation(self.slot)
