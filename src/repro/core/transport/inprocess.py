"""In-process connector: the wire is process memory (default backend).

Zero-copy staging — the staged pytree *is* what the read returns — with
byte and modeled-latency accounting, exactly the semantics of the original
monolithic ``TransferEngine``. Reads complete at issue time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

from repro.core.transport import wirefmt
from repro.core.transport.base import KVConnector, tree_bytes


class InProcessConnector(KVConnector):
    transport = "inproc"

    def __init__(self, bandwidth_gbps: float = 25.0,
                 buffer_capacity_bytes: int = 1 << 32,
                 max_inflight: int = 32):
        super().__init__(bandwidth_gbps=bandwidth_gbps,
                         buffer_capacity_bytes=buffer_capacity_bytes,
                         fixed_latency_s=0.0, max_inflight=max_inflight)
        self._staged: Dict[str, Tuple[Any, Dict[str, Any]]] = {}

    def capabilities(self):
        return dataclasses.replace(super().capabilities(),
                                   cross_process=False, zero_copy=True,
                                   wire_codec="fixed",
                                   header_bytes=wirefmt.nominal_header_bytes())

    # -- storage hooks ---------------------------------------------------- #
    def _put(self, key: str, payload, meta: Dict[str, Any]) -> int:
        nbytes = tree_bytes(payload)
        self.pool.acquire(nbytes)
        self._staged[key] = (payload, meta)
        return nbytes

    def _get(self, key: str) -> Tuple[Any, Dict[str, Any]]:
        return self._staged[key]

    def _evict(self, key: str) -> None:
        self._staged.pop(key, None)
