"""Pluggable KV-transport connector API (paper §III-B wire seam).

The paper's heterogeneous compatible transmission module assumes an
RDMA-style stage/read wire between the P and D instances. This package
makes that wire a *pluggable* connector — the shape SGLang's PD
disaggregation uses for its transfer backends (Mooncake, NIXL) and vLLM's
production stack uses for its ``kv_connector`` — so the serving stack,
planner, and scheduler program against one interface:

  control-plane  ``register(peer)`` / ``stage(key, payload, meta)``
  data-plane     ``issue_read(key)`` → :class:`TransferHandle` with
                 ``poll()`` / ``wait()`` async completion, then
                 ``complete(key)`` (D consumed it) or ``drop(key)``
                 (P-side failure)
  descriptor     ``capabilities()`` — bandwidth, fixed latency, max
                 in-flight reads, chunk granularity — consumed by the
                 planner's communication operator library and the global
                 scheduler instead of hard-coded constants.

Completion is asynchronous: a read may stay in flight across scheduler
ticks (``tick()`` advances connector-internal time), which is what lets a
D instance run decode steps while a chunk's wire transfer is still on the
wire — the "true async transfer engine" split of wire time and D-side
re-page into separate tick budgets.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def tree_bytes(tree) -> int:
    """Total array bytes in a staged pytree."""
    return sum(x.nbytes for x in jax.tree.leaves(tree)
               if hasattr(x, "nbytes"))


class TransferError(RuntimeError):
    """Wire-level failure: key lost mid-stream, dropped payload, or an
    over-subscribed channel. Subclasses RuntimeError so the scheduler's
    dispatch-failure sweep requeues the request."""


@dataclasses.dataclass
class TransferStats:
    transfers: int = 0
    bytes_moved: int = 0            # wire bytes (what actually crossed)
    payload_bytes: int = 0          # raw canonical KV bytes those carried —
    #                                 bytes_moved/payload_bytes < 1 means the
    #                                 wire compressed (int8), > 1 means
    #                                 format overhead (headers) dominated
    chunks: int = 0                 # streamed KV chunks (overlapped handoff)
    stage_seconds: float = 0.0      # wall time spent staging (P side)
    read_seconds: float = 0.0       # wall time spent reading (D side)
    modeled_seconds: float = 0.0    # fixed latency + bytes / modeled bandwidth
    overlap_modeled_seconds: float = 0.0  # modeled wire time hidden under
    #                                       the next chunk's prefill compute
    # wall-clock (measured, not modeled) handoff timings. In one process a
    # chunk on an instant wire re-pages in the tick it was sent, so measured
    # overlap is ~0; across real P/D processes the wire interval genuinely
    # runs concurrent with the next chunk's prefill compute and these fields
    # report what was actually hidden.
    wall_handoff_seconds: float = 0.0   # first stage → last re-page, per flight
    wall_overlap_seconds: float = 0.0   # measured wire time under prefill compute
    peak_buffer_bytes: int = 0
    retries: int = 0                # scheduler requeues charged to the wire
    # shared-prefix cache: tokens whose KV never touched the wire because
    # the decode side already held them, and the wire bytes that saved
    # (estimated from the flight's measured bytes/token)
    prefix_hit_tokens: int = 0
    bytes_saved: int = 0
    # link congestion: modeled extra wire time concurrent flights cost each
    # other on a shared link (fair-share arbitration), plus the measured
    # attribution — read wall time delivered while other reads were still
    # in flight, and the peak number of simultaneous in-flight reads
    congested_seconds: float = 0.0
    contended_read_seconds: float = 0.0
    concurrent_reads_peak: int = 0

    # fields merged by max() instead of summed (high-water marks)
    _PEAK_FIELDS = ("peak_buffer_bytes", "concurrent_reads_peak")

    @property
    def exposed_modeled_seconds(self) -> float:
        """Modeled wire time left on the critical path after overlap."""
        return self.modeled_seconds - self.overlap_modeled_seconds

    @property
    def wire_compression(self) -> float:
        """Measured wire/payload byte ratio (< 1: compressed; > 1:
        format overhead). 1.0 until anything moved."""
        if not self.payload_bytes:
            return 1.0
        return self.bytes_moved / self.payload_bytes

    def merge(self, other: "TransferStats") -> None:
        """Fold another connector's counters into this one (the two-process
        runtime aggregates the P-side and D-side connectors' stats)."""
        for f in dataclasses.fields(self):
            if f.name in self._PEAK_FIELDS:
                setattr(self, f.name, max(getattr(self, f.name),
                                          getattr(other, f.name)))
            else:
                setattr(self, f.name,
                        getattr(self, f.name) + getattr(other, f.name))


class PinnedBufferPool:
    """Fixed-capacity staging pool with high-water accounting.

    Registered-once semantics: acquire/release only move a watermark — no
    per-transfer allocation, mirroring the paper's pre-registered RDMA
    buffers (zero-copy)."""

    def __init__(self, capacity_bytes: int):
        self.capacity = capacity_bytes
        self.in_use = 0
        self.high_water = 0

    def acquire(self, nbytes: int) -> None:
        if self.in_use + nbytes > self.capacity:
            raise MemoryError(
                f"pinned pool exhausted: {self.in_use + nbytes} > {self.capacity}")
        self.in_use += nbytes
        self.high_water = max(self.high_water, self.in_use)

    def release(self, nbytes: int) -> None:
        if nbytes > self.in_use:
            raise ValueError(
                f"pinned pool over-release: {nbytes} > in_use {self.in_use} "
                "(double release?)")
        self.in_use -= nbytes


@dataclasses.dataclass(frozen=True)
class ConnectorCapabilities:
    """What the wire can do — consumed by the planner (communication
    operator library) and the global scheduler instead of constants."""
    transport: str                  # registry name of the backend
    bandwidth_gbps: float           # modeled wire bandwidth
    fixed_latency_s: float = 0.0    # per-read setup latency (handshake/DMA)
    max_inflight: int = 32          # concurrent issued-but-unread reads
    chunk_bytes: int = 0            # preferred wire granularity (0 = any)
    cross_process: bool = False     # payloads survive a process boundary
    zero_copy: bool = True          # reads return the staged buffers
    # how concurrent in-flight reads share the link: "exclusive" reads
    # serialize (one at a time at full bandwidth); "fair" reads progress
    # simultaneously at bandwidth/n (processor-sharing arbitration)
    link_sharing: str = "exclusive"
    # wire encoding of staged KV chunks ("fixed" = zero-copy fixed-layout
    # segments, "pickle" = legacy blob) and its fixed per-chunk overhead
    wire_codec: str = "pickle"
    header_bytes: int = 0

    @property
    def bandwidth_bytes_s(self) -> float:
        return self.bandwidth_gbps * 1e9

    def wire_seconds(self, nbytes: float) -> float:
        """Modeled time for one read of ``nbytes`` on this wire."""
        if nbytes <= 0:
            return 0.0
        return self.fixed_latency_s + nbytes / self.bandwidth_bytes_s


class TransferHandle:
    """Async completion handle for one issued read.

    ``poll()`` is non-blocking: True once the modeled wire time has elapsed
    (connector time advances via ``tick()``). ``wait()`` force-completes —
    it fast-forwards the connector clock to the handle's ready time and
    returns ``(payload, meta)``; the skipped wire time is fully exposed.
    ``wait()`` after the staged payload was dropped raises
    :class:`TransferError`."""

    def __init__(self, connector: "KVConnector", key: str, nbytes: int,
                 ready_at: float):
        self.connector = connector
        self.key = key
        self.nbytes = nbytes
        self.ready_at = ready_at
        self._result: Optional[Tuple[Any, Dict[str, Any]]] = None
        self._settled = False

    @property
    def in_flight(self) -> bool:
        return not self._settled

    def poll(self) -> bool:
        """Non-blocking: has the wire delivered this read?"""
        if self._settled:
            return True
        return self.connector._handle_ready(self)

    def wait(self) -> Tuple[Any, Dict[str, Any]]:
        """Complete the read (fast-forwarding modeled wire time if it is
        still in flight) and return ``(payload, meta)``."""
        if self._result is not None:
            return self._result
        if self._settled:                      # settled with an error before
            raise TransferError(
                f"transfer {self.key!r} already failed")
        t0 = time.perf_counter()
        contended = self.connector._inflight > 1   # others also in flight
        self.connector._advance_for(self)
        try:
            payload, meta = self.connector._fetch(self.key)
        except KeyError:
            self._settle()
            raise TransferError(
                f"transfer key {self.key!r} lost mid-stream "
                "(staged payload dropped — P failure?)") from None
        self._settle()
        self._result = (payload, meta)
        # stats account *delivered* reads, not issued ones — an aborted
        # flight's cancelled handles never inflate the wire counters
        stats = self.connector.stats
        stats.transfers += 1
        stats.bytes_moved += self.nbytes
        stats.payload_bytes += self.connector._payload_sizes.get(
            self.key, self.nbytes)
        stats.modeled_seconds += self.connector.modeled_latency(self.nbytes)
        elapsed = time.perf_counter() - t0
        stats.read_seconds += elapsed
        if contended:                  # measured attribution: this read's
            #                            wall time ran under link concurrency
            stats.contended_read_seconds += elapsed
        return self._result

    def cancel(self) -> None:
        """Abandon the read (flight aborted) — frees its channel slot.
        A later ``wait()`` raises :class:`TransferError`."""
        self._settle()

    def _settle(self) -> None:
        if not self._settled:
            self._settled = True
            self.connector._inflight = max(self.connector._inflight - 1, 0)
            self.connector._on_settle(self)


class KVConnector:
    """Base class for KV-transport backends.

    Subclasses override the storage hooks ``_put`` / ``_get`` / ``_evict``
    (and optionally ``_ready_time`` / ``tick`` for modeled-latency wires).
    The base class owns the pinned staging pool, stats, peer registry, and
    handle bookkeeping shared by every backend.
    """

    transport = "base"

    def __init__(self, bandwidth_gbps: float = 25.0,
                 buffer_capacity_bytes: int = 1 << 32,
                 fixed_latency_s: float = 0.0,
                 max_inflight: int = 32):
        self.bandwidth = bandwidth_gbps * 1e9
        self.bandwidth_gbps = bandwidth_gbps
        self.fixed_latency_s = fixed_latency_s
        self.max_inflight = max(max_inflight, 1)   # 0 would deadlock sends
        self.pool = PinnedBufferPool(buffer_capacity_bytes)
        self.stats = TransferStats()
        self._peers: Dict[str, Dict[str, Any]] = {}
        self._sizes: Dict[str, int] = {}
        self._payload_sizes: Dict[str, int] = {}   # raw bytes behind each key
        self._now = 0.0                # connector-internal (modeled) clock
        self._inflight = 0

    # -- descriptor ------------------------------------------------------- #
    def capabilities(self) -> ConnectorCapabilities:
        return ConnectorCapabilities(
            transport=self.transport,
            bandwidth_gbps=self.bandwidth_gbps,
            fixed_latency_s=self.fixed_latency_s,
            max_inflight=self.max_inflight)

    # -- control plane ---------------------------------------------------- #
    def register(self, peer: str, **meta: Any) -> None:
        """Announce an endpoint (a P or D instance). Idempotent — the
        RDMA analogue of registering a memory region with the NIC."""
        self._peers.setdefault(peer, {}).update(meta)

    def peers(self) -> List[str]:
        return sorted(self._peers)

    def stage(self, key: str, payload, meta: Optional[Dict[str, Any]] = None
              ) -> int:
        """Register a payload (pytree) for remote read. Returns the bytes
        it occupies in the staging pool."""
        if key in self._sizes:
            raise ValueError(f"transfer key {key!r} already staged")
        t0 = time.perf_counter()
        if hasattr(payload, "write_into"):     # WireChunk: already planned
            payload_bytes = payload.payload_nbytes
        else:
            payload = jax.tree.map(
                lambda x: np.asarray(x) if hasattr(x, "shape") else x,
                payload)
            payload_bytes = tree_bytes(payload)
        nbytes = self._put(key, payload, meta or {})
        self._sizes[key] = nbytes
        self._payload_sizes[key] = payload_bytes
        self.stats.stage_seconds += time.perf_counter() - t0
        self.stats.peak_buffer_bytes = self.pool.high_water
        return nbytes

    # -- data plane ------------------------------------------------------- #
    def issue_read(self, key: str) -> TransferHandle:
        """Start an RDMA-read of a staged key. Returns a handle that
        completes asynchronously (``poll()`` / ``wait()``)."""
        if key not in self._sizes:
            raise KeyError(f"transfer key {key!r} not staged (P lost?)")
        if self._inflight >= self.max_inflight:
            raise TransferError(
                f"connector channel full: {self._inflight} reads in flight "
                f"(max_inflight={self.max_inflight})")
        nbytes = self._sizes[key]
        self._inflight += 1
        self.stats.concurrent_reads_peak = max(
            self.stats.concurrent_reads_peak, self._inflight)
        handle = TransferHandle(self, key, nbytes, self._ready_time(nbytes))
        self._on_issue(handle)
        return handle

    def read(self, key: str):
        """Synchronous convenience: issue + wait in one call (the legacy
        ``TransferEngine.read`` shape)."""
        return self.issue_read(key).wait()

    def complete(self, key: str) -> None:
        """D finished materializing — free the staging buffer."""
        nbytes = self._sizes.pop(key, None)
        self._payload_sizes.pop(key, None)
        if nbytes is None:
            return                     # idempotent: already completed/dropped
        self._evict(key)
        self.pool.release(nbytes)

    def drop(self, key: str) -> None:
        """P-side failure path: drop a staged payload. Handles still in
        flight for it fail with :class:`TransferError` on ``wait()``."""
        self.complete(key)

    def staged_keys(self) -> List[str]:
        return sorted(self._sizes)

    def inflight_reads(self) -> int:
        return self._inflight

    # -- modeled time ----------------------------------------------------- #
    def modeled_latency(self, nbytes: int) -> float:
        return self.capabilities().wire_seconds(nbytes)

    def tick(self, dt: Optional[float] = None) -> None:
        """Advance connector-internal time by one scheduler tick. Instant
        backends complete at issue time, so this is a no-op."""

    def _ready_time(self, nbytes: int) -> float:
        """Connector time at which a read issued now completes. Instant
        backends deliver at issue time."""
        return self._now

    def _advance_to(self, t: float) -> None:
        self._now = max(self._now, t)

    # -- handle hooks (overridden by link-sharing backends) ---------------- #
    def _handle_ready(self, handle: "TransferHandle") -> bool:
        """Has the wire delivered ``handle``? Default: static ready time."""
        return self._now >= handle.ready_at

    def _advance_for(self, handle: "TransferHandle") -> None:
        """Fast-forward the modeled clock until ``handle`` completes."""
        self._advance_to(handle.ready_at)

    def _on_issue(self, handle: "TransferHandle") -> None:
        """A read was just issued (link-sharing backends register flows)."""

    def _on_settle(self, handle: "TransferHandle") -> None:
        """A handle settled (delivered or cancelled) — release link state."""

    # -- storage hooks (backend-specific) --------------------------------- #
    def _put(self, key: str, payload, meta: Dict[str, Any]) -> int:
        raise NotImplementedError

    def _get(self, key: str) -> Tuple[Any, Dict[str, Any]]:
        raise NotImplementedError

    def _evict(self, key: str) -> None:
        """Remove a staged entry's backing storage (bookkeeping is done)."""
        raise NotImplementedError

    def _fetch(self, key: str) -> Tuple[Any, Dict[str, Any]]:
        if key not in self._sizes:
            raise KeyError(key)
        return self._get(key)

    # -- lifecycle -------------------------------------------------------- #
    def close(self) -> None:
        """Release every staged buffer (and any OS-level resources)."""
        for key in list(self._sizes):
            self.drop(key)

    def __del__(self):  # best-effort OS resource cleanup (shm segments)
        try:
            self.close()
        except Exception:
            pass
