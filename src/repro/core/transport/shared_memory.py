"""Shared-memory connector: real cross-process staging.

Each staged wire entry is serialized (pickle of the numpy pytree + meta)
into a ``multiprocessing.shared_memory`` segment, so a D instance running
in *another process* can attach the segment by name and deserialize — the
same stage/attach/read shape a real RDMA or NVLink-peer wire has, minus
the NIC. The pinned pool accounts the serialized footprint (what actually
sits in the shared segment), and reads return fresh deserialized arrays
(no aliasing with the P side, as across a real process boundary).

Two-process protocol (the multiproc serving runtime): the P side stages
and ships ``export_descriptor(key)`` over the control plane; the D side
``adopt_segment``\\ s the descriptor into *its own* connector — attaching
the OS segment by name, charging its pinned receive pool — after which
``issue_read``/``wait``/``complete`` behave exactly as for locally staged
keys. D's ``complete`` only detaches (the creator owns the segment and
unlinks on its own ``complete``, once told the chunk was consumed).

Segment lifetime is guarded by a ``weakref.finalize`` cleanup: a process
that drops its connector without calling ``drop()``/``close()`` — or exits
normally mid-stream — unlinks every segment it created (and detaches every
segment it adopted) at GC/atexit time, so no named segments outlive the
process. Only a hard kill (``os._exit``/SIGKILL) can skip this; the
two-process launcher covers that path by unlinking a crashed worker's
outstanding segments from the parent.
"""
from __future__ import annotations

import dataclasses
import pickle
import weakref
from multiprocessing import shared_memory
from typing import Any, Dict, Set, Tuple

from repro.core.transport.base import KVConnector


def _cleanup_segments(segments: Dict[str, shared_memory.SharedMemory],
                      adopted: Set[str]) -> None:
    """Finalizer body (must not reference the connector): close every
    segment, unlink the ones this process created."""
    for key, seg in list(segments.items()):
        try:
            seg.close()
            if key not in adopted:
                seg.unlink()
        except Exception:
            pass
    segments.clear()
    adopted.clear()


class SharedMemoryConnector(KVConnector):
    transport = "shm"

    def __init__(self, bandwidth_gbps: float = 25.0,
                 buffer_capacity_bytes: int = 1 << 32,
                 max_inflight: int = 32):
        super().__init__(bandwidth_gbps=bandwidth_gbps,
                         buffer_capacity_bytes=buffer_capacity_bytes,
                         fixed_latency_s=0.0, max_inflight=max_inflight)
        self._segments: Dict[str, shared_memory.SharedMemory] = {}
        self._adopted: Set[str] = set()
        # leak guard: runs at GC *and* interpreter exit, whichever first —
        # a process dying without drop()/close() must not strand OS segments
        self._finalizer = weakref.finalize(
            self, _cleanup_segments, self._segments, self._adopted)

    def capabilities(self):
        return dataclasses.replace(super().capabilities(),
                                   cross_process=True, zero_copy=False)

    def segment_name(self, key: str) -> str:
        """OS-level name of a staged key's segment — what a reader in
        another process attaches to."""
        return self._segments[key].name

    # -- cross-process descriptor plane ----------------------------------- #
    def export_descriptor(self, key: str) -> Dict[str, Any]:
        """Control-plane handle for a staged key: everything a connector in
        another process needs to ``adopt_segment`` and read it."""
        return {"key": key, "segment": self._segments[key].name,
                "nbytes": self._sizes[key]}

    def adopt_segment(self, key: str, segment: str, nbytes: int) -> int:
        """Attach a segment staged by a connector in *another* process so
        ``issue_read(key)`` works locally. Charges this side's pinned pool
        (the receive buffer); ``complete(key)`` detaches without unlinking —
        the creating process owns the segment's lifetime."""
        if key in self._sizes:
            raise ValueError(f"transfer key {key!r} already staged")
        # NOTE: attaching re-registers the name with the resource tracker,
        # which spawn-children share with the launcher — a set, so the
        # creator's eventual unlink unregisters it exactly once. No manual
        # unregister here: it would strip the creator's registration.
        seg = shared_memory.SharedMemory(name=segment)
        try:
            self.pool.acquire(nbytes)
        except Exception:
            seg.close()
            raise
        self._segments[key] = seg
        self._adopted.add(key)
        self._sizes[key] = nbytes
        self.stats.peak_buffer_bytes = self.pool.high_water
        return nbytes

    # -- storage hooks ---------------------------------------------------- #
    def _put(self, key: str, payload, meta: Dict[str, Any]) -> int:
        blob = pickle.dumps((payload, meta), protocol=pickle.HIGHEST_PROTOCOL)
        nbytes = len(blob)
        self.pool.acquire(nbytes)
        try:
            seg = shared_memory.SharedMemory(create=True, size=nbytes)
        except Exception:
            self.pool.release(nbytes)
            raise
        seg.buf[:nbytes] = blob
        self._segments[key] = seg
        return nbytes

    def _get(self, key: str) -> Tuple[Any, Dict[str, Any]]:
        seg = self._segments[key]
        # attach-by-name round trip: deserialize from the OS segment, not
        # from any in-process reference to the staged objects
        reader = shared_memory.SharedMemory(name=seg.name)
        try:
            payload, meta = pickle.loads(bytes(reader.buf[:self._sizes[key]]))
        finally:
            reader.close()
        return payload, meta

    def _evict(self, key: str) -> None:
        seg = self._segments.pop(key, None)
        if seg is None:
            return
        seg.close()
        if key in self._adopted:               # reader side: creator unlinks
            self._adopted.discard(key)
            return
        try:
            seg.unlink()
        except FileNotFoundError:
            pass

    def close(self) -> None:
        super().close()
        self._finalizer()          # idempotent: nothing left, detach atexit
