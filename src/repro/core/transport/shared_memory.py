"""Shared-memory connector: real cross-process staging.

KV chunks (:class:`~repro.core.transport.wirefmt.WireChunk`) are staged
*zero-copy*: the chunk's fixed-layout plan is executed straight into a
``multiprocessing.shared_memory`` segment (dtype cast / quantize through
``np.frombuffer`` views — no ``pickle.dumps``, no intermediate blob), and
a reader gets a bound ``WireChunk`` whose entry arrays are views over the
segment itself. Non-chunk payloads (tail states/cross, legacy codec,
arbitrary pytrees) keep the pickled wire: serialize into the segment,
deserialize on read. The two are distinguished by the segment's leading
magic bytes. The pinned pool accounts the segment footprint either way.

Two-process protocol: same as before — only the bytes inside the segment
changed shape. A zero-copy reader must drop its views (the D re-page path
releases the bound chunk) before ``complete(key)``; ``_evict`` tolerates
stragglers by deferring the close until the buffer is unpinned.

Two-process protocol (the multiproc serving runtime): the P side stages
and ships ``export_descriptor(key)`` over the control plane; the D side
``adopt_segment``\\ s the descriptor into *its own* connector — attaching
the OS segment by name, charging its pinned receive pool — after which
``issue_read``/``wait``/``complete`` behave exactly as for locally staged
keys. D's ``complete`` only detaches (the creator owns the segment and
unlinks on its own ``complete``, once told the chunk was consumed).

Segment lifetime is guarded by a ``weakref.finalize`` cleanup: a process
that drops its connector without calling ``drop()``/``close()`` — or exits
normally mid-stream — unlinks every segment it created (and detaches every
segment it adopted) at GC/atexit time, so no named segments outlive the
process. Only a hard kill (``os._exit``/SIGKILL) can skip this; the
two-process launcher covers that path by unlinking a crashed worker's
outstanding segments from the parent.
"""
from __future__ import annotations

import dataclasses
import pickle
import weakref
from multiprocessing import shared_memory
from typing import Any, Dict, Set, Tuple

from repro.core.transport import wirefmt
from repro.core.transport.base import KVConnector


def _cleanup_segments(segments: Dict[str, shared_memory.SharedMemory],
                      adopted: Set[str]) -> None:
    """Finalizer body (must not reference the connector): close every
    segment, unlink the ones this process created."""
    for key, seg in list(segments.items()):
        try:
            if key not in adopted:
                seg.unlink()
        except Exception:
            pass
        try:
            seg.close()
        except Exception:
            pass                      # BufferError: a view still pins it
    segments.clear()
    adopted.clear()


class SharedMemoryConnector(KVConnector):
    transport = "shm"

    def __init__(self, bandwidth_gbps: float = 25.0,
                 buffer_capacity_bytes: int = 1 << 32,
                 max_inflight: int = 32):
        super().__init__(bandwidth_gbps=bandwidth_gbps,
                         buffer_capacity_bytes=buffer_capacity_bytes,
                         fixed_latency_s=0.0, max_inflight=max_inflight)
        self._segments: Dict[str, shared_memory.SharedMemory] = {}
        self._adopted: Set[str] = set()
        # segments whose close() hit BufferError (a reader's view was still
        # alive) — retried on later evictions and at close()
        self._deferred_close: list = []
        # leak guard: runs at GC *and* interpreter exit, whichever first —
        # a process dying without drop()/close() must not strand OS segments
        self._finalizer = weakref.finalize(
            self, _cleanup_segments, self._segments, self._adopted)

    def capabilities(self):
        return dataclasses.replace(super().capabilities(),
                                   cross_process=True, zero_copy=True,
                                   wire_codec="fixed",
                                   header_bytes=wirefmt.nominal_header_bytes())

    def segment_name(self, key: str) -> str:
        """OS-level name of a staged key's segment — what a reader in
        another process attaches to."""
        return self._segments[key].name

    # -- cross-process descriptor plane ----------------------------------- #
    def export_descriptor(self, key: str) -> Dict[str, Any]:
        """Control-plane handle for a staged key: everything a connector in
        another process needs to ``adopt_segment`` and read it."""
        return {"key": key, "segment": self._segments[key].name,
                "nbytes": self._sizes[key]}

    def adopt_segment(self, key: str, segment: str, nbytes: int) -> int:
        """Attach a segment staged by a connector in *another* process so
        ``issue_read(key)`` works locally. Charges this side's pinned pool
        (the receive buffer); ``complete(key)`` detaches without unlinking —
        the creating process owns the segment's lifetime."""
        if key in self._sizes:
            raise ValueError(f"transfer key {key!r} already staged")
        # NOTE: attaching re-registers the name with the resource tracker,
        # which spawn-children share with the launcher — a set, so the
        # creator's eventual unlink unregisters it exactly once. No manual
        # unregister here: it would strip the creator's registration.
        seg = shared_memory.SharedMemory(name=segment)
        try:
            self.pool.acquire(nbytes)
        except Exception:
            seg.close()
            raise
        self._segments[key] = seg
        self._adopted.add(key)
        self._sizes[key] = nbytes
        self.stats.peak_buffer_bytes = self.pool.high_water
        return nbytes

    # -- storage hooks ---------------------------------------------------- #
    def _put(self, key: str, payload, meta: Dict[str, Any]) -> int:
        if hasattr(payload, "write_into"):     # WireChunk: zero-copy stage
            nbytes = payload.nbytes
            seg = self._new_segment(nbytes)
            payload.write_into(seg.buf)        # cast/quantize into the shm
            self._segments[key] = seg
            return nbytes
        blob = pickle.dumps((payload, meta), protocol=pickle.HIGHEST_PROTOCOL)
        nbytes = len(blob)
        seg = self._new_segment(nbytes)
        seg.buf[:nbytes] = blob
        self._segments[key] = seg
        return nbytes

    def _new_segment(self, nbytes: int) -> shared_memory.SharedMemory:
        self.pool.acquire(nbytes)
        try:
            return shared_memory.SharedMemory(create=True, size=nbytes)
        except Exception:
            self.pool.release(nbytes)
            raise

    def _get(self, key: str) -> Tuple[Any, Dict[str, Any]]:
        # reuse the mapping this connector already holds — staging (P side)
        # and adoption (D side) both attached the segment once; re-attaching
        # by name per read cost an open/mmap/close round trip per chunk
        seg = self._segments[key]
        nbytes = self._sizes[key]
        if nbytes >= len(wirefmt.MAGIC) \
                and bytes(seg.buf[:len(wirefmt.MAGIC)]) == wirefmt.MAGIC:
            chunk = wirefmt.WireChunk.from_buffer(seg.buf)
            return chunk, chunk.meta()         # zero-copy views over the shm
        payload, meta = pickle.loads(bytes(seg.buf[:nbytes]))
        return payload, meta

    def _evict(self, key: str) -> None:
        seg = self._segments.pop(key, None)
        if seg is None:
            return
        adopted = key in self._adopted
        self._adopted.discard(key)
        if not adopted:                        # creator owns the OS name
            try:
                seg.unlink()
            except FileNotFoundError:
                pass
        try:
            seg.close()
        except BufferError:
            # a zero-copy view over this segment is still alive somewhere —
            # defer the munmap; retried on later evictions / close()
            self._deferred_close.append(seg)
        self._retry_deferred()

    def _retry_deferred(self) -> None:
        still = []
        for seg in self._deferred_close:
            try:
                seg.close()
            except BufferError:
                still.append(seg)
        self._deferred_close = still

    def close(self) -> None:
        super().close()
        self._retry_deferred()
        self._finalizer()          # idempotent: nothing left, detach atexit
