"""Shared-memory connector: real cross-process staging.

Each staged wire entry is serialized (pickle of the numpy pytree + meta)
into a ``multiprocessing.shared_memory`` segment, so a D instance running
in *another process* can attach the segment by name and deserialize — the
same stage/attach/read shape a real RDMA or NVLink-peer wire has, minus
the NIC. The pinned pool accounts the serialized footprint (what actually
sits in the shared segment), and reads return fresh deserialized arrays
(no aliasing with the P side, as across a real process boundary).
"""
from __future__ import annotations

import dataclasses
import pickle
from multiprocessing import shared_memory
from typing import Any, Dict, Tuple

from repro.core.transport.base import KVConnector


class SharedMemoryConnector(KVConnector):
    transport = "shm"

    def __init__(self, bandwidth_gbps: float = 25.0,
                 buffer_capacity_bytes: int = 1 << 32,
                 max_inflight: int = 32):
        super().__init__(bandwidth_gbps=bandwidth_gbps,
                         buffer_capacity_bytes=buffer_capacity_bytes,
                         fixed_latency_s=0.0, max_inflight=max_inflight)
        self._segments: Dict[str, shared_memory.SharedMemory] = {}

    def capabilities(self):
        return dataclasses.replace(super().capabilities(),
                                   cross_process=True, zero_copy=False)

    def segment_name(self, key: str) -> str:
        """OS-level name of a staged key's segment — what a reader in
        another process attaches to."""
        return self._segments[key].name

    # -- storage hooks ---------------------------------------------------- #
    def _put(self, key: str, payload, meta: Dict[str, Any]) -> int:
        blob = pickle.dumps((payload, meta), protocol=pickle.HIGHEST_PROTOCOL)
        nbytes = len(blob)
        self.pool.acquire(nbytes)
        try:
            seg = shared_memory.SharedMemory(create=True, size=nbytes)
        except Exception:
            self.pool.release(nbytes)
            raise
        seg.buf[:nbytes] = blob
        self._segments[key] = seg
        return nbytes

    def _get(self, key: str) -> Tuple[Any, Dict[str, Any]]:
        seg = self._segments[key]
        # attach-by-name round trip: deserialize from the OS segment, not
        # from any in-process reference to the staged objects
        reader = shared_memory.SharedMemory(name=seg.name)
        try:
            payload, meta = pickle.loads(bytes(reader.buf[:self._sizes[key]]))
        finally:
            reader.close()
        return payload, meta

    def _evict(self, key: str) -> None:
        seg = self._segments.pop(key, None)
        if seg is not None:
            seg.close()
            try:
                seg.unlink()
            except FileNotFoundError:
                pass
