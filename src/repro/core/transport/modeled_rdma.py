"""Modeled-RDMA connector: async completion over multiple scheduler ticks.

Storage is in-process (this container has no NIC), but every read carries
the modeled wire cost of an RDMA read — a fixed per-read setup latency
plus ``bytes / bandwidth`` — on a connector-internal virtual clock. The
global scheduler advances that clock by ``tick_seconds`` per tick
(``tick()``), so a chunk's handle stays in flight across ticks and decode
steps run *while the wire is busy*; ``wait()`` force-completes by
fast-forwarding the clock (the forced-sync path, fully exposed wire time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.core.transport.base import KVConnector, tree_bytes


class ModeledRDMAConnector(KVConnector):
    transport = "rdma"

    def __init__(self, bandwidth_gbps: float = 25.0,
                 buffer_capacity_bytes: int = 1 << 32,
                 fixed_latency_s: float = 5e-6,
                 max_inflight: int = 32,
                 tick_seconds: float = 1e-4,
                 chunk_bytes: int = 256 << 10):
        super().__init__(bandwidth_gbps=bandwidth_gbps,
                         buffer_capacity_bytes=buffer_capacity_bytes,
                         fixed_latency_s=fixed_latency_s,
                         max_inflight=max_inflight)
        self.tick_seconds = tick_seconds
        self.chunk_bytes = chunk_bytes
        self._staged: Dict[str, Tuple[Any, Dict[str, Any]]] = {}
        self._wire_free_at = 0.0       # the link is a shared serial resource

    def capabilities(self):
        return dataclasses.replace(super().capabilities(),
                                   chunk_bytes=self.chunk_bytes,
                                   cross_process=False, zero_copy=False)

    # -- modeled async completion ----------------------------------------- #
    def tick(self, dt: Optional[float] = None) -> None:
        """One scheduler tick of wire progress on the virtual clock."""
        self._now += self.tick_seconds if dt is None else dt

    def _ready_time(self, nbytes: int) -> float:
        # serialize reads on the link: a read starts when the wire frees up
        start = max(self._now, self._wire_free_at)
        ready = start + self.fixed_latency_s + nbytes / self.bandwidth
        self._wire_free_at = ready
        return ready

    # -- storage hooks ---------------------------------------------------- #
    def _put(self, key: str, payload, meta: Dict[str, Any]) -> int:
        nbytes = tree_bytes(payload)
        self.pool.acquire(nbytes)
        self._staged[key] = (payload, meta)
        return nbytes

    def _get(self, key: str) -> Tuple[Any, Dict[str, Any]]:
        return self._staged[key]

    def _evict(self, key: str) -> None:
        self._staged.pop(key, None)
