"""Modeled-RDMA connector: async completion over multiple scheduler ticks.

Storage is in-process (this container has no NIC), but every read carries
the modeled wire cost of an RDMA read — a fixed per-read setup latency
plus ``bytes / bandwidth`` — on a connector-internal virtual clock. The
global scheduler advances that clock by ``tick_seconds`` per tick
(``tick()``), so a chunk's handle stays in flight across ticks and decode
steps run *while the wire is busy*; ``wait()`` force-completes by
fast-forwarding the clock (the forced-sync path, fully exposed wire time).

Concurrent reads contend for the one link. Two arbitration modes:

  * ``link_sharing="fair"`` (default) — processor sharing: the ``n``
    active flows each drain at ``bandwidth / n``; completion times are
    found event-driven (a flow finishing or activating changes the rate).
    The extra time a flow spent beyond its alone-on-the-link cost is
    accounted to ``TransferStats.congested_seconds``.
  * ``link_sharing="serial"`` — the legacy exclusive link: reads queue and
    run one at a time at full bandwidth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.transport import wirefmt
from repro.core.transport.base import (KVConnector, TransferHandle,
                                       tree_bytes)

_EPS = 1e-12


class _Flow:
    """One in-flight read on the shared link (fair-share mode)."""
    __slots__ = ("remaining", "active_at", "issued_at", "alone", "done_at")

    def __init__(self, nbytes: float, active_at: float, issued_at: float,
                 alone: float):
        self.remaining = float(nbytes)
        self.active_at = active_at     # setup latency elapsed, on the link
        self.issued_at = issued_at
        self.alone = alone             # latency + bytes/bw, uncontended
        self.done_at: Optional[float] = None


class ModeledRDMAConnector(KVConnector):
    transport = "rdma"

    def __init__(self, bandwidth_gbps: float = 25.0,
                 buffer_capacity_bytes: int = 1 << 32,
                 fixed_latency_s: float = 5e-6,
                 max_inflight: int = 32,
                 tick_seconds: float = 1e-4,
                 chunk_bytes: int = 256 << 10,
                 link_sharing: str = "fair"):
        super().__init__(bandwidth_gbps=bandwidth_gbps,
                         buffer_capacity_bytes=buffer_capacity_bytes,
                         fixed_latency_s=fixed_latency_s,
                         max_inflight=max_inflight)
        assert link_sharing in ("fair", "serial"), link_sharing
        self.tick_seconds = tick_seconds
        self.chunk_bytes = chunk_bytes
        self.link_sharing = link_sharing
        self._staged: Dict[str, Tuple[Any, Dict[str, Any]]] = {}
        self._wire_free_at = 0.0       # serial mode: exclusive link queue
        self._flows: List[_Flow] = []  # fair mode: active processor-sharing
        self._pending_flow: Optional[_Flow] = None

    def capabilities(self):
        return dataclasses.replace(
            super().capabilities(),
            chunk_bytes=self.chunk_bytes, cross_process=False,
            zero_copy=False, wire_codec="fixed",
            header_bytes=wirefmt.nominal_header_bytes(),
            link_sharing="fair" if self.link_sharing == "fair"
            else "exclusive")

    # -- modeled async completion ----------------------------------------- #
    def tick(self, dt: Optional[float] = None) -> None:
        """One scheduler tick of wire progress on the virtual clock."""
        target = self._now + (self.tick_seconds if dt is None else dt)
        if self.link_sharing == "fair":
            self._drain(t_target=target)
        else:
            self._now = target

    def _ready_time(self, nbytes: int) -> float:
        if self.link_sharing == "serial":
            # serialize reads on the link: a read starts when it frees up
            start = max(self._now, self._wire_free_at)
            ready = start + self.fixed_latency_s + nbytes / self.bandwidth
            self._wire_free_at = ready
            return ready
        flow = _Flow(nbytes, self._now + self.fixed_latency_s, self._now,
                     self.fixed_latency_s + nbytes / self.bandwidth)
        self._flows.append(flow)
        self._pending_flow = flow
        # optimistic (uncontended) estimate; actual readiness comes from
        # the flow via _handle_ready — contention only pushes it later
        return self._now + flow.alone

    def _on_issue(self, handle: TransferHandle) -> None:
        if self._pending_flow is not None:
            handle._flow = self._pending_flow
            self._pending_flow = None

    def _on_settle(self, handle: TransferHandle) -> None:
        # a cancelled read leaves the link: stop charging its bandwidth
        flow = getattr(handle, "_flow", None)
        if flow is not None and flow.done_at is None and flow in self._flows:
            self._flows.remove(flow)

    def _handle_ready(self, handle: TransferHandle) -> bool:
        flow = getattr(handle, "_flow", None)
        if flow is None:
            return self._now >= handle.ready_at
        return flow.done_at is not None

    def _advance_for(self, handle: TransferHandle) -> None:
        flow = getattr(handle, "_flow", None)
        if flow is None:
            self._advance_to(handle.ready_at)
            return
        self._drain(until_flow=flow)

    # -- processor-sharing link simulation -------------------------------- #
    def _drain(self, t_target: Optional[float] = None,
               until_flow: Optional[_Flow] = None) -> None:
        """Advance the fair-share link event by event: between events the
        ``n`` active flows each drain at ``bandwidth / n``; events are a
        flow activating (its setup latency elapsing) or completing."""
        t = self._now
        while True:
            if until_flow is not None and until_flow.done_at is not None:
                break
            if until_flow is None and (t_target is None
                                       or t >= t_target - _EPS):
                break
            pending = [f for f in self._flows if f.done_at is None]
            # settle zero-byte flows whose setup latency has elapsed
            for f in pending:
                if f.remaining <= 0 and f.active_at <= t + _EPS:
                    f.done_at = max(f.active_at, t)
            pending = [f for f in self._flows if f.done_at is None]
            if until_flow is not None and until_flow.done_at is not None:
                break
            if until_flow is not None and until_flow not in pending:
                break                    # cancelled out from under us
            active = [f for f in pending if f.active_at <= t + _EPS]
            waiting = [f.active_at for f in pending if f.active_at > t + _EPS]
            if not active:
                if waiting:
                    nxt = min(waiting)
                    if t_target is not None and nxt > t_target:
                        t = t_target
                        break
                    t = nxt
                    continue
                t = t_target if t_target is not None else t
                break                    # idle link: jump to the target
            rate = self.bandwidth / len(active)
            t_done = t + min(f.remaining for f in active) / rate
            step = [t_done] + waiting
            if t_target is not None:
                step.append(t_target)
            t_step = min(step)
            dt = t_step - t
            for f in active:
                f.remaining -= dt * rate
            if t_step >= t_done - _EPS:   # at least one flow completed
                # the minimum-remaining flow is done by construction — even
                # when ``dt * rate`` underflows (transfer time below float
                # resolution at t, e.g. tiny payload on a fast link), so
                # the event always retires a flow and the loop progresses
                m = min(f.remaining for f in active)
                for f in active:
                    if f.remaining - m <= 1e-6 or f.remaining <= 1e-6:
                        f.remaining = 0.0
                        f.done_at = t_step
            t = t_step
        self._now = max(self._now, t)
        # account congestion for completed flows and prune them off the link
        for f in self._flows:
            if f.done_at is not None:
                extra = (f.done_at - f.issued_at) - f.alone
                if extra > 1e-12:
                    self.stats.congested_seconds += extra
        self._flows = [f for f in self._flows if f.done_at is None]

    # -- storage hooks ---------------------------------------------------- #
    def _put(self, key: str, payload, meta: Dict[str, Any]) -> int:
        nbytes = tree_bytes(payload)
        self.pool.acquire(nbytes)
        self._staged[key] = (payload, meta)
        return nbytes

    def _get(self, key: str) -> Tuple[Any, Dict[str, Any]]:
        return self._staged[key]

    def _evict(self, key: str) -> None:
        self._staged.pop(key, None)
