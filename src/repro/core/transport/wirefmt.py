"""Fixed-layout zero-copy KV wire format.

The legacy shared-memory wire pickled a pytree of per-shard numpy arrays
into each segment: one serialize copy on P, one deserialize copy on D, and
a Python-object header whose size scales with entry count. This module
replaces it with a *fixed binary layout* so the segment itself is the wire
representation:

    ┌───────────────────────────────────────────────────────────────┐
    │ prelude  magic · version · wire kind/dtype · tp_p · n_entries │
    │          · seq_len · payload_bytes · total_bytes              │
    ├───────────────────────────────────────────────────────────────┤
    │ entry records  kind · gi · pi · start · count · seq · parts   │
    │   part records  dtype · shape · payload_off · scales_off      │
    ├───────────────────────────────────────────────────────────────┤
    │ slab 0  contiguous KV payload (64-byte aligned)               │
    │ slab 0' fp32 scales (int8 wire only)                          │
    │ slab 1  …                                                     │
    └───────────────────────────────────────────────────────────────┘

A :class:`WireChunk` has two states sharing one decode path:

  * *planned* (P side): built from normalized chunk entries; knows its
    exact byte layout up front, so ``write_into(buf)`` casts/quantizes the
    source arrays straight into the destination buffer through
    ``np.frombuffer`` views — no ``pickle.dumps``, no intermediate blob.
  * *bound* (D side): ``from_buffer`` parses the header of an adopted
    segment and ``entries()`` yields zero-copy numpy views over its slabs.

A planned chunk read in-process (inproc/rdma backends) lazily serializes
to a local buffer and decodes through the same bound path, so the bits a
reader sees are identical across every backend. ``release()`` drops all
buffer references so the shared-memory segment can close without
``BufferError`` (numpy views pin the exported buffer).
"""
from __future__ import annotations

import struct
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compat import precision
from repro.core.compat.precision import WireFormat

MAGIC = b"RKVWIRE1"
VERSION = 1
_ALIGN = 64
_NO_SCALES = 0xFFFFFFFFFFFFFFFF

# magic(8) version(H) wire_kind(B) wire_dtype(B) tp_p(H) n_entries(H)
# seq_len(I) payload_bytes(Q) total_bytes(Q)
_PRELUDE = struct.Struct("<8sHBBHHIQQ")
# kind(B) n_parts(B) gi(H) pi(H) start(I) count(I) seq(I)
_ENTRY = struct.Struct("<BBHHIII")
# dtype(B) ndim(B) shape[5](I) payload_off(Q) scales_off(Q)
_PART = struct.Struct("<BB5IQQ")

_WIRE_KINDS = ("raw", "int8")
_ENTRY_KINDS = ("kv", "mla")
# wire payload dtypes (names resolved through jnp for bfloat16 interop)
_DTYPES = ("float32", "bfloat16", "float16", "int8")


def _dtype_code(dt: np.dtype) -> int:
    name = np.dtype(dt).name if np.dtype(dt).name in _DTYPES else None
    if name is None:
        # ml_dtypes bfloat16 reports name "bfloat16"; anything else is a bug
        raise ValueError(f"unsupported wire dtype {dt!r}")
    return _DTYPES.index(name)


def _align(off: int) -> int:
    return (off + _ALIGN - 1) // _ALIGN * _ALIGN


def nominal_header_bytes(n_entries: int = 1, parts_per_entry: int = 1) -> int:
    """Planner-facing estimate of the fixed per-chunk wire overhead."""
    return _align(_PRELUDE.size
                  + n_entries * (_ENTRY.size + parts_per_entry * _PART.size))


class _Part:
    __slots__ = ("dtype", "shape", "payload_off", "scales_off")

    def __init__(self, dtype: np.dtype, shape: Tuple[int, ...],
                 payload_off: int, scales_off: int):
        self.dtype = np.dtype(dtype)
        self.shape = shape
        self.payload_off = payload_off
        self.scales_off = scales_off

    @property
    def payload_nbytes(self) -> int:
        return int(np.prod(self.shape)) * self.dtype.itemsize

    @property
    def scales_count(self) -> int:
        # one fp32 scale per (token, head) row: payload elems / last axis
        return int(np.prod(self.shape)) // self.shape[-1]


class _Entry:
    __slots__ = ("kind", "gi", "pi", "start", "count", "seq", "parts", "src")

    def __init__(self, kind: str, gi: int, pi: int, start: int, count: int,
                 seq: int, parts: List[_Part],
                 src: Optional[Dict[str, np.ndarray]] = None):
        self.kind = kind
        self.gi = gi
        self.pi = pi
        self.start = start
        self.count = count
        self.seq = seq
        self.parts = parts
        self.src = src                      # planned state only


class WireChunk:
    """One staged KV chunk in the fixed zero-copy wire layout."""

    def __init__(self, wire: WireFormat, tp_p: int, seq_len: int,
                 entries: List[_Entry], header: bytes, payload_bytes: int,
                 total_bytes: int, buf: Optional[memoryview] = None):
        self.wire = wire
        self.tp_p = tp_p
        self.seq_len = seq_len
        self._entries = entries
        self._header = header
        self._payload_bytes = payload_bytes
        self._total_bytes = total_bytes
        self._buf = buf                     # bound state: backing buffer
        self._local: Optional[bytearray] = None   # planned, read in-process

    # -- construction: planned (P side) -------------------------------- #
    @classmethod
    def from_entries(cls, chunk_entries: Sequence[Tuple[str, int, int,
                                                        Dict[str, Any]]],
                     wire: WireFormat, tp_p: int,
                     seq_len: int = 0) -> "WireChunk":
        """Normalized chunk entries ``(kind, gi, pi, ent)`` → planned chunk.

        kv entries carry ``k``/``v`` of (count, S, kv_heads, hd); mla
        entries carry ``ckv``/``kpe`` of (count, S, dim). The slab plan is
        computed here; no KV bytes move until ``write_into``."""
        pdt = precision.wire_payload_dtype(wire)
        int8 = wire.kind == "int8"
        entries: List[_Entry] = []
        payload_bytes = 0
        # header size is layout-independent: prelude + records
        n_parts_total = sum(2 if kind == "mla" else 1
                            for kind, _g, _p, _e in chunk_entries)
        off = _align(_PRELUDE.size + len(chunk_entries) * _ENTRY.size
                     + n_parts_total * _PART.size)

        for kind, gi, pi, ent in chunk_entries:
            parts: List[_Part] = []
            if kind == "mla":
                ckv = np.asarray(ent["ckv"])
                kpe = np.asarray(ent["kpe"])
                count, s = ckv.shape[0], ckv.shape[1]
                src = {"ckv": ckv, "kpe": kpe}
                payload_bytes += ckv.nbytes + kpe.nbytes
                for arr in (ckv, kpe):
                    shape = (count * s, 1, arr.shape[-1])
                    p = _Part(pdt, shape, off, _NO_SCALES)
                    off = _align(off + p.payload_nbytes)
                    if int8:
                        p.scales_off = off
                        off = _align(off + p.scales_count * 4)
                    parts.append(p)
                entries.append(_Entry("mla", gi, pi, ent["start"], count, s,
                                      parts, src))
                continue
            k = np.asarray(ent["k"])
            v = np.asarray(ent["v"])
            count, s, kv_heads, hd = k.shape
            assert kv_heads % tp_p == 0, (kv_heads, tp_p)
            payload_bytes += k.nbytes + v.nbytes
            shape = (2 * tp_p, count, s, kv_heads // tp_p, hd)
            p = _Part(pdt, shape, off, _NO_SCALES)
            off = _align(off + p.payload_nbytes)
            if int8:
                p.scales_off = off
                off = _align(off + p.scales_count * 4)
            entries.append(_Entry("kv", gi, pi, ent["start"], count, s,
                                  [p], {"k": k, "v": v}))

        total = off
        header = cls._pack_header(wire, tp_p, seq_len, entries,
                                  payload_bytes, total)
        return cls(wire, tp_p, seq_len, entries, header, payload_bytes,
                   total, buf=None)

    @staticmethod
    def _pack_header(wire: WireFormat, tp_p: int, seq_len: int,
                     entries: List[_Entry], payload_bytes: int,
                     total: int) -> bytes:
        pdt = precision.wire_payload_dtype(wire)
        out = [_PRELUDE.pack(MAGIC, VERSION, _WIRE_KINDS.index(wire.kind),
                             _DTYPES.index(np.dtype(wire.dtype).name
                                           if wire.kind == "raw"
                                           else np.dtype(pdt).name),
                             tp_p, len(entries), seq_len,
                             payload_bytes, total)]
        for e in entries:
            out.append(_ENTRY.pack(_ENTRY_KINDS.index(e.kind), len(e.parts),
                                   e.gi, e.pi, e.start, e.count, e.seq))
            for p in e.parts:
                shape5 = tuple(p.shape) + (1,) * (5 - len(p.shape))
                out.append(_PART.pack(_dtype_code(p.dtype), len(p.shape),
                                      *shape5, p.payload_off, p.scales_off))
        return b"".join(out)

    # -- construction: bound (D side, zero-copy) ------------------------ #
    @classmethod
    def from_buffer(cls, buf) -> "WireChunk":
        """Parse the fixed header of a wire segment; slabs stay in place
        and ``entries()`` returns views over ``buf`` (zero-copy)."""
        mv = memoryview(buf)
        (magic, version, kind_c, dtype_c, tp_p, n_entries, seq_len,
         payload_bytes, total) = _PRELUDE.unpack_from(mv, 0)
        if magic != MAGIC:
            raise ValueError("not a fixed-layout wire segment")
        if version != VERSION:
            raise ValueError(f"wire format version {version} != {VERSION}")
        wire = WireFormat(_WIRE_KINDS[kind_c], _DTYPES[dtype_c]
                          if _WIRE_KINDS[kind_c] == "raw" else "bfloat16")
        off = _PRELUDE.size
        entries: List[_Entry] = []
        for _ in range(n_entries):
            ek, n_parts, gi, pi, start, count, seq = \
                _ENTRY.unpack_from(mv, off)
            off += _ENTRY.size
            parts = []
            for _p in range(n_parts):
                rec = _PART.unpack_from(mv, off)
                off += _PART.size
                dt_c, ndim = rec[0], rec[1]
                shape = tuple(rec[2:2 + ndim])
                parts.append(_Part(_DTYPES[dt_c] if _DTYPES[dt_c] != "bfloat16"
                                   else precision.wire_payload_dtype(
                                       WireFormat("raw", "bfloat16")),
                                   shape, rec[7], rec[8]))
            entries.append(_Entry(_ENTRY_KINDS[ek], gi, pi, start, count,
                                  seq, parts))
        header = bytes(mv[:_PRELUDE.size])     # prelude copy for meta()
        return cls(wire, tp_p, seq_len, entries, header, payload_bytes,
                   total, buf=mv)

    # -- sizes / meta ---------------------------------------------------- #
    @property
    def nbytes(self) -> int:
        """Wire footprint (header + slabs) — what the segment occupies."""
        return self._total_bytes

    @property
    def payload_nbytes(self) -> int:
        """Raw canonical KV bytes this chunk represents (pre-encode)."""
        return self._payload_bytes

    @property
    def header_nbytes(self) -> int:
        return self._total_bytes - sum(
            p.payload_nbytes + (0 if p.scales_off == _NO_SCALES
                                else p.scales_count * 4)
            for e in self._entries for p in e.parts)

    def meta(self) -> Dict[str, Any]:
        return {"wire": self.wire, "tp_p": self.tp_p,
                "seq_len": self.seq_len}

    # -- P side: encode straight into the destination buffer ------------- #
    def write_into(self, buf) -> None:
        """Execute the slab plan: cast/quantize every source array directly
        into ``buf`` through typed views. One pass, no intermediate blob."""
        assert all(e.src is not None for e in self._entries), \
            "write_into on a bound chunk"
        mv = memoryview(buf)
        mv[:len(self._header)] = self._header
        wire = self.wire
        for e in self._entries:
            if e.kind == "mla":
                for p, name in zip(e.parts, ("ckv", "kpe")):
                    src = e.src[name].reshape(p.shape)
                    self._encode_part(mv, p, src, wire)
                continue
            (p,) = e.parts
            n_sh, count, s, kvs, hd = p.shape
            tp = n_sh // 2
            # (count, S, tp·kvs, hd) → shard-major (tp, count, S, kvs, hd):
            # the same contiguous head split np.split(axis=2) produces
            k = np.moveaxis(e.src["k"].reshape(count, s, tp, kvs, hd), 2, 0)
            v = np.moveaxis(e.src["v"].reshape(count, s, tp, kvs, hd), 2, 0)
            self._encode_part(mv, p, np.concatenate([k, v], axis=0)
                              if wire.kind == "int8" else (k, v), wire)

    @staticmethod
    def _encode_part(mv: memoryview, p: _Part, src, wire: WireFormat) -> None:
        dst = np.frombuffer(mv, dtype=p.dtype,
                            count=int(np.prod(p.shape)),
                            offset=p.payload_off).reshape(p.shape)
        if wire.kind == "raw":
            if isinstance(src, tuple):         # kv halves: strided cast copy
                k, v = src
                tp = p.shape[0] // 2
                np.copyto(dst[:tp], k, casting="unsafe")
                np.copyto(dst[tp:], v, casting="unsafe")
            else:
                np.copyto(dst, src, casting="unsafe")
            return
        scales = np.frombuffer(mv, dtype=np.float32, count=p.scales_count,
                               offset=p.scales_off)
        flat = src.reshape(-1, src.shape[-2], src.shape[-1]) \
            if src.ndim > 3 else src
        precision.encode_wire_into(
            flat, wire, dst.reshape(flat.shape),
            scales.reshape(flat.shape[0], flat.shape[1], 1))

    # -- in-process read path -------------------------------------------- #
    def _backing(self) -> memoryview:
        """Bound buffer, or a lazily encoded local one (in-process reads
        decode the exact same bits a cross-process reader would see)."""
        if self._buf is not None:
            return self._buf
        if self._local is None:
            self._local = bytearray(self._total_bytes)
            self.write_into(self._local)
        return memoryview(self._local)

    # -- D side: zero-copy entry views ------------------------------------ #
    def entries(self) -> List[Dict[str, Any]]:
        """Decoded entry descriptors with numpy views over the backing
        buffer (no copies). Views die with the caller's frame; call
        ``release()`` before the segment is closed."""
        mv = self._backing()
        out = []
        for e in self._entries:
            d: Dict[str, Any] = {"kind": e.kind, "gi": e.gi, "pi": e.pi,
                                 "start": e.start, "count": e.count,
                                 "seq": e.seq, "tp_p": self.tp_p}
            views = [self._view(mv, p) for p in e.parts]
            if e.kind == "mla":
                d["payloads"] = [v[0] for v in views]
                d["scales"] = [v[1] for v in views]
            else:
                d["payload"], d["scales"] = views[0]
            out.append(d)
        return out

    @staticmethod
    def _view(mv: memoryview, p: _Part
              ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        pay = np.frombuffer(mv, dtype=p.dtype, count=int(np.prod(p.shape)),
                            offset=p.payload_off).reshape(p.shape)
        if p.scales_off == _NO_SCALES:
            return pay, None
        sc = np.frombuffer(mv, dtype=np.float32, count=p.scales_count,
                           offset=p.scales_off)
        return pay, sc

    def release(self) -> None:
        """Drop buffer references so the backing segment can be closed.
        Any views handed out by ``entries()`` must already be dead."""
        if self._buf is not None:
            try:
                self._buf.release()
            except BufferError:
                pass                        # a view still pins it; GC closes
            self._buf = None
        self._local = None
