"""Pluggable KV-transport connectors (paper §III-B wire seam).

Backends register here by name; everything above the wire — the disagg
pipeline, the global scheduler, the planner — programs against
:class:`KVConnector` + :class:`TransferHandle` + ``capabilities()`` and
never against a concrete backend.

  inproc  — process memory, zero-copy, instant completion (default; the
            original ``TransferEngine`` semantics)
  shm     — real cross-process staging via multiprocessing.shared_memory,
            serialized wire entries
  rdma    — modeled per-read latency on a virtual clock; handles complete
            over multiple scheduler ticks (true async wire)
"""
from __future__ import annotations

import inspect
from typing import Any, Dict, Type

from repro.core.transport.base import (ConnectorCapabilities,  # noqa: F401
                                       KVConnector, PinnedBufferPool,
                                       TransferError, TransferHandle,
                                       TransferStats, tree_bytes)
from repro.core.transport.inprocess import InProcessConnector  # noqa: F401
from repro.core.transport.modeled_rdma import ModeledRDMAConnector  # noqa: F401
from repro.core.transport.shared_memory import SharedMemoryConnector  # noqa: F401
from repro.core.transport.wirefmt import WireChunk  # noqa: F401

CONNECTORS: Dict[str, Type[KVConnector]] = {
    InProcessConnector.transport: InProcessConnector,
    SharedMemoryConnector.transport: SharedMemoryConnector,
    ModeledRDMAConnector.transport: ModeledRDMAConnector,
}


def register_connector(cls: Type[KVConnector]) -> Type[KVConnector]:
    """Register a new backend under ``cls.transport`` (decorator-friendly)."""
    CONNECTORS[cls.transport] = cls
    return cls


def make_connector(kind: str = "inproc", **kwargs: Any) -> KVConnector:
    """Build a connector by registry name.

    Keyword arguments not accepted by the chosen backend (e.g.
    ``tick_seconds`` for ``inproc``) are silently dropped, so one shared
    config can drive any backend."""
    if kind not in CONNECTORS:
        raise KeyError(
            f"unknown KV connector {kind!r}; known: {sorted(CONNECTORS)}")
    cls = CONNECTORS[kind]
    accepted = inspect.signature(cls.__init__).parameters
    return cls(**{k: v for k, v in kwargs.items() if k in accepted})


__all__ = [
    "ConnectorCapabilities", "KVConnector", "PinnedBufferPool",
    "TransferError", "TransferHandle", "TransferStats", "tree_bytes",
    "InProcessConnector", "SharedMemoryConnector", "ModeledRDMAConnector",
    "WireChunk", "CONNECTORS", "register_connector", "make_connector",
]
