"""KV transfer engine (paper §III-B-1).

Models the paper's RDMA read flow: the P instance stages KV into a managed
pinned-CPU-buffer pool (registered once, reused — "reduce the overhead
caused by temporary allocation"), the D instance *reads* it by key, then
frees the buffer. On this container the "wire" is process memory; byte and
latency accounting flow to the scheduler and the planner's communication
operator library.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class TransferStats:
    transfers: int = 0
    bytes_moved: int = 0
    chunks: int = 0                 # streamed KV chunks (overlapped handoff)
    stage_seconds: float = 0.0      # wall time spent staging (P side)
    read_seconds: float = 0.0       # wall time spent reading (D side)
    modeled_seconds: float = 0.0    # bytes / modeled_bandwidth
    overlap_modeled_seconds: float = 0.0  # modeled wire time hidden under
    #                                       the next chunk's prefill compute
    peak_buffer_bytes: int = 0
    retries: int = 0

    @property
    def exposed_modeled_seconds(self) -> float:
        """Modeled wire time left on the critical path after overlap."""
        return self.modeled_seconds - self.overlap_modeled_seconds


class PinnedBufferPool:
    """Fixed-capacity staging pool with high-water accounting.

    Registered-once semantics: acquire/release only move a watermark — no
    per-transfer allocation, mirroring the paper's pre-registered RDMA
    buffers (zero-copy)."""

    def __init__(self, capacity_bytes: int):
        self.capacity = capacity_bytes
        self.in_use = 0
        self.high_water = 0

    def acquire(self, nbytes: int) -> None:
        if self.in_use + nbytes > self.capacity:
            raise MemoryError(
                f"pinned pool exhausted: {self.in_use + nbytes} > {self.capacity}")
        self.in_use += nbytes
        self.high_water = max(self.high_water, self.in_use)

    def release(self, nbytes: int) -> None:
        self.in_use = max(0, self.in_use - nbytes)


def _tree_bytes(tree) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(tree)
               if hasattr(x, "nbytes"))


class TransferEngine:
    """Key-value staged transfer between instances.

    control-plane: (key, metadata) registration; data-plane: read(key).
    """

    def __init__(self, bandwidth_gbps: float = 25.0,
                 buffer_capacity_bytes: int = 1 << 32):
        self.bandwidth = bandwidth_gbps * 1e9
        self.pool = PinnedBufferPool(buffer_capacity_bytes)
        self._staged: Dict[str, Tuple[Any, Dict[str, Any], int]] = {}
        self.stats = TransferStats()

    # -- P side ---------------------------------------------------------- #
    def stage(self, key: str, payload, meta: Optional[Dict[str, Any]] = None
              ) -> int:
        """Register a payload (pytree) for remote read. Returns its bytes."""
        t0 = time.perf_counter()
        payload = jax.tree.map(
            lambda x: np.asarray(x) if hasattr(x, "shape") else x, payload)
        nbytes = _tree_bytes(payload)
        self.pool.acquire(nbytes)
        self._staged[key] = (payload, meta or {}, nbytes)
        self.stats.stage_seconds += time.perf_counter() - t0
        self.stats.peak_buffer_bytes = self.pool.high_water
        return nbytes

    # -- D side ---------------------------------------------------------- #
    def read(self, key: str):
        """RDMA-read analogue: returns (payload, meta); accounts latency."""
        t0 = time.perf_counter()
        if key not in self._staged:
            raise KeyError(f"transfer key {key!r} not staged (P lost?)")
        payload, meta, nbytes = self._staged[key]
        self.stats.transfers += 1
        self.stats.bytes_moved += nbytes
        self.stats.modeled_seconds += nbytes / self.bandwidth
        self.stats.read_seconds += time.perf_counter() - t0
        return payload, meta

    def complete(self, key: str) -> None:
        """D finished materializing — free the pinned buffer."""
        entry = self._staged.pop(key, None)
        if entry is not None:
            self.pool.release(entry[2])

    def staged_keys(self) -> List[str]:
        return list(self._staged)

    def drop(self, key: str) -> None:
        """P-side failure path: drop a staged payload."""
        self.complete(key)

    def modeled_latency(self, nbytes: int) -> float:
        return nbytes / self.bandwidth
