"""Back-compat shim: the KV transfer engine now lives in
``repro.core.transport`` as a pluggable connector API (paper §III-B).

``TransferEngine`` is an alias of the default backend
(:class:`~repro.core.transport.InProcessConnector`), which preserves the
original semantics — zero-copy in-process staging, stage/read/complete
lifecycle, pinned-pool and modeled-latency accounting — behind the new
``issue_read`` → :class:`~repro.core.transport.TransferHandle` data plane.
"""
from __future__ import annotations

from repro.core.transport import (ConnectorCapabilities,  # noqa: F401
                                  InProcessConnector, KVConnector,
                                  ModeledRDMAConnector, PinnedBufferPool,
                                  SharedMemoryConnector, TransferError,
                                  TransferHandle, TransferStats,
                                  make_connector, tree_bytes)

TransferEngine = InProcessConnector

__all__ = [
    "ConnectorCapabilities", "KVConnector", "TransferEngine",
    "InProcessConnector", "SharedMemoryConnector", "ModeledRDMAConnector",
    "PinnedBufferPool", "TransferError", "TransferHandle", "TransferStats",
    "make_connector", "tree_bytes",
]
