"""The paper's primary contribution: P/D disaggregation for heterogeneous
accelerator pools — orchestrator, pluggable KV-transport connectors
(transport/), heterogeneous compatible transmission module (compat/), and
the deployment planner (planner/)."""
from repro.core.disagg import DisaggPipeline        # noqa: F401
from repro.core.kv_transfer import TransferEngine   # noqa: F401
from repro.core.transport import (KVConnector,      # noqa: F401
                                  make_connector)
