"""Core layer library — pure-JAX reference implementations.

Every layer is a pure function ``f(params, x, ...) -> y`` over plain dict
params. Hot-spot layers (prefill flash attention, paged decode attention,
KV repack) have Pallas TPU kernels in ``repro.kernels``; the functions here
are the numerically-authoritative references and the CPU execution path.

Conventions:
  * activations: (B, S, d) unless stated
  * attention heads axis layout: (B, S, H, hd)
  * KV caches carry explicit position tensors so full-attention and
    sliding-window (ring-buffer) caches share one decode path.
  * softmax / norms accumulate in fp32.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import dist

Params = Dict[str, Any]

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def layer_norm(w: jax.Array, b: jax.Array, x: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w.astype(dt) + b.astype(dt)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies, fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` (..., S, H, hd) by per-position angles.

    ``positions``: broadcastable to (..., S) — int32 absolute positions.
    Uses the llama half-split convention.
    """
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv    # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Attention core (reference). Masks are additive fp32.
# --------------------------------------------------------------------------- #
def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
         scale: Optional[float] = None) -> jax.Array:
    """q: (B,Sq,H,hd)  k,v: (B,Skv,KV,hd)  mask: (B,1|H,Sq,Skv) additive.

    GQA: H must be a multiple of KV; Q heads are grouped onto KV heads.
    Returns (B,Sq,H,hd_v).
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    grp = h // kv
    qg = q.reshape(b, sq, kv, grp, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    # mask: (B|1, 1, Sq, Skv) additive → broadcast over (kv, grp)
    scores = scores + mask[:, :, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def chunked_sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
                 q_pos: jax.Array, k_pos: jax.Array, *,
                 causal: bool = True, window: int = 0,
                 scale: Optional[float] = None,
                 chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks (flash-style).

    Numerically equivalent to ``sdpa`` with the positional mask, but the
    score buffer is (..., Sq, chunk) instead of (..., Sq, Skv) — required
    for 32k+ prefill, and the formulation XLA pipelines on TPU.

    q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd); q_pos: (B,Sq); k_pos: (B,Skv)
    int32 absolute positions, -1 = invalid (padding). Returns (B,Sq,H,hd).
    """
    b, sq, h, hd = q.shape
    kvh, skv = k.shape[2], k.shape[1]
    grp = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nc = (skv + pad) // chunk
    qg = q.reshape(b, sq, kvh, grp, hd).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, nc, chunk, kvh, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, kvh, hd), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(b, nc, chunk), 1, 0)

    m0 = jnp.full((b, kvh, grp, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, grp, sq), jnp.float32)
    # accumulator stays in the scores' (b,kv,grp,sq,hd) layout through the
    # whole scan — the PV einsum emits it natively, so no per-chunk
    # transposes of a multi-GiB buffer (one moveaxis after the loop).
    a0 = jnp.zeros((b, kvh, grp, sq, hd), jnp.float32)
    p_bf16 = dist.ctx().attn_p_bf16

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj.astype(jnp.float32)) * scale
        ok = (pj[:, None, :] >= 0)                        # (B,1,C)
        if causal:
            ok &= pj[:, None, :] <= q_pos[:, :, None]     # (B,Sq,C)
        if window > 0:
            ok &= (q_pos[:, :, None] - pj[:, None, :]) < window
        s = jnp.where(ok[:, None, None], s, NEG_INF)      # (B,KV,G,Sq,C)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        if p_bf16:
            upd = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(jnp.bfloat16),
                             vj.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32)
        else:
            upd = jnp.einsum("bkgqs,bskd->bkgqd", p,
                             vj.astype(jnp.float32))
        acc = acc * alpha[..., None] + upd
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc),
                                  unroll=True if dist.ctx().unroll else 1)
    l = jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(acc / l, 3, 1)                     # (B,Sq,KV,G,hd)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def causal_mask(sq: int, skv: int, q_offset: jax.Array | int = 0,
                window: int = 0) -> jax.Array:
    """(1,1,sq,skv) additive mask; query i at abs pos q_offset+i may see
    key j at abs pos j if j <= i (and i - j < window when window > 0)."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(skv)[None, :]
    ok = kj <= qi
    if window > 0:
        ok &= (qi - kj) < window
    return jnp.where(ok, 0.0, NEG_INF)[None, None].astype(jnp.float32)


def length_mask(lengths: jax.Array, skv: int) -> jax.Array:
    """(B,1,1,skv) additive mask blanking positions >= per-seq length."""
    ok = jnp.arange(skv)[None] < lengths[:, None]
    return jnp.where(ok, 0.0, NEG_INF)[:, None, None].astype(jnp.float32)


# --------------------------------------------------------------------------- #
# KV cache (dense, position-tagged). Shared by full attention (capacity =
# max_seq) and sliding window (capacity = window, ring buffer).
# --------------------------------------------------------------------------- #
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array          # (B, cap, KV, hd)
    v: jax.Array          # (B, cap, KV, hd)
    pos: jax.Array        # (B, cap) int32 absolute positions, -1 = empty

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def kv_cache_init(batch: int, capacity: int, kv_heads: int, hd: int,
                  dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, kv_heads, hd), dtype),
        v=jnp.zeros((batch, capacity, kv_heads, hd), dtype),
        pos=jnp.full((batch, capacity), -1, jnp.int32),
    )


def kv_cache_write(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                   positions: jax.Array) -> KVCache:
    """Write S_new entries per sequence at slots ``positions % capacity``.

    k_new/v_new: (B, S_new, KV, hd); positions: (B, S_new) absolute (-1 = skip).
    """
    cap = cache.capacity
    slots = jnp.where(positions >= 0, positions % cap, cap)   # cap = OOB
    b = k_new.shape[0]
    bidx = jnp.arange(b)[:, None]

    def scat(buf, new):
        # OOB slots (== cap) are dropped; in-place when the cache is donated
        return buf.at[bidx, slots].set(new.astype(buf.dtype), mode="drop")

    return KVCache(k=scat(cache.k, k_new), v=scat(cache.v, v_new),
                   pos=scat(cache.pos, positions.astype(jnp.int32)))


def kv_cache_from_prefill(cache: KVCache, k_new: jax.Array,
                          v_new: jax.Array, positions: jax.Array) -> KVCache:
    """Build a fresh cache from a full prefill pass.

    Prefill positions are contiguous-from-0, so when the capacity covers
    the prompt the cache is just the (padded) K/V — no scatter, which lets
    XLA alias buffers instead of copying multi-GB pools. Ring-buffer
    (windowed) caches fall back to the scatter path."""
    cap = cache.capacity
    s = k_new.shape[1]
    if cap < s:
        return kv_cache_write(cache, k_new, v_new,
                              _ring_positions(positions, cap))
    pad = cap - s
    def pd(x, fill=0):
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
        return jnp.pad(x, widths, constant_values=fill)
    return KVCache(k=pd(k_new).astype(cache.k.dtype),
                   v=pd(v_new).astype(cache.v.dtype),
                   pos=pd(positions.astype(jnp.int32), -1))


def mla_cache_from_prefill(cache: "MLACache", ckv_new: jax.Array,
                           kpe_new: jax.Array,
                           positions: jax.Array) -> "MLACache":
    cap = cache.capacity
    s = ckv_new.shape[1]
    if cap < s:
        return mla_cache_write(cache, ckv_new, kpe_new,
                               _ring_positions(positions, cap))
    pad = cap - s
    def pd(x, fill=0):
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
        return jnp.pad(x, widths, constant_values=fill)
    return MLACache(ckv=pd(ckv_new).astype(cache.ckv.dtype),
                    kpe=pd(kpe_new).astype(cache.kpe.dtype),
                    pos=pd(positions.astype(jnp.int32), -1))


def _ring_positions(positions: jax.Array, capacity: int) -> jax.Array:
    """Drop (−1) positions that have already slid out of a ring buffer."""
    last = jnp.max(positions, axis=-1, keepdims=True)
    return jnp.where(positions > last - capacity, positions, -1)


def cache_attention_mask(cache: KVCache, q_positions: jax.Array,
                         window: int = 0) -> jax.Array:
    """(B,1,Sq,cap) additive mask: valid entries with pos <= q_pos
    (and within window if sliding)."""
    cp = cache.pos[:, None, :]                   # (B,1,cap)
    qp = q_positions[:, :, None]                 # (B,Sq,1)
    ok = (cp >= 0) & (cp <= qp)
    if window > 0:
        ok &= (qp - cp) < window
    return jnp.where(ok, 0.0, NEG_INF)[:, None].astype(jnp.float32)


# --------------------------------------------------------------------------- #
# Standard attention block (GQA / MHA / MQA, optional sliding window)
# --------------------------------------------------------------------------- #
def init_attention(rng, cfg: ModelConfig) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, h, hd)) * s).astype(cfg.pdtype),
        "wk": (jax.random.normal(k2, (d, kv, hd)) * s).astype(cfg.pdtype),
        "wv": (jax.random.normal(k3, (d, kv, hd)) * s).astype(cfg.pdtype),
        "wo": (jax.random.normal(k4, (h, hd, d)) * s / math.sqrt(2 * cfg.num_layers)).astype(cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), cfg.pdtype)
        p["bk"] = jnp.zeros((kv, hd), cfg.pdtype)
        p["bv"] = jnp.zeros((kv, hd), cfg.pdtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.pdtype)
    return p


def _project_qkv(p: Params, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p: Params, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array, *, causal: bool = True,
                    lengths: Optional[jax.Array] = None,
                    window: int = 0) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Self-attention over a full sequence (train / prefill).

    Returns (out, (k, v)) — k/v for cache construction. positions: (B,S).
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    dctx = dist.ctx()
    if dctx.chunk_kv and s >= dctx.chunk_kv:
        k_pos = positions
        if lengths is not None:
            k_pos = jnp.where(jnp.arange(s)[None] < lengths[:, None],
                              positions, -1)
        out = chunked_sdpa(q, k, v, positions, k_pos, causal=causal,
                           window=window, chunk=dctx.chunk_size)
    else:
        mask = causal_mask(s, s, 0, window) if causal else \
            jnp.zeros((1, 1, s, s), jnp.float32)
        if lengths is not None:
            mask = mask + length_mask(lengths, s)
        out = sdpa(q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (k, v)


def attention_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                     positions: jax.Array, cache: KVCache,
                     window: int = 0) -> Tuple[jax.Array, KVCache]:
    """Single-token (or few-token) decode against a position-tagged cache.

    x: (B,Sq,d); positions: (B,Sq) absolute. Returns (out, new_cache).
    """
    q, k, v = _project_qkv(p, cfg, x, positions)
    cache = kv_cache_write(cache, k, v, positions)
    mask = cache_attention_mask(cache, positions, window)
    out = sdpa(q, cache.k, cache.v, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache


# --------------------------------------------------------------------------- #
# Paged decode attention (serving path). Pools/tables per repro.serving.
# --------------------------------------------------------------------------- #
def attention_decode_paged(p: Params, cfg: ModelConfig, x: jax.Array,
                           positions: jax.Array, pcache: Dict[str, jax.Array],
                           block_table: jax.Array, seq_lens: jax.Array,
                           write_blocks: jax.Array, write_slots: jax.Array,
                           spec, window: int = 0
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode against paged pools.

    x: (B,1,d); positions: (B,1) == old seq_lens; block_table: (B,maxb);
    seq_lens: (B,) lengths BEFORE this token; write_blocks/slots: (B,).
    """
    from repro.serving import paged_cache as PC
    q, k, v = _project_qkv(p, cfg, x, positions)
    k_pool = PC.append_token(spec, pcache["k_pool"], write_blocks, write_slots,
                             k[:, 0])
    v_pool = PC.append_token(spec, pcache["v_pool"], write_blocks, write_slots,
                             v[:, 0])
    new_lens = seq_lens + 1
    out = PC.paged_attention_ref(q, k_pool, v_pool, block_table, new_lens,
                                 spec, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, {"k_pool": k_pool, "v_pool": v_pool}


def mla_decode_paged(p: Params, cfg: ModelConfig, x: jax.Array,
                     positions: jax.Array, pcache: Dict[str, jax.Array],
                     block_table: jax.Array, seq_lens: jax.Array,
                     write_blocks: jax.Array, write_slots: jax.Array,
                     ckv_spec, kpe_spec
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed MLA decode against paged latent pools (kv_heads=1 pools)."""
    from repro.serving import paged_cache as PC
    m = cfg.mla
    b = x.shape[0]
    q_nope, q_pe, ckv_new, kpe_new = _mla_qkv_latent(p, cfg, x, positions)
    ckv_pool = PC.append_token(ckv_spec, pcache["ckv_pool"], write_blocks,
                               write_slots, ckv_new[:, 0, None, :])
    kpe_pool = PC.append_token(kpe_spec, pcache["kpe_pool"], write_blocks,
                               write_slots, kpe_new[:, 0, None, :])
    new_lens = seq_lens + 1
    maxb = block_table.shape[1]
    ckv = PC.pages_to_canonical(ckv_spec, ckv_pool[block_table.reshape(-1)])
    kpe = PC.pages_to_canonical(kpe_spec, kpe_pool[block_table.reshape(-1)])
    s_max = maxb * ckv_spec.block_size
    ckv = ckv.reshape(b, s_max, m.kv_lora_rank)
    kpe = kpe.reshape(b, s_max, m.qk_rope_head_dim)
    w_uk = p["w_ukv"][..., :m.qk_nope_head_dim]
    w_uv = p["w_ukv"][..., m.qk_nope_head_dim:]
    q_lat = jnp.einsum("bqhd,khd->bqhk", q_nope, w_uk.astype(x.dtype))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bqhk,bsk->bhqs", q_lat.astype(jnp.float32),
                         ckv.astype(jnp.float32)) +
              jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                         kpe.astype(jnp.float32))) * scale
    mask = jnp.where(jnp.arange(s_max)[None] < new_lens[:, None], 0.0, NEG_INF)
    probs = jax.nn.softmax(scores + mask[:, None, None, :], axis=-1)
    ctx_lat = jnp.einsum("bhqs,bsk->bqhk", probs,
                         ckv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bqhk,khd->bqhd", ctx_lat, w_uv.astype(x.dtype))
    out = jnp.einsum("bqhd,hdo->bqo", out, p["wo"].astype(x.dtype))
    return out, {"ckv_pool": ckv_pool, "kpe_pool": kpe_pool}


# --------------------------------------------------------------------------- #
# Cross-attention (enc-dec). Cache = encoder memory K/V, built once.
# --------------------------------------------------------------------------- #
def init_cross_attention(rng, cfg: ModelConfig) -> Params:
    return init_attention(rng, cfg.with_(qkv_bias=False, qk_norm=False))


def cross_attention_kv(p: Params, cfg: ModelConfig,
                       memory: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(memory.dtype))
    return k, v


def cross_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                    mem_kv: Tuple[jax.Array, jax.Array],
                    mem_lengths: Optional[jax.Array] = None) -> jax.Array:
    k, v = mem_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    mask = jnp.zeros((x.shape[0], 1, x.shape[1], k.shape[1]), jnp.float32)
    if mem_lengths is not None:
        mask = mask + length_mask(mem_lengths, k.shape[1])
    out = sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# --------------------------------------------------------------------------- #
# MLA — multi-head latent attention (DeepSeek-V2). Cache = compressed latent.
# --------------------------------------------------------------------------- #
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    ckv: jax.Array        # (B, cap, lora)
    kpe: jax.Array        # (B, cap, rope_dim)
    pos: jax.Array        # (B, cap)

    @property
    def capacity(self) -> int:
        return self.ckv.shape[1]


def mla_cache_init(batch: int, capacity: int, cfg: ModelConfig,
                   dtype) -> MLACache:
    m = cfg.mla
    return MLACache(
        ckv=jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        kpe=jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
        pos=jnp.full((batch, capacity), -1, jnp.int32),
    )


def mla_cache_write(cache: MLACache, ckv_new: jax.Array, kpe_new: jax.Array,
                    positions: jax.Array) -> MLACache:
    """Write S_new latent entries at slots ``positions % capacity``."""
    cap = cache.capacity
    slots = jnp.where(positions >= 0, positions % cap, cap)
    bidx = jnp.arange(ckv_new.shape[0])[:, None]

    def scat(buf, new):
        return buf.at[bidx, slots].set(new.astype(buf.dtype), mode="drop")

    return MLACache(ckv=scat(cache.ckv, ckv_new),
                    kpe=scat(cache.kpe, kpe_new),
                    pos=scat(cache.pos, positions.astype(jnp.int32)))


def init_mla(rng, cfg: ModelConfig) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    sl = 1.0 / math.sqrt(m.kv_lora_rank)
    return {
        "wq": (jax.random.normal(ks[0], (d, h, qk_hd)) * s).astype(cfg.pdtype),
        "w_dkv": (jax.random.normal(ks[1], (d, m.kv_lora_rank + m.qk_rope_head_dim)) * s).astype(cfg.pdtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), cfg.pdtype),
        "w_ukv": (jax.random.normal(ks[2], (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)) * sl).astype(cfg.pdtype),
        "wo": (jax.random.normal(ks[3], (h, m.v_head_dim, d)) * s / math.sqrt(2 * cfg.num_layers)).astype(cfg.pdtype),
    }


def _mla_qkv_latent(p: Params, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array):
    """Shared projections → (q_nope, q_pe, ckv, kpe)."""
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    dkv = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"].astype(x.dtype))
    ckv, kpe = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(p["kv_norm"], ckv, cfg.norm_eps)
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_pe, ckv, kpe


def mla_block(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
              lengths: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Prefill/train MLA: expand latent to per-head K/V (naive, FLOP-cheap
    at long Sq). Returns (out, (ckv, kpe)) for latent caching."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_pe, ckv, kpe = _mla_qkv_latent(p, cfg, x, positions)
    ukv = jnp.einsum("bsk,khj->bshj", ckv, p["w_ukv"].astype(x.dtype))
    k_nope, v = jnp.split(ukv, [m.qk_nope_head_dim], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    dctx = dist.ctx()
    if dctx.chunk_kv and s >= dctx.chunk_kv:
        k_pos = positions
        if lengths is not None:
            k_pos = jnp.where(jnp.arange(s)[None] < lengths[:, None],
                              positions, -1)
        out = _chunked_mla_sdpa(q_nope, q_pe, k_nope, kpe, v, positions,
                                k_pos, scale=scale, chunk=dctx.chunk_size
                                ).astype(x.dtype)
    else:
        mask = causal_mask(s, s)
        if lengths is not None:
            mask = mask + length_mask(lengths, s)
        scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope.astype(jnp.float32),
                             k_nope.astype(jnp.float32)) +
                  jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                             kpe.astype(jnp.float32))) * scale
        probs = jax.nn.softmax(scores + mask, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", probs,
                         v.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bqhd,hdo->bqo", out, p["wo"].astype(x.dtype))
    return out, (ckv, kpe)


def _chunked_mla_sdpa(q_nope: jax.Array, q_pe: jax.Array, k_nope: jax.Array,
                      kpe: jax.Array, v: jax.Array, q_pos: jax.Array,
                      k_pos: jax.Array, *, scale: float,
                      chunk: int = 1024) -> jax.Array:
    """Chunked online-softmax for MLA's two-term scores (nope + rope).

    q_nope/k_nope: (B,S,H,dn); q_pe: (B,S,H,dr); kpe: (B,S,dr);
    v: (B,S,H,dv). Causal. Returns (B,Sq,H,dv) fp32."""
    b, sq, h, dn = q_nope.shape
    skv = k_nope.shape[1]
    dv = v.shape[-1]
    pad = (-skv) % chunk
    if pad:
        k_nope = jnp.pad(k_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpe = jnp.pad(kpe, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nc = (skv + pad) // chunk
    qn = q_nope.astype(jnp.float32)
    qp = q_pe.astype(jnp.float32)
    knc = jnp.moveaxis(k_nope.reshape(b, nc, chunk, h, dn), 1, 0)
    kpc = jnp.moveaxis(kpe.reshape(b, nc, chunk, kpe.shape[-1]), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, h, dv), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(b, nc, chunk), 1, 0)

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, sq, h, dv), jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        kn, kp, vj, pj = xs
        s = (jnp.einsum("bqhd,bshd->bhqs", qn, kn.astype(jnp.float32)) +
             jnp.einsum("bqhd,bsd->bhqs", qp, kp.astype(jnp.float32))) * scale
        ok = (pj[:, None, :] >= 0) & (pj[:, None, :] <= q_pos[:, :, None])
        s = jnp.where(ok[:, None], s, NEG_INF)            # (B,H,Sq,C)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bhqs,bshd->bqhd", p, vj.astype(jnp.float32))
        acc = acc * jnp.moveaxis(alpha, 1, 2)[..., None] + upd
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (knc, kpc, vc, pc),
                                  unroll=True if dist.ctx().unroll else 1)
    l = jnp.maximum(jnp.moveaxis(l, 1, 2)[..., None], 1e-30)
    return acc / l


def mla_decode(p: Params, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array, cache: MLACache
               ) -> Tuple[jax.Array, MLACache]:
    """Absorbed-weight MLA decode: attention runs in the latent space."""
    m = cfg.mla
    q_nope, q_pe, ckv_new, kpe_new = _mla_qkv_latent(p, cfg, x, positions)
    cache = mla_cache_write(cache, ckv_new, kpe_new, positions)
    w_uk = p["w_ukv"][..., :m.qk_nope_head_dim]     # (lora, H, nope)
    w_uv = p["w_ukv"][..., m.qk_nope_head_dim:]     # (lora, H, v)
    # absorb K up-projection into q
    q_lat = jnp.einsum("bqhd,khd->bqhk", q_nope, w_uk.astype(x.dtype))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bqhk,bsk->bhqs", q_lat.astype(jnp.float32),
                         cache.ckv.astype(jnp.float32)) +
              jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                         cache.kpe.astype(jnp.float32))) * scale
    cp = cache.pos[:, None, None, :]
    qp = positions[:, None, :, None]
    mask = jnp.where((cp >= 0) & (cp <= qp), 0.0, NEG_INF)
    probs = jax.nn.softmax(scores + mask, axis=-1)
    ctx_lat = jnp.einsum("bhqs,bsk->bqhk", probs,
                         cache.ckv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bqhk,khd->bqhd", ctx_lat, w_uv.astype(x.dtype))
    out = jnp.einsum("bqhd,hdo->bqo", out, p["wo"].astype(x.dtype))
    return out, cache


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #
def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.num_layers)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * s_in).astype(cfg.pdtype),
        "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(cfg.pdtype),
        "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(cfg.pdtype),
    }


def swiglu_mlp(p: Params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))


# --------------------------------------------------------------------------- #
# MoE — top-k routed experts (+ shared), sort-based grouping + ragged_dot.
# No token dropping (capacity = T * top_k exactly, via sort).
# --------------------------------------------------------------------------- #
def init_moe(rng, cfg: ModelConfig) -> Params:
    e = cfg.moe
    d, fe = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(rng, 5)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(fe) / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": (jax.random.normal(ks[0], (d, e.num_experts)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e.num_experts, d, fe)) * s_in).astype(cfg.pdtype),
        "w_up": (jax.random.normal(ks[2], (e.num_experts, d, fe)) * s_in).astype(cfg.pdtype),
        "w_down": (jax.random.normal(ks[3], (e.num_experts, fe, d)) * s_out).astype(cfg.pdtype),
    }
    if e.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=e.num_shared_experts * fe)
    return p


def moe_route(p: Params, cfg: ModelConfig, x2d: jax.Array):
    """x2d: (T, d) → (weights (T,k), expert_idx (T,k)). Softmax-then-topk."""
    e = cfg.moe
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, e.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, idx


def moe_mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Dispatch → grouped GEMM (ragged_dot) → combine. x: (B,S,d) or (T,d).

    Distributed mode (ctx.moe_shard_map): local routing + expert-TP under
    shard_map — each shard routes its own tokens and computes every expert's
    d_ff slice, then psums over the model axis. The global sort/ragged path
    below would otherwise force an all-gather of every token at scale.
    """
    dctx = dist.ctx()
    if dctx.moe_shard_map and dctx.mesh is not None:
        return _moe_mlp_shard_map(p, cfg, x, dctx)
    return _moe_mlp_local(p, cfg, x)


def _moe_mlp_shard_map(p: Params, cfg: ModelConfig, x: jax.Array,
                       dctx) -> jax.Array:
    from jax.sharding import PartitionSpec as P

    from repro.kernels._jax_compat import shard_map

    M = dctx.model_axis
    dp = dctx.dp_axes if x.shape[0] % _axes_size(dctx.mesh, dctx.dp_axes) == 0 \
        else ()
    xspec = P(dp if dp else None, None, None)
    wspec = {"router": P(None, None),
             "w_gate": P(None, None, M), "w_up": P(None, None, M),
             "w_down": P(None, M, None)}
    if cfg.moe.num_shared_experts:
        wspec["shared"] = {"w_gate": P(None, M), "w_up": P(None, M),
                           "w_down": P(M, None)}

    def body(pl, xl):
        return _moe_mlp_capacity(pl, cfg, xl, psum_axis=M,
                                 capacity_factor=dctx.moe_capacity_factor)

    return shard_map(body, mesh=dctx.mesh, in_specs=(wspec, xspec),
                     out_specs=xspec, check_vma=False)(
        {k: p[k] for k in wspec}, x)


def moe_mlp_dist_specs(cfg: ModelConfig, model_axis: str):
    """The weight PartitionSpecs `_moe_mlp_shard_map` expects (launch layer
    must shard MoE params exactly like this)."""
    from jax.sharding import PartitionSpec as P
    spec = {"router": P(None, None),
            "w_gate": P(None, None, model_axis),
            "w_up": P(None, None, model_axis),
            "w_down": P(None, model_axis, None)}
    if cfg.moe.num_shared_experts:
        spec["shared"] = {"w_gate": P(None, model_axis),
                          "w_up": P(None, model_axis),
                          "w_down": P(model_axis, None)}
    return spec


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return max(n, 1)


def _moe_mlp_capacity(p: Params, cfg: ModelConfig, x: jax.Array,
                      psum_axis: Optional[str] = None,
                      capacity_factor: float = 1.25) -> jax.Array:
    """Capacity-bounded grouped GEMM: sort tokens by expert, scan over
    experts with a fixed-size window into the sorted stream.

    ``jax.lax.ragged_dot`` is the right primitive on TPU, but its generic
    (non-TPU) lowering materializes O(E·T·d) masks — 192 GiB/chip for
    DeepSeek-V2 prefill. The capacity window bounds both memory (cap·d per
    expert) and FLOPs (capacity_factor × useful); tokens landing beyond an
    expert's capacity are dropped, the standard trade of dropping MoE
    implementations. Used on the distributed path; the exact sort/ragged
    path below remains the small-model/TPU route.
    """
    e = cfg.moe
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    t = x2d.shape[0]
    d = shape[-1]
    weights, idx = moe_route(p, cfg, x2d)

    flat_expert = idx.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_expert)
    token_of = order // e.top_k
    flat_w = weights.reshape(-1)[order]                          # (T*k,)
    group_sizes = jnp.bincount(flat_expert,
                               length=e.num_experts).astype(jnp.int32)
    offsets = jnp.cumsum(group_sizes) - group_sizes
    tk = t * e.top_k
    cap = min(tk, max(8, int(math.ceil(tk / e.num_experts
                                       * capacity_factor / 8) * 8)))

    # (E, cap) window into the sorted token stream, clamped at the end;
    # positions outside an expert's true range are masked to weight 0.
    starts = jnp.minimum(offsets, tk - cap)
    pos = starts[:, None] + jnp.arange(cap)[None]                # (E, cap)
    valid = (pos >= offsets[:, None]) \
        & (pos < (offsets + group_sizes)[:, None])
    tok = token_of[pos.reshape(-1)]                              # (E*cap,)
    xw = x2d[tok].reshape(e.num_experts, cap, d)                 # (E, cap, d)
    gate_w = jnp.where(valid, flat_w[pos.reshape(-1)].reshape(pos.shape),
                       0.0)

    g = jnp.einsum("ecd,edf->ecf", xw, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xw, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    ye = ye * gate_w[..., None].astype(x.dtype)

    y = jnp.zeros((t, d), x.dtype)
    y = y.at[tok].add(ye.reshape(-1, d))
    if e.num_shared_experts:
        y = y + swiglu_mlp(p["shared"], x2d)
    if psum_axis is not None:
        y = jax.lax.psum(y, psum_axis)
    return y.reshape(shape)


def _moe_mlp_local(p: Params, cfg: ModelConfig, x: jax.Array,
                   psum_axis: Optional[str] = None) -> jax.Array:
    e = cfg.moe
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    t = x2d.shape[0]
    weights, idx = moe_route(p, cfg, x2d)

    flat_expert = idx.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_expert)                             # stable
    token_of = order // e.top_k
    xs = x2d[token_of]                                           # (T*k, d)
    group_sizes = jnp.bincount(flat_expert, length=e.num_experts).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, p["w_gate"].astype(x.dtype), group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"].astype(x.dtype), group_sizes)
    h = jax.nn.silu(g) * u
    y_sorted = jax.lax.ragged_dot(h, p["w_down"].astype(x.dtype), group_sizes)

    w_sorted = weights.reshape(-1)[order][:, None].astype(y_sorted.dtype)
    y = jnp.zeros((t, shape[-1]), y_sorted.dtype)
    y = y.at[token_of].add(y_sorted * w_sorted)
    if e.num_shared_experts:
        y = y + swiglu_mlp(p["shared"], x2d)
    if psum_axis is not None:
        y = jax.lax.psum(y, psum_axis)     # combine d_ff-sliced partials
    return y.reshape(shape)


def moe_load_balance_loss(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    e = cfg.moe
    x2d = x.reshape(-1, x.shape[-1])
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, e.top_k)
    counts = jnp.sum(jax.nn.one_hot(idx, e.num_experts, dtype=jnp.float32),
                     axis=(0, 1))
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    imp = jnp.mean(probs, axis=0)
    return e.num_experts * jnp.sum(frac * imp)


# --------------------------------------------------------------------------- #
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# --------------------------------------------------------------------------- #
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RGLRUState:
    h: jax.Array          # (B, w) recurrent hidden
    conv: jax.Array       # (B, d_conv-1, w) conv tail


def rglru_state_init(batch: int, cfg: ModelConfig, dtype) -> RGLRUState:
    r = cfg.recurrent
    w = r.lru_width or cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, w), jnp.float32),
                      conv=jnp.zeros((batch, r.d_conv - 1, w), dtype))


def init_rglru(rng, cfg: ModelConfig) -> Params:
    r = cfg.recurrent
    d = cfg.d_model
    w = r.lru_width or d
    ks = jax.random.split(rng, 7)
    s = 1.0 / math.sqrt(d)
    # Λ init so that a = sigmoid(Λ)^(8r) sits in (0.9, 0.999)
    lam = jnp.log(jnp.expm1(
        -jnp.log(jax.random.uniform(ks[4], (w,), jnp.float32,
                                    0.9 ** (1 / 8), 0.999 ** (1 / 8)))))
    return {
        "w_x": (jax.random.normal(ks[0], (d, w)) * s).astype(cfg.pdtype),
        "w_gate": (jax.random.normal(ks[1], (d, w)) * s).astype(cfg.pdtype),
        "conv_w": (jax.random.normal(ks[2], (r.d_conv, w)) / math.sqrt(r.d_conv)).astype(cfg.pdtype),
        "conv_b": jnp.zeros((w,), cfg.pdtype),
        "lru_in_w": (jax.random.normal(ks[3], (w, w)) / math.sqrt(w) * 0.1).astype(cfg.pdtype),
        "lru_a_w": (jax.random.normal(ks[5], (w, w)) / math.sqrt(w) * 0.1).astype(cfg.pdtype),
        "lru_in_b": jnp.zeros((w,), jnp.float32),
        "lru_a_b": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "w_out": (jax.random.normal(ks[6], (w, d)) / math.sqrt(w) / math.sqrt(2 * cfg.num_layers)).astype(cfg.pdtype),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                   tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. x: (B,S,w); w: (K,w); tail: (B,K-1,w) history."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(k))
    return out + b.astype(x.dtype)


def _rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t, h_0 given. a,b: (B,S,w) fp32. Returns h_{1..S}."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl
    a_, b_ = jax.lax.associative_scan(combine, (a, b), axis=1)
    return a_ * h0[:, None, :] + b_


def rglru_block(p: Params, cfg: ModelConfig, x: jax.Array,
                state: RGLRUState) -> Tuple[jax.Array, RGLRUState]:
    """Full-sequence recurrent block. x: (B,S,d). Returns (out, final state)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(x.dtype))
    new_tail = jnp.concatenate([state.conv, xb], axis=1)[:, -(p["conv_w"].shape[0] - 1):]
    xb = _causal_conv1d(xb, p["conv_w"], p["conv_b"], state.conv)
    # RG-LRU gates (fp32 recurrence)
    xf = xb.astype(jnp.float32)
    r_g = jax.nn.sigmoid(xf @ p["lru_a_w"].astype(jnp.float32) + p["lru_a_b"])
    i_g = jax.nn.sigmoid(xf @ p["lru_in_w"].astype(jnp.float32) + p["lru_in_b"])
    log_a = -8.0 * r_g * jax.nn.softplus(p["lam"])          # (B,S,w)
    a = jnp.exp(log_a)
    gated_x = xf * i_g
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    h = _rglru_scan(a, b, state.h)
    out = (h.astype(x.dtype) * jax.nn.gelu(gate))
    out = jnp.einsum("bsw,wd->bsd", out, p["w_out"].astype(x.dtype))
    return out, RGLRUState(h=h[:, -1], conv=new_tail)


def rglru_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                 state: RGLRUState) -> Tuple[jax.Array, RGLRUState]:
    """Single-step decode; x: (B,1,d)."""
    return rglru_block(p, cfg, x, state)


# --------------------------------------------------------------------------- #
# Mamba-2 SSD (state-space duality, chunked)
# --------------------------------------------------------------------------- #
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMState:
    h: jax.Array          # (B, H, P, N) fp32 SSD state
    conv: jax.Array       # (B, d_conv-1, conv_dim) conv tail


def ssm_state_init(batch: int, cfg: ModelConfig, dtype) -> SSMState:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return SSMState(h=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
                    conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype))


def init_ssd(rng, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = di + 2 * s.n_groups * s.d_state
    ks = jax.random.split(rng, 5)
    sc = 1.0 / math.sqrt(d)
    return {
        "w_in": (jax.random.normal(ks[0], (d, 2 * di + 2 * s.n_groups * s.d_state + nh)) * sc).astype(cfg.pdtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim)) / math.sqrt(s.d_conv)).astype(cfg.pdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "a_log": jnp.log(jax.random.uniform(ks[2], (nh,), jnp.float32, 1.0, 16.0)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jax.random.uniform(ks[3], (nh,), jnp.float32, 1e-3, 0.1))),
        "out_norm": jnp.ones((di,), cfg.pdtype),
        "w_out": (jax.random.normal(ks[4], (di, d)) / math.sqrt(di) / math.sqrt(2 * cfg.num_layers)).astype(cfg.pdtype),
    }


def _ssd_split(p: Params, cfg: ModelConfig, x: jax.Array):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * gn], axis=-1)
    return z, xbc, dt, di, nh, gn


def _segsum_exp(da_cs: jax.Array) -> jax.Array:
    """L[i,j] = exp(cum_i - cum_j) for i>=j else 0. da_cs: (..., Q)."""
    diff = da_cs[..., :, None] - da_cs[..., None, :]
    mask = jnp.tril(jnp.ones(diff.shape[-2:], bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_block(p: Params, cfg: ModelConfig, x: jax.Array,
              state: SSMState) -> Tuple[jax.Array, SSMState]:
    """Chunked SSD over a full sequence. x: (B,S,d); S % chunk == 0 or padded."""
    s = cfg.ssm
    b, S, _ = x.shape
    z, xbc, dt, di, nh, gn = _ssd_split(p, cfg, x)
    new_tail = jnp.concatenate([state.conv, xbc], axis=1)[:, -(s.d_conv - 1):]
    xbc = jax.nn.silu(_causal_conv1d(xbc, p["conv_w"], p["conv_b"], state.conv))
    xs, B_, C_ = jnp.split(xbc, [di, di + gn], axis=-1)
    xh = xs.reshape(b, S, nh, s.head_dim).astype(jnp.float32)       # (B,S,H,P)
    Bh = B_.reshape(b, S, s.n_groups, s.d_state).astype(jnp.float32)
    Ch = C_.reshape(b, S, s.n_groups, s.d_state).astype(jnp.float32)
    # broadcast groups → heads
    rep = nh // s.n_groups
    Bh = jnp.repeat(Bh, rep, axis=2)                                 # (B,S,H,N)
    Ch = jnp.repeat(Ch, rep, axis=2)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    a = -jnp.exp(p["a_log"])                                         # (H,)
    da = dtf * a                                                     # (B,S,H)

    Q = min(s.chunk_size, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // Q

    def ch(t):  # (B, S', ...) -> (B, nc, Q, ...)
        return t.reshape((b, nc, Q) + t.shape[2:])
    xc, Bc, Cc, dac, dtc = map(ch, (xh, Bh, Ch, da, dtf))
    da_cs = jnp.cumsum(dac, axis=2)                                  # (B,nc,Q,H)
    # --- intra-chunk (quadratic within chunk)
    L = _segsum_exp(jnp.moveaxis(da_cs, -1, 2))                      # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc) * L * \
        jnp.moveaxis(dtc, -1, 2)[:, :, :, None, :]                   # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)
    # --- chunk-local end states
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)              # (B,nc,Q,H)
    states_loc = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                            decay_to_end * dtc, Bc, xc)              # (B,nc,H,P,N)
    # --- inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))                      # (B,nc,H)

    def step(h, inp):
        dec, st = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h
    h_final, h_prev = jax.lax.scan(
        step, state.h, (jnp.moveaxis(chunk_decay, 1, 0),
                        jnp.moveaxis(states_loc, 1, 0)),
        unroll=True if dist.ctx().unroll else 1)
    h_prev = jnp.moveaxis(h_prev, 0, 1)                              # (B,nc,H,P,N) state entering chunk
    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(da_cs), Cc, h_prev)
    y = (y_intra + y_inter).reshape(b, S + pad, nh, s.head_dim)[:, :S]
    y = y + xh[:, :S] * p["d_skip"][None, None, :, None]
    y = y.reshape(b, S, di).astype(x.dtype)
    y = rms_norm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p["w_out"].astype(x.dtype))
    return out, SSMState(h=h_final, conv=new_tail)


def ssd_decode(p: Params, cfg: ModelConfig, x: jax.Array,
               state: SSMState) -> Tuple[jax.Array, SSMState]:
    """Single-step SSD recurrence. x: (B,1,d)."""
    s = cfg.ssm
    b = x.shape[0]
    z, xbc, dt, di, nh, gn = _ssd_split(p, cfg, x)
    new_tail = jnp.concatenate([state.conv, xbc], axis=1)[:, -(s.d_conv - 1):]
    xbc = jax.nn.silu(_causal_conv1d(xbc, p["conv_w"], p["conv_b"], state.conv))
    xs, B_, C_ = jnp.split(xbc[:, 0], [di, di + gn], axis=-1)
    xh = xs.reshape(b, nh, s.head_dim).astype(jnp.float32)
    rep = nh // s.n_groups
    Bh = jnp.repeat(B_.reshape(b, s.n_groups, s.d_state), rep, 1).astype(jnp.float32)
    Ch = jnp.repeat(C_.reshape(b, s.n_groups, s.d_state), rep, 1).astype(jnp.float32)
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dtf * a)                                            # (B,H)
    h = state.h * decay[:, :, None, None] + \
        jnp.einsum("bh,bhn,bhp->bhpn", dtf, Bh, xh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h) + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p["w_out"].astype(x.dtype))
    return out, SSMState(h=h, conv=new_tail)
