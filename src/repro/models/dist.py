"""Distribution context — launch-layer knobs consulted by the model code.

The tiny-model CPU path (serving engines, unit tests) runs with the default
context (everything off). The launch layer installs a context to switch on:

  * chunk_kv      — chunked (online-softmax) attention above this seq len;
                    bounds the score buffer for 32k/500k prefill.
  * vocab_parallel— one-hot matmul embedding + vocab-parallel loss
                    (gather/take_along_axis lower to all-gathers of the
                    sharded table/logits; the one-hot einsum stays sharded).
  * moe_shard_map — local-routing expert-TP MoE under shard_map (the global
                    sort/ragged_dot path would all-gather every token).
  * unroll        — unroll the layer scan (roofline probe compiles only;
                    XLA cost analysis counts a while body exactly once, so
                    FLOPs of scanned programs are undercounted by the trip
                    count).
  * act_spec/seq_spec — with_sharding_constraint anchors for the residual
                    stream (None = let GSPMD propagate).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Any = None
    dp_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    chunk_kv: int = 0            # 0 = never chunk
    chunk_size: int = 1024
    vocab_parallel: bool = False
    moe_shard_map: bool = False
    moe_capacity_factor: float = 1.25
    attn_p_bf16: bool = False    # bf16 probabilities into the PV matmul
    unroll: bool = False
    act_spec: Any = None         # PartitionSpec for (B, S, d) residuals


_DEFAULT = DistContext()
_CURRENT = _DEFAULT


def ctx() -> DistContext:
    return _CURRENT


@contextlib.contextmanager
def use(context: DistContext):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = context
    try:
        yield context
    finally:
        _CURRENT = prev
