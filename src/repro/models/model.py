"""Config-driven model assembly.

A model is a list of *block groups*; each group is a repeating unit of layer
kinds scanned ``count`` times (``jax.lax.scan`` over stacked params) so that
64-layer 32B configs lower to compact HLO.

  dense:   [Group(("attn",), L)]
  moe:     [Group(("attn",), first_dense, moe=False), Group(("attn",), rest, moe=True)]
  hybrid:  [Group((rec,rec,attn), L//3), Group((rec,rec), 1)]   # RecurrentGemma
  ssm:     [Group(("ssd",), L)]
  enc-dec: encoder groups (non-causal) + decoder groups (cross=True)

Three entry points:
  train_forward(params, cfg, batch)                    -> logits (B,S,V)
  prefill(params, cfg, inputs, caches)                 -> (last_logits, caches)
  decode_step(params, cfg, tokens, positions, caches)  -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, RECURRENT, SSD, ModelConfig
from repro.models import dist
from repro.models import layers as L

Params = Dict[str, Any]


def _constrain(x: jax.Array) -> jax.Array:
    """Anchor the residual stream to the launch layer's activation spec."""
    dctx = dist.ctx()
    if dctx.act_spec is None or dctx.mesh is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(dctx.mesh, dctx.act_spec))


@dataclasses.dataclass(frozen=True)
class Group:
    kinds: Tuple[str, ...]
    count: int
    moe: bool = False
    cross: bool = False     # decoder layers of an enc-dec model
    causal: bool = True


def block_groups(cfg: ModelConfig) -> List[Group]:
    if cfg.family == "ssm":
        return [Group((SSD,), cfg.num_layers)]
    if cfg.recurrent is not None:
        pat = cfg.recurrent.block_pattern
        full, rem = divmod(cfg.num_layers, len(pat))
        gs = [Group(pat, full)]
        if rem:
            gs.append(Group(pat[:rem], 1))
        return gs
    if cfg.is_moe and cfg.moe.first_dense_layers:
        fd = cfg.moe.first_dense_layers
        return [Group((ATTN,), fd, moe=False),
                Group((ATTN,), cfg.num_layers - fd, moe=True)]
    cross = cfg.is_enc_dec
    return [Group((ATTN,), cfg.num_layers, moe=cfg.is_moe, cross=cross)]


def encoder_groups(cfg: ModelConfig) -> List[Group]:
    return [Group((ATTN,), cfg.encoder_layers, causal=False)]


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #
def _init_layer(rng, cfg: ModelConfig, kind: str, moe: bool, cross: bool) -> Params:
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    if kind == SSD:
        return {"norm": jnp.ones((d,), cfg.pdtype),
                "ssd": L.init_ssd(ks[0], cfg)}
    if kind == RECURRENT:
        return {"norm1": jnp.ones((d,), cfg.pdtype),
                "rglru": L.init_rglru(ks[0], cfg),
                "norm2": jnp.ones((d,), cfg.pdtype),
                "mlp": L.init_mlp(ks[1], cfg)}
    p = {"norm1": jnp.ones((d,), cfg.pdtype),
         "norm2": jnp.ones((d,), cfg.pdtype)}
    if cfg.attention_kind == "mla":
        p["attn"] = L.init_mla(ks[0], cfg)
    else:
        p["attn"] = L.init_attention(ks[0], cfg)
    p["mlp"] = L.init_moe(ks[1], cfg) if moe else L.init_mlp(ks[1], cfg)
    if cross:
        p["norm_x"] = jnp.ones((d,), cfg.pdtype)
        p["cross"] = L.init_cross_attention(ks[2], cfg)
    return p


def _init_group(rng, cfg: ModelConfig, g: Group) -> Tuple[Params, ...]:
    """Returns tuple (per position in kinds) of stacked (count, ...) params."""
    out = []
    for i, kind in enumerate(g.kinds):
        keys = jax.random.split(jax.random.fold_in(rng, i), g.count)
        out.append(jax.vmap(
            lambda k: _init_layer(k, cfg, kind, g.moe, g.cross))(keys))
    return tuple(out)


def init_params(rng: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 8)
    d, v = cfg.d_model, cfg.vocab_size
    p: Params = {
        "embed": (jax.random.normal(ks[0], (v, d)) * 0.02).astype(cfg.pdtype),
        "final_norm": jnp.ones((d,), cfg.pdtype),
        "groups": tuple(_init_group(jax.random.fold_in(ks[1], i), cfg, g)
                        for i, g in enumerate(block_groups(cfg))),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[2], (d, v)) / math.sqrt(d)).astype(cfg.pdtype)
    if cfg.is_enc_dec:
        p["enc_groups"] = tuple(
            _init_group(jax.random.fold_in(ks[3], i), cfg, g)
            for i, g in enumerate(encoder_groups(cfg)))
        p["enc_norm"] = jnp.ones((d,), cfg.pdtype)
    return p


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# --------------------------------------------------------------------------- #
# Caches
# --------------------------------------------------------------------------- #
def layer_cache_init(cfg: ModelConfig, kind: str, cross: bool, batch: int,
                     capacity: int, dtype, mem_len: int = 0,
                     full_capacity: bool = False):
    if kind == SSD:
        return L.ssm_state_init(batch, cfg, dtype)
    if kind == RECURRENT:
        return L.rglru_state_init(batch, cfg, dtype)
    cap = capacity
    if (cfg.attention_kind == "sliding" and cfg.sliding_window
            and not full_capacity):
        # ring buffer sized to the window. Chunked prefill must opt OUT
        # (full_capacity): writing chunk c would evict positions still
        # inside the window of chunk c's own queries; window masking is
        # applied by attention instead, so slot == position.
        cap = min(cap, cfg.sliding_window)
    if cfg.attention_kind == "mla":
        c = L.mla_cache_init(batch, cap, cfg, dtype)
    else:
        c = L.kv_cache_init(batch, cap, cfg.num_kv_heads, cfg.hd, dtype)
    if cross:
        return {"self": c,
                "cross_k": jnp.zeros((batch, mem_len, cfg.num_kv_heads, cfg.hd), dtype),
                "cross_v": jnp.zeros((batch, mem_len, cfg.num_kv_heads, cfg.hd), dtype),
                "mem_len": jnp.zeros((batch,), jnp.int32)}
    return c


def init_caches(cfg: ModelConfig, batch: int, capacity: int,
                dtype=None, mem_len: int = 0, full_capacity: bool = False):
    """Nested cache pytree matching ``params['groups']`` structure, with every
    leaf stacked (count, ...) per group position."""
    dtype = dtype or cfg.cdtype
    out = []
    for g in block_groups(cfg):
        per_pos = []
        for kind in g.kinds:
            one = layer_cache_init(cfg, kind, g.cross, batch, capacity,
                                   dtype, mem_len, full_capacity)
            per_pos.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (g.count,) + x.shape), one))
        out.append(tuple(per_pos))
    return tuple(out)


def abstract_caches(cfg: ModelConfig, batch: int, capacity: int,
                    dtype=None, mem_len: int = 0):
    return jax.eval_shape(
        lambda: init_caches(cfg, batch, capacity, dtype, mem_len))


# --------------------------------------------------------------------------- #
# Layer application
# --------------------------------------------------------------------------- #
def _window(cfg: ModelConfig) -> int:
    return cfg.sliding_window if cfg.attention_kind == "sliding" else 0


def _mlp_apply(p: Params, cfg: ModelConfig, moe: bool, x: jax.Array) -> jax.Array:
    return L.moe_mlp(p, cfg, x) if moe else L.swiglu_mlp(p, x)


def _apply_layer_full(p, cfg: ModelConfig, g: Group, kind: str, x,
                      positions, lengths, cache, memory=None, mem_lengths=None):
    """Full-sequence pass (train/prefill). Returns (x, new_cache).

    ``cache`` may be None (train mode) — then no cache is built.
    """
    build = cache is not None
    if kind == SSD:
        h, st = L.ssd_block(p["ssd"], cfg, L.rms_norm(p["norm"], x, cfg.norm_eps),
                            cache if build else L.ssm_state_init(x.shape[0], cfg, x.dtype))
        return x + h, (st if build else None)
    if kind == RECURRENT:
        h, st = L.rglru_block(p["rglru"], cfg,
                              L.rms_norm(p["norm1"], x, cfg.norm_eps),
                              cache if build else L.rglru_state_init(x.shape[0], cfg, x.dtype))
        x = x + h
        x = x + L.swiglu_mlp(p["mlp"], L.rms_norm(p["norm2"], x, cfg.norm_eps))
        return x, (st if build else None)
    # attention layer
    win = _window(cfg) if kind == ATTN and g.causal else 0
    h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
    self_cache = cache["self"] if (build and g.cross) else cache
    new_cache: Any = None
    if cfg.attention_kind == "mla":
        out, (ckv, kpe) = L.mla_block(p["attn"], cfg, h, positions, lengths)
        if build:
            new_cache = L.mla_cache_from_prefill(self_cache, ckv, kpe,
                                                 positions)
    else:
        out, (k, v) = L.attention_block(p["attn"], cfg, h, positions,
                                        causal=g.causal, lengths=lengths,
                                        window=win)
        if build:
            new_cache = L.kv_cache_from_prefill(self_cache, k, v, positions)
    x = x + out
    if g.cross:
        hx = L.rms_norm(p["norm_x"], x, cfg.norm_eps)
        mk, mv = L.cross_attention_kv(p["cross"], cfg, memory)
        x = x + L.cross_attention(p["cross"], cfg, hx, (mk, mv), mem_lengths)
        if build:
            new_cache = {"self": new_cache, "cross_k": mk, "cross_v": mv,
                         "mem_len": mem_lengths if mem_lengths is not None
                         else jnp.full((x.shape[0],), mk.shape[1], jnp.int32)}
    h2 = L.rms_norm(p["norm2"], x, cfg.norm_eps)
    x = x + _mlp_apply(p["mlp"], cfg, g.moe, h2)
    return x, new_cache


def _apply_layer_decode(p, cfg: ModelConfig, g: Group, kind: str, x,
                        positions, cache):
    if kind == SSD:
        # ssd_decode is the single-token recurrence; multi-token chunks
        # (chunked prefill) go through the chunk-scan with the incoming
        # state as scan carry. Static shape branch — resolved at trace.
        ssd = L.ssd_block if x.shape[1] > 1 else L.ssd_decode
        h, st = ssd(p["ssd"], cfg,
                    L.rms_norm(p["norm"], x, cfg.norm_eps), cache)
        return x + h, st
    if kind == RECURRENT:
        h, st = L.rglru_decode(p["rglru"], cfg,
                               L.rms_norm(p["norm1"], x, cfg.norm_eps), cache)
        x = x + h
        x = x + L.swiglu_mlp(p["mlp"], L.rms_norm(p["norm2"], x, cfg.norm_eps))
        return x, st
    win = _window(cfg)
    h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
    self_cache = cache["self"] if g.cross else cache
    if cfg.attention_kind == "mla":
        out, new_self = L.mla_decode(p["attn"], cfg, h, positions, self_cache)
    else:
        out, new_self = L.attention_decode(p["attn"], cfg, h, positions,
                                           self_cache, window=win)
    x = x + out
    new_cache: Any = new_self
    if g.cross:
        hx = L.rms_norm(p["norm_x"], x, cfg.norm_eps)
        x = x + L.cross_attention(p["cross"], cfg, hx,
                                  (cache["cross_k"], cache["cross_v"]),
                                  cache["mem_len"])
        new_cache = {"self": new_self, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"], "mem_len": cache["mem_len"]}
    h2 = L.rms_norm(p["norm2"], x, cfg.norm_eps)
    x = x + _mlp_apply(p["mlp"], cfg, g.moe, h2)
    return x, new_cache


# --------------------------------------------------------------------------- #
# Group scan
# --------------------------------------------------------------------------- #
def _scan_group(gp, cfg: ModelConfig, g: Group, x, apply_pos, caches_g,
                remat: bool):
    """Scan a group over its ``count`` repetitions.

    gp: tuple(len(kinds)) of stacked params; caches_g same structure or None.
    apply_pos(p_i, kind_i, x, cache_i) -> (x, new_cache_i)

    Caches ride in the scan CARRY (sliced / written back per layer with
    dynamic-(index|update)-slice) rather than as scan xs/ys: the carry is
    aliased in place by XLA buffer assignment, so a donated multi-GB KV
    cache is updated without a second stacked copy.
    """
    unroll = True if dist.ctx().unroll else 1

    if caches_g is None:
        def body(carry, ps):
            xx = _constrain(carry)
            for i, kind in enumerate(g.kinds):
                xx, _ = apply_pos(ps[i], kind, xx, None)
            return xx, None

        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, gp, unroll=unroll)
        return x, None

    def body(carry, ps):
        xx, caches, li = carry
        xx = _constrain(xx)
        new_caches = []
        for i, kind in enumerate(g.kinds):
            c_i = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(
                    buf, li, 0, keepdims=False), caches[i])
            xx, nc = apply_pos(ps[i], kind, xx, c_i)
            new_caches.append(jax.tree.map(
                lambda buf, n: jax.lax.dynamic_update_index_in_dim(
                    buf, n.astype(buf.dtype), li, 0), caches[i], nc))
        return (xx, tuple(new_caches), li + 1), None

    if remat:
        body = jax.checkpoint(body)
    (x, new_caches, _), _ = jax.lax.scan(
        body, (x, caches_g, jnp.zeros((), jnp.int32)), gp, unroll=unroll)
    return x, new_caches


def _run_groups(params, cfg: ModelConfig, groups: List[Group], gparams, x,
                mode: str, positions, lengths, caches, memory=None,
                mem_lengths=None, remat: bool = False):
    new_caches = []
    for gi, g in enumerate(groups):
        cg = None if caches is None else caches[gi]
        if mode == "decode":
            def apply_pos(p_i, kind, xx, c_i, _g=g):
                return _apply_layer_decode(p_i, cfg, _g, kind, xx, positions, c_i)
        else:
            def apply_pos(p_i, kind, xx, c_i, _g=g):
                return _apply_layer_full(p_i, cfg, _g, kind, xx, positions,
                                         lengths, c_i, memory, mem_lengths)
        x, nc = _scan_group(gparams[gi], cfg, g, x, apply_pos, cg, remat)
        new_caches.append(nc)
    return x, tuple(new_caches)


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #
def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    e = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    return e * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype) \
        if cfg.tie_embeddings else e


def lm_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    return jnp.einsum("...d,dv->...v", x, head.astype(x.dtype))


def _merge_frontend(params, cfg: ModelConfig, inputs: Dict[str, jax.Array]):
    """Returns (x (B,S,d), positions (B,S), lengths or None)."""
    tokens = inputs["tokens"]
    b = tokens.shape[0]
    emb = embed_tokens(params, cfg, tokens)
    lengths = inputs.get("lengths")
    if cfg.frontend.kind == "vision" and "patches" in inputs:
        patches = inputs["patches"].astype(cfg.cdtype)
        emb = jnp.concatenate([patches, emb], axis=1)
        if lengths is not None:
            lengths = lengths + patches.shape[1]
    s = emb.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if lengths is not None:
        positions = jnp.where(positions < lengths[:, None], positions, -1)
    return emb, positions, lengths


def encode(params, cfg: ModelConfig, frames: jax.Array,
           frame_lengths: Optional[jax.Array] = None) -> jax.Array:
    """Encoder forward (audio frontend STUB: frames are embeddings)."""
    x = frames.astype(cfg.cdtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = _run_groups(params, cfg, encoder_groups(cfg), params["enc_groups"],
                       x, "full", positions, frame_lengths, None)
    return L.rms_norm(params["enc_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------------- #
# Entry points
# --------------------------------------------------------------------------- #
def train_forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  remat: bool = True) -> jax.Array:
    """Teacher-forced logits (B,S,V)."""
    memory = mem_lengths = None
    if cfg.is_enc_dec:
        memory = encode(params, cfg, batch["frames"], batch.get("frame_lengths"))
        mem_lengths = batch.get("frame_lengths")
    x, positions, lengths = _merge_frontend(params, cfg, batch)
    x, _ = _run_groups(params, cfg, block_groups(cfg), params["groups"], x,
                       "full", positions, lengths, None, memory, mem_lengths,
                       remat=remat)
    return lm_logits(params, cfg, x)


def prefill(params, cfg: ModelConfig, inputs: Dict[str, jax.Array], caches,
            remat: bool = False):
    """Build caches from a prompt. Returns (last_token_logits (B,V), caches).

    inputs: tokens (B,S), optional lengths (B,), frames (enc-dec),
    patches (vlm).
    """
    memory = mem_lengths = None
    if cfg.is_enc_dec:
        memory = encode(params, cfg, inputs["frames"], inputs.get("frame_lengths"))
        mem_lengths = inputs.get("frame_lengths")
    x, positions, lengths = _merge_frontend(params, cfg, inputs)
    x, caches = _run_groups(params, cfg, block_groups(cfg), params["groups"],
                            x, "full", positions, lengths, caches, memory,
                            mem_lengths, remat=remat)
    x = lm_logits(params, cfg, x)                        # (B,S,V)
    if lengths is None:
        last = x[:, -1]
    else:
        idx = jnp.maximum(lengths - 1, 0)
        last = jnp.take_along_axis(x, idx[:, None, None].astype(jnp.int32),
                                   axis=1)[:, 0]
        if cfg.frontend.kind == "vision" and "patches" in inputs:
            pass  # lengths already include patches via _merge_frontend
    return last, caches


def decode_step(params, cfg: ModelConfig, tokens: jax.Array,
                positions: jax.Array, caches):
    """One decode step. tokens: (B,T) new token ids; positions: (B,T) absolute
    (text-space positions are offset by num_patches for VLM prompts upstream).
    Returns (logits (B,T,V), caches)."""
    return decode_step_embeds(params, cfg, embed_tokens(params, cfg, tokens),
                              positions, caches)


def decode_step_embeds(params, cfg: ModelConfig, embeds: jax.Array,
                       positions: jax.Array, caches):
    """Decode path over precomputed embeddings (B,T,d) — the chunked-prefill
    route for multimodal prompts, where patch embeddings and token
    embeddings interleave in one merged sequence."""
    x = embeds.astype(cfg.cdtype)
    x, caches = _run_groups(params, cfg, block_groups(cfg), params["groups"],
                            x, "decode", positions, None, caches)
    return lm_logits(params, cfg, x), caches


def encoder_cross_kv(params, cfg: ModelConfig, memory: jax.Array):
    """Per-decoder-layer cross-attention K/V from encoder ``memory``
    (B,S_mem,d) — the non-resumable preamble of a chunked enc-dec prefill.
    Returns {(gi, pi): (mk, mv)} with mk/mv stacked (count, B, S_mem, KV, hd)
    to match the cache leaf layout (tuple keys stay static under jit)."""
    out = {}
    for gi, g in enumerate(block_groups(cfg)):
        if not g.cross:
            continue
        for pi, _kind in enumerate(g.kinds):
            cp = params["groups"][gi][pi]["cross"]
            mk, mv = jax.vmap(
                lambda c: L.cross_attention_kv(c, cfg, memory))(cp)
            out[(gi, pi)] = (mk, mv)
    return out


# --------------------------------------------------------------------------- #
# Paged decode (serving path). Pool pytree mirrors ``params['groups']``:
# attn positions hold {"k_pool","v_pool"} (or {"ckv_pool","kpe_pool"} for MLA,
# plus cross_* for enc-dec); ssm/rglru positions hold their dense states.
# block_table/seq_lens/write_* are shared across layers.
# --------------------------------------------------------------------------- #
def init_paged_caches(cfg: ModelConfig, specs: Dict[str, Any],
                      num_blocks: int, batch: int = 0, mem_len: int = 0):
    """specs: {"kv": KVPageSpec} or {"ckv": ..., "kpe": ...} for MLA."""
    from repro.serving import paged_cache as PC
    out = []
    for g in block_groups(cfg):
        per_pos = []
        for kind in g.kinds:
            if kind == SSD:
                one: Any = L.ssm_state_init(batch, cfg, cfg.cdtype)
            elif kind == RECURRENT:
                one = L.rglru_state_init(batch, cfg, cfg.cdtype)
            elif cfg.attention_kind == "mla":
                one = {"ckv_pool": PC.init_pool(specs["ckv"], num_blocks),
                       "kpe_pool": PC.init_pool(specs["kpe"], num_blocks)}
            else:
                one = {"k_pool": PC.init_pool(specs["kv"], num_blocks),
                       "v_pool": PC.init_pool(specs["kv"], num_blocks)}
            if g.cross and kind == ATTN:
                one.update({
                    "cross_k": jnp.zeros((batch, mem_len, cfg.num_kv_heads,
                                          cfg.hd), cfg.cdtype),
                    "cross_v": jnp.zeros((batch, mem_len, cfg.num_kv_heads,
                                          cfg.hd), cfg.cdtype),
                    "mem_len": jnp.zeros((batch,), jnp.int32)})
            per_pos.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (g.count,) + x.shape), one))
        out.append(tuple(per_pos))
    return tuple(out)


def _apply_layer_decode_paged(p, cfg: ModelConfig, g: Group, kind: str, x,
                              positions, cache, block_table, seq_lens,
                              write_blocks, write_slots, specs):
    if kind == SSD:
        h, st = L.ssd_decode(p["ssd"], cfg,
                             L.rms_norm(p["norm"], x, cfg.norm_eps), cache)
        return x + h, st
    if kind == RECURRENT:
        h, st = L.rglru_decode(p["rglru"], cfg,
                               L.rms_norm(p["norm1"], x, cfg.norm_eps), cache)
        x = x + h
        x = x + L.swiglu_mlp(p["mlp"], L.rms_norm(p["norm2"], x, cfg.norm_eps))
        return x, st
    win = _window(cfg)
    h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
    if cfg.attention_kind == "mla":
        out, new_pools = L.mla_decode_paged(
            p["attn"], cfg, h, positions, cache, block_table, seq_lens,
            write_blocks, write_slots, specs["ckv"], specs["kpe"])
    else:
        out, new_pools = L.attention_decode_paged(
            p["attn"], cfg, h, positions, cache, block_table, seq_lens,
            write_blocks, write_slots, specs["kv"], window=win)
    x = x + out
    new_cache = dict(new_pools)
    if g.cross:
        hx = L.rms_norm(p["norm_x"], x, cfg.norm_eps)
        x = x + L.cross_attention(p["cross"], cfg, hx,
                                  (cache["cross_k"], cache["cross_v"]),
                                  cache["mem_len"])
        new_cache.update({"cross_k": cache["cross_k"],
                          "cross_v": cache["cross_v"],
                          "mem_len": cache["mem_len"]})
    h2 = L.rms_norm(p["norm2"], x, cfg.norm_eps)
    x = x + _mlp_apply(p["mlp"], cfg, g.moe, h2)
    return x, new_cache


def decode_step_paged(params, cfg: ModelConfig, tokens: jax.Array,
                      seq_lens: jax.Array, block_table: jax.Array,
                      write_blocks: jax.Array, write_slots: jax.Array,
                      caches, specs: Dict[str, Any]):
    """One continuous-batching decode step against paged pools.

    tokens: (B,1); seq_lens: (B,) lengths BEFORE this step (== rope position);
    block_table: (B, max_blocks); write_blocks/slots: (B,) current page slot.
    Returns (logits (B,1,V), caches)."""
    positions = seq_lens[:, None].astype(jnp.int32)
    x = embed_tokens(params, cfg, tokens)
    groups = block_groups(cfg)
    new_caches = []
    for gi, g in enumerate(groups):
        def apply_pos(p_i, kind, xx, c_i, _g=g):
            return _apply_layer_decode_paged(
                p_i, cfg, _g, kind, xx, positions, c_i, block_table,
                seq_lens, write_blocks, write_slots, specs)
        x, nc = _scan_group(params["groups"][gi], cfg, g, x, apply_pos,
                            caches[gi], remat=False)
        new_caches.append(nc)
    return lm_logits(params, cfg, x), tuple(new_caches)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = True) -> jax.Array:
    """Mean next-token cross-entropy over positions with label >= 0."""
    logits = train_forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    if cfg.frontend.kind == "vision" and "patches" in batch:
        np_ = batch["patches"].shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], np_), -1, labels.dtype), labels], 1)
    mask = labels >= 0
    lab = jnp.where(mask, labels, 0)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, lab[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
