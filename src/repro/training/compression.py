"""Gradient compression for cross-pod reduction (beyond-paper, scale feature).

Two-level reduction for a (pod, data, model) mesh: the intra-pod all-reduce
runs at full precision over fast ICI; the cross-pod hop (slow DCN) moves a
compressed representation. Implemented as shard_map-compatible primitives:

  compressed_psum(x, axis)         — int8 absmax-quantized all-reduce
  hierarchical_psum(x, inner, outer, wire)
                                   — fp32 psum(inner) → wire-compressed
                                     psum(outer)

Error feedback (residual carrying) is provided for iterated use so the
quantization error does not bias the optimizer long-run.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8. Returns (q, scale)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32
                    ) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axis: str, wire: str = "int8") -> jax.Array:
    """All-reduce with a compressed wire format. Inside shard_map only.

    int8: each participant quantizes; the psum runs on dequantized fp32 (the
    wire cost is the int8 payload + one scale — what a real DCN allgather
    of quantized shards would move). bf16: cast-psum-cast.
    """
    if wire == "none":
        return jax.lax.psum(x, axis)
    if wire == "bf16":
        return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(x.dtype)
    if wire == "int8":
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale, jnp.float32)
        return jax.lax.psum(deq, axis).astype(x.dtype)
    raise ValueError(f"unknown wire {wire!r}")


def hierarchical_psum(x: jax.Array, inner_axis: str, outer_axis: str,
                      wire: str = "bf16") -> jax.Array:
    """fp32 all-reduce over the fast inner axis, compressed over the slow
    outer (cross-pod) axis."""
    x = jax.lax.psum(x, inner_axis)
    return compressed_psum(x, outer_axis, wire)


def error_feedback_compress(x: jax.Array, residual: jax.Array
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """EF21-style: compress(x + residual), carry the new residual.

    Returns (q, scale, new_residual)."""
    target = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale)
    return q, scale, target - deq
