"""Synthetic data pipeline — deterministic, seeded, host-side token stream.

Produces exactly the batch dict the model entry points consume:
  {"tokens": (B,S) int32, "labels": (B,S) int32,
   "frames": (B,F,d) for enc-dec (audio stub),
   "patches": (B,P,d) for VLM (vision stub)}

Labels are next-token-shifted tokens with -1 at padding. The stream is a
Zipf-ish unigram distribution so cross-entropy decreases measurably within
a few hundred steps (uniform tokens would pin loss at log V).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    vocab_cap: int = 0          # 0 → model vocab
    zipf_a: float = 1.3


def _frames_len(cfg: ModelConfig) -> int:
    return min(cfg.max_source_len, 64)


class SyntheticTokens:
    """Infinite iterator of training batches."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        self.rng = np.random.default_rng(dcfg.seed)
        self.vocab = dcfg.vocab_cap or cfg.vocab_size
        # fixed random "bigram" table makes the stream learnable
        self._next = np.asarray(
            self.rng.integers(0, self.vocab, size=(min(self.vocab, 4096),)),
            np.int32)

    def _sample_tokens(self, b: int, s: int) -> np.ndarray:
        z = self.rng.zipf(self.dcfg.zipf_a, size=(b, s + 1)).astype(np.int64)
        toks = (z % self.vocab).astype(np.int32)
        # half the positions follow the deterministic bigram table
        follow = self.rng.random((b, s)) < 0.5
        nxt = self._next[toks[:, :-1] % len(self._next)]
        toks[:, 1:] = np.where(follow, nxt, toks[:, 1:])
        return toks

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        cfg, d = self.cfg, self.dcfg
        toks = self._sample_tokens(d.batch_size, d.seq_len)
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1].copy(),
            "labels": toks[:, 1:].copy(),
        }
        if cfg.is_enc_dec:
            f = _frames_len(cfg)
            batch["frames"] = self.rng.standard_normal(
                (d.batch_size, f, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.frontend.kind == "vision":
            p = min(cfg.frontend.num_patches, 16)
            batch["patches"] = self.rng.standard_normal(
                (d.batch_size, p, cfg.d_model)).astype(np.float32) * 0.02
        return batch
