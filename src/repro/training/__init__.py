from repro.training.optim import AdamWConfig, adamw_init, adamw_update
from repro.training.train_step import TrainState, make_train_step, train_state_init
