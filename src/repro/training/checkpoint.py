"""Sharded checkpoint save/restore with an async writer (no orbax here).

Layout on disk:
  <dir>/step_<N>/
    MANIFEST.json         — {"step": N, "leaves": [{"path", "file", "shape",
                             "dtype"}], "meta": {...}}
    leaf_<i>.npy          — one array per pytree leaf (np.save)

Save gathers each leaf to host (works for single-process CPU and for
fully-addressable shardings); restore rebuilds the pytree and, given a
sharding tree, ``jax.device_put``s each leaf to its target sharding — i.e.
restore works onto a *different* mesh shape than the save ran on (elastic
restart), because the on-disk form is the unsharded logical array.

The async writer moves np.save off the training thread; ``wait()`` joins
outstanding writes (call before exiting / before deleting old steps).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

Params = Any


def _flatten_with_paths(tree) -> List:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class AsyncWriter:
    def __init__(self):
        self._q: "queue.Queue" = queue.Queue()
        self._errors: List[BaseException] = []
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            fn = item
            try:
                fn()
            except BaseException as e:      # surfaced at wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def submit(self, fn) -> None:
        self._q.put(fn)

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self.writer = AsyncWriter() if async_write else None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Params,
             meta: Optional[Dict[str, Any]] = None) -> str:
        """Snapshot ``tree`` at ``step``. Returns the checkpoint path."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves = _flatten_with_paths(tree)
        manifest = {"step": step, "meta": meta or {}, "leaves": []}
        arrays = []
        for i, (kp, leaf) in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            manifest["leaves"].append({
                "path": kp, "file": fname,
                "shape": list(arr.shape), "dtype": str(arr.dtype)})
            arrays.append((os.path.join(tmp, fname), arr))

        def commit():
            for f, a in arrays:
                np.save(f, a, allow_pickle=False)
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as fh:
                json.dump(manifest, fh)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.replace(tmp, path)          # atomic publish
            self._gc()

        if self.writer:
            self.writer.submit(commit)
        else:
            commit()
        return path

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self.writer:
            self.writer.wait()

    # ------------------------------------------------------------------ #
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Params,
                shardings: Optional[Params] = None) -> Params:
        """Rebuild the pytree saved at ``step``.

        ``like``: template pytree (structure + dtypes). ``shardings``: same
        structure of jax.sharding.Sharding — each leaf is device_put onto it
        (elastic restart onto a different mesh)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "MANIFEST.json")) as fh:
            manifest = json.load(fh)
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        assert len(flat_like) == len(manifest["leaves"]), \
            (len(flat_like), len(manifest["leaves"]))
        flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(flat_like))
        leaves = []
        for entry, tmpl, sh in zip(manifest["leaves"], flat_like, flat_sh):
            arr = np.load(os.path.join(path, entry["file"]),
                          allow_pickle=False)
            out = jax.numpy.asarray(arr, dtype=tmpl.dtype)
            if sh is not None:
                out = jax.device_put(out, sh)
            leaves.append(out)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_meta(self, step: int) -> Dict[str, Any]:
        path = os.path.join(self.dir, f"step_{step:08d}", "MANIFEST.json")
        with open(path) as fh:
            return json.load(fh)["meta"]
