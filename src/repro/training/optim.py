"""AdamW optimizer (from scratch — no optax on this container).

State is a pytree mirroring params: {"mu": tree, "nu": tree, "step": scalar}.
Moments are fp32 regardless of param dtype (mixed-precision training).

ZeRO-1: ``zero1_spec`` takes a param PartitionSpec and returns the optimizer
moment spec with one extra ``data``-sharded dim — optimizer state is
partitioned across the data-parallel axis, the standard optimizer-state
sharding trick that makes 32B-scale training fit 16 GiB/chip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def adamw_init(params: Params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros32, params),
            "nu": jax.tree.map(zeros32, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Params, grads: Params,
                 state: dict) -> Tuple[Params, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.where(gnorm > cfg.grad_clip, cfg.grad_clip / gnorm, 1.0) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * scale
        mu_n = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu_n = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        mh = mu_n / b1c
        nh = nu_n / b2c
        delta = mh / (jnp.sqrt(nh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu_n, nu_n

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
