"""Training step: loss → grads → AdamW, remat-friendly, pjit-shardable.

``make_train_step(cfg, opt)`` returns a pure function
  step(state, batch) -> (state, metrics)
that the launcher jits with in/out shardings. Remat policy is already inside
the model (scan-over-layers with jax.checkpoint around the block body).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.optim import AdamWConfig, adamw_init, adamw_update

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Params
    opt: dict


def train_state_init(rng: jax.Array, cfg: ModelConfig) -> TrainState:
    params = M.init_params(rng, cfg)
    return TrainState(params=params, opt=adamw_init(params))


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    """ShapeDtypeStruct train state (dry-run path, no allocation)."""
    return jax.eval_shape(
        lambda: train_state_init(jax.random.key(0), cfg))


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, *,
                    remat: bool = True, n_micro: int = 1,
                    accum_shardings=None):
    """``n_micro > 1``: gradient accumulation — the global batch is split
    into n_micro microbatches scanned sequentially; per-micro grads are
    averaged into a bf16 accumulator.

    ``accum_shardings``: optional pytree of NamedSharding for the
    accumulator (ZeRO-2-style: the add lowers to a reduce-scatter over the
    data axis, so the carried accumulator costs 1/dp of a model copy
    instead of a full one)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch, remat=remat))(params)

    def step(state: TrainState, batch: Dict[str, jax.Array]
             ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if n_micro == 1:
            loss, grads = grads_of(state.params, batch)
        else:
            from repro.models import dist
            micro = {k: v.reshape((n_micro, v.shape[0] // n_micro)
                                  + v.shape[1:])
                     for k, v in batch.items()}
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), state.params)
            if accum_shardings is not None:
                acc0 = jax.lax.with_sharding_constraint(acc0,
                                                        accum_shardings)

            def body(carry, mb):
                acc, loss_sum = carry
                l, g = grads_of(state.params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + (gi / n_micro).astype(jnp.bfloat16),
                    acc, g)
                if accum_shardings is not None:
                    acc = jax.lax.with_sharding_constraint(acc,
                                                           accum_shardings)
                return (acc, loss_sum + l), None

            (grads, loss), _ = jax.lax.scan(
                body, (acc0, jnp.zeros((), jnp.float32)), micro,
                unroll=True if dist.ctx().unroll else 1)
            loss = loss / n_micro
        params, opt_state, om = adamw_update(opt, state.params, grads,
                                             state.opt)
        metrics = {"loss": loss, **om}
        return TrainState(params=params, opt=opt_state), metrics

    return step
