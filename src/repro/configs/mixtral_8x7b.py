"""Mixtral 8x7B — 8-expert top-2 MoE with sliding-window attention.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attention_kind="sliding",
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, num_shared_experts=0, top_k=2,
                  d_ff_expert=14336),
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
))
