"""Whisper-large-v3 — encoder-decoder audio transformer. [arXiv:2212.04356]

The conv frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (post-conv, stride-2 downsampled). 32 encoder + 32 decoder layers.
"""
from repro.configs.base import FrontendConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,                   # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    frontend=FrontendConfig(kind="audio", downsample=2),
    max_source_len=1500,
    rope_theta=10_000.0,             # we use RoPE in place of learned abs-pos
    source="arXiv:2212.04356",
))
