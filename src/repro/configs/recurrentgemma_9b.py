"""RecurrentGemma-9B — Griffin hybrid: RG-LRU + local attention, 2:1.
[arXiv:2402.19427]

38 blocks with pattern (recurrent, recurrent, local-attn); MQA (kv=1).
"""
from repro.configs.base import (ATTN, RECURRENT, ModelConfig,
                                RecurrentConfig, register)

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attention_kind="sliding",
    sliding_window=2048,
    recurrent=RecurrentConfig(lru_width=4096, d_conv=4,
                              block_pattern=(RECURRENT, RECURRENT, ATTN),
                              local_window=2048),
    rope_theta=10_000.0,
    source="arXiv:2402.19427",
))
