"""Mamba2-370M — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=32,                    # SSD heads = d_inner / head_dim
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,                          # attention-free, no separate MLP
    vocab_size=50280,
    attention_kind="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4,
                  chunk_size=256, n_groups=1),
    source="arXiv:2405.21060",
))
