"""InternVL2-2B — InternViT frontend (STUB) + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf]

``input_specs()`` provides precomputed patch embeddings which are prepended
to the token embeddings.
"""
from repro.configs.base import FrontendConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    frontend=FrontendConfig(kind="vision", num_patches=256),
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821",
))
