"""Model configuration system.

One frozen dataclass covers every assigned architecture family:
dense / MoE / MLA / enc-dec (audio) / hybrid (RG-LRU) / VLM / SSM.
Configs are pure data — the model builder in ``repro.models.model``
interprets them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

# Layer-kind tags used in block patterns.
ATTN = "attn"
RECURRENT = "rglru"
SSD = "ssd"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0     # always-on experts (DeepSeek style)
    top_k: int = 0
    d_ff_expert: int = 0            # per-expert hidden dim
    first_dense_layers: int = 0     # leading layers that use the dense MLP
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk_size: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU recurrent block (Griffin / RecurrentGemma)."""
    lru_width: int = 0              # defaults to d_model if 0
    d_conv: int = 4
    block_pattern: Tuple[str, ...] = (RECURRENT, RECURRENT, ATTN)
    local_window: int = 2048


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() provides precomputed embeddings."""
    kind: str = "none"              # "audio" | "vision" | "none"
    # audio: conv stem downsampling factor (Whisper: 2 after two conv1d)
    downsample: int = 2
    # vision: number of image patch embeddings prepended to the text sequence
    num_patches: int = 256


@dataclass(frozen=True)
class PrefillCapabilities:
    """What the prefill path can do for one model family — the prefill
    analogue of the connector ``capabilities()`` descriptor: a frozen
    dataclass that the engine, scheduler, router, and planner *consume*
    (no ``cfg.attention_kind`` string checks outside this module).

      incremental      chunk-at-a-time prefill compute (every family —
                       attention chunks against a position-tagged cache,
                       recurrent/SSM layers carry state across chunks,
                       enc-dec/vision run a preamble then chunk tokens)
      resumable        a mid-stream snapshot (layer states + window KV
                       tail) restarts compute at the crash point instead
                       of from token 0
      prefix_cache     shared-prefix KV replay/skip is *safe*: every
                       cached row is still attendable by later tokens
                       (false for ring-buffer caches, which only retain
                       the last window of whatever prompt built them)
      encoder_preamble a non-resumable encoder/vision pass must run on P
                       before token chunking starts
      kv_on_wire       per-token KV ships P→D (false for pure-SSM
                       stacks, whose handoff is states only)
      latent_kv        KV is the MLA compressed latent (ckv+kpe), which
                       changes wire bytes/token and pool layout
      window           sliding-window size (0 = full attention)
    """
    family: str
    incremental: bool
    resumable: bool
    prefix_cache: bool
    encoder_preamble: bool
    kv_on_wire: bool
    latent_kv: bool
    window: int = 0


@dataclass(frozen=True)
class ConnectorConfig:
    """Deployment-side selection of the P→D KV-transport backend.

    Pure data, like every config here: ``kind`` names a backend in the
    ``repro.core.transport`` registry, and ``build()`` instantiates it
    (fields a backend does not accept are dropped by the factory, so one
    config can describe any backend)."""
    kind: str = "inproc"            # inproc | shm | rdma (registry name)
    bandwidth_gbps: float = 25.0
    fixed_latency_s: float = 5e-6   # per-read setup cost (modeled backends)
    max_inflight: int = 32          # concurrent issued-but-unread reads
    buffer_capacity_bytes: int = 1 << 32
    tick_seconds: float = 1e-4      # rdma: wire progress per scheduler tick
    chunk_bytes: int = 256 << 10    # rdma: preferred wire granularity

    def build(self):
        """Instantiate the configured KV connector."""
        from repro.core.transport import make_connector
        return make_connector(self.kind,
                              bandwidth_gbps=self.bandwidth_gbps,
                              fixed_latency_s=self.fixed_latency_s,
                              max_inflight=self.max_inflight,
                              buffer_capacity_bytes=self.buffer_capacity_bytes,
                              tick_seconds=self.tick_seconds,
                              chunk_bytes=self.chunk_bytes)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | audio | hybrid | vlm | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // num_heads
    # -- attention flavour ------------------------------------------------
    attention_kind: str = "full"    # full | sliding | mla | none
    sliding_window: int = 0         # >0 with attention_kind=="sliding"
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # -- optional sub-configs ---------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # -- enc-dec ----------------------------------------------------------
    encoder_layers: int = 0         # >0 → encoder-decoder (num_layers = decoder)
    max_source_len: int = 1500      # encoder positions (Whisper: 1500 frames)
    # -- numerics ---------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # -- citation / provenance --------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.num_experts > 0

    def prefill_capabilities(self) -> PrefillCapabilities:
        """Derive the per-family prefill capability descriptor. This is
        the single place family structure maps to prefill behaviour —
        everything downstream consumes the dataclass."""
        kinds = set(self.layer_kinds())
        preamble = self.is_enc_dec or self.frontend.kind in ("vision",
                                                             "audio")
        window = self.sliding_window if self.attention_kind == "sliding" \
            else 0
        has_state = (RECURRENT in kinds) or (SSD in kinds)
        return PrefillCapabilities(
            family=self.family,
            incremental=True,
            # snapshot resume needs bounded carried state: layer states
            # and/or a window KV tail. Full-attention KV grows with the
            # prompt (those families resume via the prefix cache), and a
            # preamble (encoder memory) is not snapshot-restorable.
            resumable=(has_state or window > 0) and not preamble,
            prefix_cache=(self.family in ("dense", "moe")
                          and self.attention_kind in ("full", "mla")
                          and not preamble),
            encoder_preamble=preamble,
            kv_on_wire=ATTN in kinds,
            latent_kv=self.attention_kind == "mla",
            window=window)

    @property
    def supports_chunked_prefill(self) -> bool:
        """Incremental (chunk-at-a-time) prefill compute — now supported
        for every family (see ``prefill_capabilities``): attention-only
        stacks chunk against a dense position-tagged cache, sliding
        windows chunk with window-aware masking, recurrent/SSM layers
        carry state across chunks, and enc-dec/multimodal families run
        their encoder preamble once then chunk the token sequence."""
        return self.prefill_capabilities().incremental

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-decoder-layer block kind, length == num_layers."""
        if self.family == "ssm":
            return (SSD,) * self.num_layers
        if self.recurrent is not None:
            pat = self.recurrent.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        return (ATTN,) * self.num_layers

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (used by planner + roofline) ------------------ #
    def param_count(self) -> int:
        """Exact-ish analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        n = v * d                       # token embedding
        if not self.tie_embeddings:
            n += v * d                  # lm head
        n += d                          # final norm
        kinds = self.layer_kinds()
        for k in kinds:
            n += self._block_params(k)
        if self.is_enc_dec:
            # encoder self-attn blocks + cross-attn in decoder
            n += self.encoder_layers * self._block_params(ATTN)
            n += self.num_layers * self._attn_params()      # cross-attn
            n += self.num_layers * self.d_model              # extra norm
        return n

    def _attn_params(self) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.hd
        if self.attention_kind == "mla":
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            n = d * h * qk_hd                               # q proj (no q-lora in V2-Lite)
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # kv down-proj
            n += m.kv_lora_rank                             # kv-a norm
            n += m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)  # kv up
            n += h * m.v_head_dim * d                       # o proj
            return n
        n = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.qkv_bias:
            n += h * hd + 2 * kv * hd
        return n

    def _mlp_params(self, layer_idx_is_moe: bool) -> int:
        d = self.d_model
        if layer_idx_is_moe and self.is_moe:
            e = self.moe
            per = 3 * d * e.d_ff_expert
            n = (e.num_experts + e.num_shared_experts) * per
            n += d * e.num_experts                          # router
            return n
        return 3 * d * self.d_ff                            # SwiGLU

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        if kind == SSD:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            g = s.n_groups
            n = d * (2 * di + 2 * g * s.d_state + nh)       # in_proj (x,z,B,C,dt)
            n += s.d_conv * (di + 2 * g * s.d_state)        # conv
            n += nh * 3                                     # A, D, dt_bias
            n += di                                         # out norm
            n += di * d                                     # out proj
            return n + d                                    # block norm
        if kind == RECURRENT:
            r = self.recurrent
            w = r.lru_width or d
            n = 2 * d * w                                   # x/gate proj
            n += r.d_conv * w                               # conv
            n += 3 * w                                      # lru a, input gate params (approx)
            n += w * d                                      # out proj
            return n + 2 * d + self._mlp_params(False) + d
        # attention block
        n = self._attn_params() + 2 * d
        moe_layer = self.is_moe
        n += self._mlp_params(moe_layer)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        e = self.moe
        full = self.param_count()
        moe_layers = self.num_layers - e.first_dense_layers
        per_expert = 3 * self.d_model * e.d_ff_expert
        inactive = moe_layers * (e.num_experts - e.top_k) * per_expert
        return full - inactive


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # Import side-effect registration of all shipped configs.
    from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
