"""Architecture configs. Importing this package registers every config."""
from repro.configs.base import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                                RecurrentConfig, FrontendConfig,
                                get_config, list_configs, register)

# Assigned architectures (side-effect registration).
from repro.configs import deepseek_v2_lite_16b  # noqa: F401
from repro.configs import mixtral_8x7b          # noqa: F401
from repro.configs import qwen1_5_32b           # noqa: F401
from repro.configs import phi3_medium_14b       # noqa: F401
from repro.configs import qwen3_4b              # noqa: F401
from repro.configs import qwen2_5_32b           # noqa: F401
from repro.configs import whisper_large_v3      # noqa: F401
from repro.configs import recurrentgemma_9b     # noqa: F401
from repro.configs import internvl2_2b          # noqa: F401
from repro.configs import mamba2_370m           # noqa: F401
# The paper's own experimental model.
from repro.configs import llama2_7b             # noqa: F401

ASSIGNED = [
    "deepseek-v2-lite-16b", "mixtral-8x7b", "qwen1.5-32b", "phi3-medium-14b",
    "qwen3-4b", "qwen2.5-32b", "whisper-large-v3", "recurrentgemma-9b",
    "internvl2-2b", "mamba2-370m",
]

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
           "RecurrentConfig", "FrontendConfig", "get_config", "list_configs",
           "register", "ASSIGNED"]
