"""DeepSeek-V2-Lite 16B — MLA + fine-grained MoE. [arXiv:2405.04434; hf]

Assignment string lists both "64e top-6" and "2 shared + 160 routed";
published V2-Lite is 64 routed + 2 shared, top-6 (160 routed is full V2).
We implement 64 routed + 2 shared top-6 — see DESIGN.md §5.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,                      # dense MLP of the first layer
    vocab_size=102400,
    attention_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                  d_ff_expert=1408, first_dense_layers=1),
    rope_theta=10_000.0,
    source="arXiv:2405.04434",
))
