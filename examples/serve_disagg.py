"""End-to-end driver: serve a ~100M-param model with batched requests
through the full disaggregated stack — heterogeneous P/D vendor profiles,
global scheduler with load-aware routing, a mid-run D-instance failure
(recovered via re-prefill), and elastic scale-up.

Two runtimes share the stack:

  * single-process (default): every engine lives in this process and the
    `GlobalScheduler` pumps the P-side flight loop and D-side decode loop
    in one tick loop.
  * ``--two-process``: the P and D engines run in *separate OS processes*
    (``repro.serving.multiproc``), control plane over multiprocessing
    queues, KV data plane over SharedMemoryConnector segments. Requires
    ``--connector shm``.

``--parity`` runs both runtimes back to back and asserts token-exact
output — the acceptance check the CI two-process-smoke job enforces.

  PYTHONPATH=src python examples/serve_disagg.py [--requests 24]
  PYTHONPATH=src python examples/serve_disagg.py --two-process --connector shm
  PYTHONPATH=src python examples/serve_disagg.py --two-process --connector shm --parity
"""
import argparse
import time

import numpy as np

from repro.configs.base import ConnectorConfig, ModelConfig
from repro.core.compat.precision import WireFormat
from repro.serving.engine import VendorProfile
from repro.serving.request import Request

# ~100M params: 16L × d640 (GQA 10/5), vocab 16k
CFG = ModelConfig(name="demo-100m", family="dense", num_layers=16,
                  d_model=640, num_heads=10, num_kv_heads=5, head_dim=64,
                  d_ff=2560, vocab_size=16384, param_dtype="float32",
                  compute_dtype="float32")
# tp must divide the model's KV heads (5) — the KV shards on the wire
# are per-TP-rank slices of the head axis
VENDOR_P = VendorProfile("vendorB", block_size=16, layout="nhbd",
                         kv_dtype="float32", tp=5, hardware="gpu-b")
VENDOR_D = VendorProfile("vendorA", block_size=8, layout="nbhd",
                         kv_dtype="float32", tp=1, hardware="gpu-a")
PARAMS_SEED = 0


def build_requests(n: int, max_new: int):
    rng = np.random.default_rng(0)
    return [Request(req_id=f"req-{i:03d}",
                    prompt=rng.integers(0, CFG.vocab_size,
                                        int(rng.integers(16, 64))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def run_single(args, faults: bool):
    """Single-process runtime: all engines in this process."""
    import jax

    from repro.core.disagg import DisaggPipeline
    from repro.models import model as M
    from repro.serving.engine import Engine
    from repro.serving.scheduler import GlobalScheduler
    from repro.serving.server import Server

    n = sum(int(np.prod(p.shape)) for p in
            jax.tree.leaves(M.abstract_params(CFG)))
    print(f"model: {CFG.name} ({n/1e6:.0f}M params)")
    params = M.init_params(jax.random.key(PARAMS_SEED), CFG)

    mk = lambda name, vendor, role: Engine(
        name, CFG, params, vendor, num_blocks=512, max_batch=8,
        max_seq_len=256, role=role)
    p0 = mk("P0", VENDOR_P, "prefill")
    d0 = mk("D0", VENDOR_D, "decode")

    connector = ConnectorConfig(kind=args.connector,
                                bandwidth_gbps=25.0).build()
    caps = connector.capabilities()
    print(f"KV connector: {caps.transport} ({caps.bandwidth_gbps:g} Gbps, "
          f"{caps.fixed_latency_s*1e6:g} µs/read, "
          f"max {caps.max_inflight} in flight, "
          f"{'cross-process' if caps.cross_process else 'in-process'})")
    pipeline = DisaggPipeline(connector, WireFormat("raw", "float32"))
    # chunked streaming: each prefill chunk's KV hits the wire while the
    # next chunk computes, and decode steps interleave with long prefills
    sched = GlobalScheduler(pipeline, prefill_chunk=args.prefill_chunk)
    for e in (p0, d0) + ((mk("D1", VENDOR_D, "decode"),) if faults else ()):
        sched.add_instance(e)
    server = Server(sched)

    reqs = build_requests(args.requests, args.max_new)
    print(f"serving {len(reqs)} requests "
          f"({'1P+2D, fault injection on' if faults else '1P+1D'}) ...")
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    tick = 0
    failed = scaled = False
    while sched.stats.finished + sched.stats.failed < len(reqs) \
            and tick < 5000:
        sched.step()
        tick += 1
        if faults and tick == 6 and not failed:   # kill a decode node mid-run
            print("  !! injecting D0 failure (volatile KV lost)")
            d0.fail()
            failed = True
        if faults and tick == 14 and not scaled:   # elastic scale-up
            print("  ++ joining D2 (elastic scale-up)")
            sched.add_instance(mk("D2", VENDOR_D, "decode"))
            scaled = True
    wall = time.perf_counter() - t0

    done = [r for r in reqs if r.done]
    total_tokens = sum(len(r.output_tokens) for r in done)
    print(f"\nfinished {len(done)}/{len(reqs)} requests, "
          f"{total_tokens} tokens in {wall:.1f}s "
          f"({total_tokens / wall:.0f} tok/s on CPU)")
    print(f"requeues after failure: {sched.stats.requeues}")
    print(f"P dispatches: {dict(sched.stats.p_dispatches)}")
    print(f"D dispatches: {dict(sched.stats.d_dispatches)}")
    _print_wire(pipeline.transfer.stats)
    assert len(done) == len(reqs), "lost requests!"
    sample = reqs[0]
    print(f"sample stream {sample.req_id}: {sample.output_tokens[:12]}...")
    connector.close()                 # free staged buffers / shm segments
    return {r.req_id: list(r.output_tokens) for r in reqs}


def run_two_process(args):
    """Two-process runtime: P and D engines in separate OS processes."""
    import os

    from repro.serving.multiproc import EngineSpec, serve_two_process

    if args.connector != "shm":
        raise SystemExit("--two-process needs the cross-process staging "
                         "backend: pass --connector shm")
    p_spec = EngineSpec("P0", CFG, VENDOR_P, params_seed=PARAMS_SEED,
                        num_blocks=512, max_batch=8, max_seq_len=256,
                        role="prefill")
    d_spec = EngineSpec("D0", CFG, VENDOR_D, params_seed=PARAMS_SEED,
                        num_blocks=512, max_batch=8, max_seq_len=256,
                        role="decode")
    reqs = build_requests(args.requests, args.max_new)
    print(f"serving {len(reqs)} requests on 1P + 1D "
          f"(separate OS processes; parent pid {os.getpid()}) ...")
    t0 = time.perf_counter()
    tokens, rt = serve_two_process(p_spec, d_spec, reqs,
                                   prefill_chunk=args.prefill_chunk,
                                   max_wall_s=600.0)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(t) for t in tokens.values())
    print(f"\nfinished {rt.stats.finished}/{len(reqs)} requests, "
          f"{total_tokens} tokens in {wall:.1f}s "
          f"({total_tokens / wall:.0f} tok/s on CPU)")
    print(f"worker pids: {rt.worker_pids} (parent {os.getpid()})")
    _print_wire(rt.transfer_stats)
    assert rt.stats.finished == len(reqs), "lost requests!"
    return tokens


def _print_wire(ts) -> None:
    print(f"KV wire: {ts.transfers} transfers ({ts.chunks} streamed chunks), "
          f"{ts.bytes_moved/1e6:.1f} MB, "
          f"peak pinned buffer {ts.peak_buffer_bytes/1e6:.1f} MB")
    if ts.chunks and ts.overlap_modeled_seconds:
        print(f"overlap (modeled): {ts.overlap_modeled_seconds*1e6:.1f} µs of "
              f"{ts.modeled_seconds*1e6:.1f} µs wire time hidden under "
              f"chunk compute")
    if ts.wall_handoff_seconds:
        print(f"overlap (measured): {ts.wall_overlap_seconds*1e3:.1f} ms of "
              f"wire time hidden under prefill compute across "
              f"{ts.wall_handoff_seconds*1e3:.1f} ms of total handoff wall "
              f"time")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="tokens per streamed prefill chunk (0 = monolithic "
                         "single-tick handoff)")
    ap.add_argument("--connector", default="inproc",
                    choices=["inproc", "shm", "rdma"],
                    help="KV-transport backend: in-process (zero-copy), "
                         "shared-memory (real cross-process staging), or "
                         "modeled-RDMA (async multi-tick completion)")
    ap.add_argument("--two-process", action="store_true",
                    help="run the P and D engines in separate OS processes "
                         "(multiproc runtime; requires --connector shm)")
    ap.add_argument("--parity", action="store_true",
                    help="run single-process then two-process and assert "
                         "token-exact output (implies --two-process)")
    args = ap.parse_args()

    if args.parity:
        print("== parity: single-process reference ==")
        ref = run_single(args, faults=False)
        print("\n== parity: two-process runtime ==")
        two = run_two_process(args)
        assert set(ref) == set(two), (sorted(ref), sorted(two))
        for rid in sorted(ref):
            assert ref[rid] == two[rid], \
                f"{rid}: single={ref[rid]} two-process={two[rid]}"
        print(f"\nPARITY OK: {len(ref)} requests token-exact across "
              "single-process and two-process runtimes")
    elif args.two_process:
        run_two_process(args)
    else:
        run_single(args, faults=True)


if __name__ == "__main__":
    main()
