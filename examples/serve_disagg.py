"""End-to-end driver: serve a ~100M-param model with batched requests
through the full disaggregated stack — heterogeneous P/D vendor profiles,
load-aware routing, a mid-run D-instance failure (recovered via
re-prefill), and elastic scale-up.

Two runtimes share the stack:

  * single-process (default): every engine lives in this process and the
    `GlobalScheduler` pumps the P-side flight loop and D-side decode loop
    in one tick loop.
  * multi-process (``--num-p/--num-d``, or ``--two-process`` for the
    degenerate 1P+1D point): N prefill + M decode engines run in
    *separate OS processes* (``repro.serving.multiproc``), the parent
    routes each request by measured load, control plane over
    multiprocessing queues, KV data plane over SharedMemoryConnector
    segments. Requires ``--connector shm``. ``--plan`` sizes the topology
    with the planner's joint optimization (``plan_deployment`` →
    ``to_cluster_spec``) and prints a plan-vs-measured report;
    ``--num-p/--num-d`` override the planned counts.

``--parity`` runs both runtimes back to back and exits nonzero with a
per-request token diff unless the output is token-exact — the acceptance
check the CI smoke jobs enforce.

  PYTHONPATH=src python examples/serve_disagg.py [--requests 24]
  PYTHONPATH=src python examples/serve_disagg.py --two-process --connector shm
  PYTHONPATH=src python examples/serve_disagg.py --num-p 2 --num-d 2 \\
      --connector shm --parity
  PYTHONPATH=src python examples/serve_disagg.py --plan --connector shm
"""
import argparse
import sys
import time

import numpy as np

from repro.configs.base import ConnectorConfig, ModelConfig
from repro.core.compat.precision import WireFormat
from repro.serving.engine import VendorProfile
from repro.serving.request import Request

# ~100M params: 16L × d640 (GQA 10/5), vocab 16k
CFG = ModelConfig(name="demo-100m", family="dense", num_layers=16,
                  d_model=640, num_heads=10, num_kv_heads=5, head_dim=64,
                  d_ff=2560, vocab_size=16384, param_dtype="float32",
                  compute_dtype="float32")
# tp must divide the model's KV heads (5) — the KV shards on the wire
# are per-TP-rank slices of the head axis
VENDOR_P = VendorProfile("vendorB", block_size=16, layout="nhbd",
                         kv_dtype="float32", tp=5, hardware="gpu-b")
VENDOR_D = VendorProfile("vendorA", block_size=8, layout="nbhd",
                         kv_dtype="float32", tp=1, hardware="gpu-a")
PARAMS_SEED = 0


def build_requests(n: int, max_new: int):
    rng = np.random.default_rng(0)
    return [Request(req_id=f"req-{i:03d}",
                    prompt=rng.integers(0, CFG.vocab_size,
                                        int(rng.integers(16, 64))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def run_single(args, faults: bool):
    """Single-process runtime: all engines in this process."""
    import jax

    from repro.core.disagg import DisaggPipeline
    from repro.models import model as M
    from repro.serving.engine import Engine
    from repro.serving.scheduler import GlobalScheduler
    from repro.serving.server import Server

    n = sum(int(np.prod(p.shape)) for p in
            jax.tree.leaves(M.abstract_params(CFG)))
    print(f"model: {CFG.name} ({n/1e6:.0f}M params)")
    params = M.init_params(jax.random.key(PARAMS_SEED), CFG)

    mk = lambda name, vendor, role: Engine(
        name, CFG, params, vendor, num_blocks=512, max_batch=8,
        max_seq_len=256, role=role, prefix_cache=args.prefix_cache)
    p0 = mk("P0", VENDOR_P, "prefill")
    d0 = mk("D0", VENDOR_D, "decode")

    connector = ConnectorConfig(kind=args.connector,
                                bandwidth_gbps=25.0).build()
    caps = connector.capabilities()
    print(f"KV connector: {caps.transport} ({caps.bandwidth_gbps:g} Gbps, "
          f"{caps.fixed_latency_s*1e6:g} µs/read, "
          f"max {caps.max_inflight} in flight, "
          f"{'cross-process' if caps.cross_process else 'in-process'})")
    pipeline = DisaggPipeline(connector, WireFormat("raw", "float32"),
                              codec=args.codec)
    # chunked streaming: each prefill chunk's KV hits the wire while the
    # next chunk computes, and decode steps interleave with long prefills
    sched = GlobalScheduler(pipeline, prefill_chunk=args.prefill_chunk)
    for e in (p0, d0) + ((mk("D1", VENDOR_D, "decode"),) if faults else ()):
        sched.add_instance(e)
    server = Server(sched)

    reqs = build_requests(args.requests, args.max_new)
    print(f"serving {len(reqs)} requests "
          f"({'1P+2D, fault injection on' if faults else '1P+1D'}) ...")
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    tick = 0
    failed = scaled = False
    while sched.stats.finished + sched.stats.failed < len(reqs) \
            and tick < 5000:
        sched.step()
        tick += 1
        if faults and tick == 6 and not failed:   # kill a decode node mid-run
            print("  !! injecting D0 failure (volatile KV lost)")
            d0.fail()
            failed = True
        if faults and tick == 14 and not scaled:   # elastic scale-up
            print("  ++ joining D2 (elastic scale-up)")
            sched.add_instance(mk("D2", VENDOR_D, "decode"))
            scaled = True
    wall = time.perf_counter() - t0

    done = [r for r in reqs if r.done]
    total_tokens = sum(len(r.output_tokens) for r in done)
    print(f"\nfinished {len(done)}/{len(reqs)} requests, "
          f"{total_tokens} tokens in {wall:.1f}s "
          f"({total_tokens / wall:.0f} tok/s on CPU)")
    print(f"requeues after failure: {sched.stats.requeues}")
    print(f"P dispatches: {dict(sched.stats.p_dispatches)}")
    print(f"D dispatches: {dict(sched.stats.d_dispatches)}")
    _print_wire(pipeline.transfer.stats)
    assert len(done) == len(reqs), "lost requests!"
    sample = reqs[0]
    print(f"sample stream {sample.req_id}: {sample.output_tokens[:12]}...")
    connector.close()                 # free staged buffers / shm segments
    return {r.req_id: list(r.output_tokens) for r in reqs}


def _build_cluster(args):
    """Resolve the multi-process topology: planner-fed (--plan) with
    --num-p/--num-d overriding, or explicit counts (default 1P+1D)."""
    from repro.serving.multiproc import ClusterSpec, EngineSpec

    plan = None
    if args.plan:
        from repro.core.planner.hardware import GPU_A, GPU_B
        from repro.core.planner.optimizer import plan_deployment
        from repro.core.planner.workload import Workload
        wl = Workload(qps=args.plan_qps, input_len=48,
                      output_len=args.max_new,
                      slo_ttft_s=10.0, slo_tpot_s=1.0)
        plan = plan_deployment(CFG, wl, GPU_B, GPU_A)
        print(f"planner chose {plan.ratio()} "
              f"(capacity {plan.qps_capacity:.2f} req/s, "
              f"${plan.cost_per_hour:.2f}/h)")
        spec = plan.to_cluster_spec(CFG, p_vendor=VENDOR_P,
                                    d_vendor=VENDOR_D,
                                    params_seed=PARAMS_SEED,
                                    num_blocks=512, max_batch=8,
                                    max_seq_len=256,
                                    num_p=args.num_p, num_d=args.num_d)
        if args.prefix_cache:
            import dataclasses
            spec = ClusterSpec(
                p=tuple(dataclasses.replace(e, prefix_cache=True)
                        for e in spec.p),
                d=tuple(dataclasses.replace(e, prefix_cache=True)
                        for e in spec.d))
        return spec, plan
    n_p = args.num_p or 1
    n_d = args.num_d or 1
    spec = ClusterSpec(
        p=tuple(EngineSpec(f"P{i}", CFG, VENDOR_P, params_seed=PARAMS_SEED,
                           num_blocks=512, max_batch=8, max_seq_len=256,
                           role="prefill", prefix_cache=args.prefix_cache)
                for i in range(n_p)),
        d=tuple(EngineSpec(f"D{i}", CFG, VENDOR_D, params_seed=PARAMS_SEED,
                           num_blocks=512, max_batch=8, max_seq_len=256,
                           role="decode", prefix_cache=args.prefix_cache)
                for i in range(n_d)))
    return spec, plan


def run_cluster(args):
    """Multi-process runtime: N P + M D engines in separate OS processes."""
    import os

    from repro.serving.multiproc import serve_cluster
    from repro.serving.multiproc.report import format_report, plan_vs_measured

    if args.connector != "shm":
        raise SystemExit("the multi-process runtime needs the cross-process "
                         "staging backend: pass --connector shm")
    cluster, plan = _build_cluster(args)
    reqs = build_requests(args.requests, args.max_new)
    print(f"serving {len(reqs)} requests on {cluster.ratio()} "
          f"(separate OS processes; parent pid {os.getpid()}) ...")
    t0 = time.perf_counter()
    tokens, rt = serve_cluster(cluster, reqs,
                               prefill_chunk=args.prefill_chunk,
                               codec=args.codec,
                               max_wall_s=600.0)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(t) for t in tokens.values())
    print(f"\nfinished {rt.stats.finished}/{len(reqs)} requests, "
          f"{total_tokens} tokens in {wall:.1f}s "
          f"({total_tokens / wall:.0f} tok/s on CPU)")
    print(f"worker pids: {rt.worker_pids} (parent {os.getpid()})")
    _print_wire(rt.transfer_stats)
    print()
    print(format_report(plan_vs_measured(rt, reqs, plan=plan, wall_s=wall)))
    assert rt.stats.finished == len(reqs), "lost requests!"
    return tokens


def _print_wire(ts) -> None:
    print(f"KV wire: {ts.transfers} transfers ({ts.chunks} streamed chunks), "
          f"{ts.bytes_moved/1e6:.1f} MB, "
          f"peak pinned buffer {ts.peak_buffer_bytes/1e6:.1f} MB")
    if ts.payload_bytes:
        print(f"wire/payload: {ts.bytes_moved/1e6:.2f}/"
              f"{ts.payload_bytes/1e6:.2f} MB "
              f"(compression ratio {ts.wire_compression:.2f})")
    if ts.chunks and ts.overlap_modeled_seconds:
        print(f"overlap (modeled): {ts.overlap_modeled_seconds*1e6:.1f} µs of "
              f"{ts.modeled_seconds*1e6:.1f} µs wire time hidden under "
              f"chunk compute")
    if ts.wall_handoff_seconds:
        print(f"overlap (measured): {ts.wall_overlap_seconds*1e3:.1f} ms of "
              f"wire time hidden under prefill compute across "
              f"{ts.wall_handoff_seconds*1e3:.1f} ms of total handoff wall "
              f"time")


def _parity_diff(ref, got) -> int:
    """Print a readable per-request token diff; returns mismatch count."""
    bad = 0
    for rid in sorted(set(ref) | set(got)):
        a, b = ref.get(rid), got.get(rid)
        if a == b:
            continue
        bad += 1
        if a is None or b is None:
            print(f"  {rid}: only in "
                  f"{'single-process' if b is None else 'multi-process'} run",
                  file=sys.stderr)
            continue
        div = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
                   min(len(a), len(b)))
        print(f"  {rid}: diverges at token {div} "
              f"(single has {len(a)}, multi has {len(b)})", file=sys.stderr)
        lo, hi = max(0, div - 2), div + 4
        print(f"    single[{lo}:{hi}] = {a[lo:hi]}", file=sys.stderr)
        print(f"    multi [{lo}:{hi}] = {b[lo:hi]}", file=sys.stderr)
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="tokens per streamed prefill chunk (0 = monolithic "
                         "single-tick handoff)")
    ap.add_argument("--connector", default="inproc",
                    choices=["inproc", "shm", "rdma"],
                    help="KV-transport backend: in-process (zero-copy), "
                         "shared-memory (real cross-process staging), or "
                         "modeled-RDMA (async multi-tick completion)")
    ap.add_argument("--codec", default="fixed",
                    choices=["fixed", "pickle"],
                    help="chunk wire codec: zero-copy fixed-layout "
                         "segments or the legacy pickled blob")
    ap.add_argument("--num-p", type=int, default=None,
                    help="prefill worker processes (multi-process runtime; "
                         "overrides --plan)")
    ap.add_argument("--num-d", type=int, default=None,
                    help="decode worker processes (multi-process runtime; "
                         "overrides --plan)")
    ap.add_argument("--plan", action="store_true",
                    help="size the topology with the planner's joint "
                         "optimization (plan_deployment → to_cluster_spec) "
                         "and print a plan-vs-measured report")
    ap.add_argument("--plan-qps", type=float, default=0.5,
                    help="workload QPS fed to --plan")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the shared-prefix KV cache on every "
                         "engine: cache-hit prompt blocks skip prefill "
                         "compute on P and KV bytes on the wire, and the "
                         "cluster router scores D-side prefix affinity")
    ap.add_argument("--two-process", action="store_true",
                    help="run the degenerate 1P+1D multi-process runtime "
                         "(alias for --num-p 1 --num-d 1; requires "
                         "--connector shm)")
    ap.add_argument("--parity", action="store_true",
                    help="run single-process then multi-process and exit "
                         "nonzero with a token diff unless output is "
                         "token-exact")
    args = ap.parse_args()
    multiproc = (args.two_process or args.plan
                 or args.num_p is not None or args.num_d is not None)

    if args.parity:
        print("== parity: single-process reference ==")
        ref = run_single(args, faults=False)
        print("\n== parity: multi-process runtime ==")
        got = run_cluster(args)
        bad = _parity_diff(ref, got)
        if bad:
            print(f"\nPARITY FAILED: {bad}/{len(set(ref) | set(got))} "
                  "request(s) diverge between the single-process and "
                  "multi-process runtimes", file=sys.stderr)
            sys.exit(1)
        print(f"\nPARITY OK: {len(ref)} requests token-exact across "
              "single-process and multi-process runtimes")
    elif multiproc:
        run_cluster(args)
    else:
        run_single(args, faults=True)


if __name__ == "__main__":
    main()
