"""End-to-end driver: serve a ~100M-param model with batched requests
through the full disaggregated stack — heterogeneous P/D vendor profiles,
global scheduler with load-aware routing, a mid-run D-instance failure
(recovered via re-prefill), and elastic scale-up.

  PYTHONPATH=src python examples/serve_disagg.py [--requests 24]
"""
import argparse
import time

import numpy as np
import jax

from repro.configs.base import ConnectorConfig, ModelConfig
from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler
from repro.serving.server import Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="tokens per streamed prefill chunk (0 = monolithic "
                         "single-tick handoff)")
    ap.add_argument("--connector", default="inproc",
                    choices=["inproc", "shm", "rdma"],
                    help="KV-transport backend: in-process (zero-copy), "
                         "shared-memory (real cross-process staging), or "
                         "modeled-RDMA (async multi-tick completion)")
    args = ap.parse_args()

    # ~100M params: 16L × d640 (GQA 10/5), vocab 16k
    cfg = ModelConfig(name="demo-100m", family="dense", num_layers=16,
                      d_model=640, num_heads=10, num_kv_heads=5, head_dim=64,
                      d_ff=2560, vocab_size=16384, param_dtype="float32",
                      compute_dtype="float32")
    n = sum(int(np.prod(p.shape)) for p in
            jax.tree.leaves(M.abstract_params(cfg)))
    print(f"model: {cfg.name} ({n/1e6:.0f}M params)")
    params = M.init_params(jax.random.key(0), cfg)

    # tp must divide the model's KV heads (5) — the KV shards on the wire
    # are per-TP-rank slices of the head axis
    vendor_p = VendorProfile("vendorB", block_size=16, layout="nhbd",
                             kv_dtype="float32", tp=5, hardware="gpu-b")
    vendor_d = VendorProfile("vendorA", block_size=8, layout="nbhd",
                             kv_dtype="float32", tp=1, hardware="gpu-a")

    mk = lambda name, vendor, role: Engine(
        name, cfg, params, vendor, num_blocks=512, max_batch=8,
        max_seq_len=256, role=role)
    p0 = mk("P0", vendor_p, "prefill")
    d0 = mk("D0", vendor_d, "decode")
    d1 = mk("D1", vendor_d, "decode")

    connector = ConnectorConfig(kind=args.connector,
                                bandwidth_gbps=25.0).build()
    caps = connector.capabilities()
    print(f"KV connector: {caps.transport} ({caps.bandwidth_gbps:g} Gbps, "
          f"{caps.fixed_latency_s*1e6:g} µs/read, "
          f"max {caps.max_inflight} in flight, "
          f"{'cross-process' if caps.cross_process else 'in-process'})")
    pipeline = DisaggPipeline(connector, WireFormat("raw", "float32"))
    # chunked streaming: each prefill chunk's KV hits the wire while the
    # next chunk computes, and decode steps interleave with long prefills
    sched = GlobalScheduler(pipeline, prefill_chunk=args.prefill_chunk)
    for e in (p0, d0, d1):
        sched.add_instance(e)
    server = Server(sched)

    rng = np.random.default_rng(0)
    reqs = [Request(req_id=f"req-{i:03d}",
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(16, 64))
                                        ).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    print(f"serving {len(reqs)} requests on 1P + 2D ...")
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    tick = 0
    failed = scaled = False
    while sched.stats.finished < len(reqs) and tick < 5000:
        sched.step()
        tick += 1
        if tick == 6 and not failed:          # kill a decode node mid-run
            print("  !! injecting D0 failure (volatile KV lost)")
            d0.fail()
            failed = True
        if tick == 14 and not scaled:          # elastic scale-up
            print("  ++ joining D2 (elastic scale-up)")
            sched.add_instance(mk("D2", vendor_d, "decode"))
            scaled = True
    wall = time.perf_counter() - t0

    done = [r for r in reqs if r.done]
    total_tokens = sum(len(r.output_tokens) for r in done)
    print(f"\nfinished {len(done)}/{len(reqs)} requests, "
          f"{total_tokens} tokens in {wall:.1f}s "
          f"({total_tokens / wall:.0f} tok/s on CPU)")
    print(f"requeues after failure: {sched.stats.requeues}")
    print(f"P dispatches: {dict(sched.stats.p_dispatches)}")
    print(f"D dispatches: {dict(sched.stats.d_dispatches)}")
    ts = pipeline.transfer.stats
    print(f"KV wire: {ts.transfers} transfers ({ts.chunks} streamed chunks), "
          f"{ts.bytes_moved/1e6:.1f} MB, "
          f"peak pinned buffer {ts.peak_buffer_bytes/1e6:.1f} MB")
    if ts.chunks:
        print(f"overlap: {ts.overlap_modeled_seconds*1e6:.1f} µs of "
              f"{ts.modeled_seconds*1e6:.1f} µs modeled wire time hidden "
              f"under chunk compute")
    assert len(done) == len(reqs), "lost requests!"
    sample = reqs[0]
    print(f"sample stream {sample.req_id}: {sample.output_tokens[:12]}...")
    connector.close()                 # free staged buffers / shm segments


if __name__ == "__main__":
    main()
