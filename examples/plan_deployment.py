"""Joint optimization walkthrough (paper §III-C/§IV): given a customer QPS
+ SLO + context profile, pick parallel strategies and the P:D ratio on the
paper's heterogeneous GPU pair, then sanity-check the plan in the
discrete-event simulator.

  PYTHONPATH=src python examples/plan_deployment.py [--qps 6] [--in 1024]
"""
import argparse

from repro.configs.base import get_config
from repro.core.planner.events import simulate
from repro.core.planner.hardware import GPU_A, GPU_B, TPU_V5E
from repro.core.planner.optimizer import plan_deployment
from repro.core.planner.simulator import InstanceModel
from repro.core.planner.workload import Workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=6.0)
    ap.add_argument("--input", type=int, default=1024, dest="input_len")
    ap.add_argument("--output", type=int, default=1024, dest="output_len")
    ap.add_argument("--model", default="llama2-7b")
    args = ap.parse_args()

    cfg = get_config(args.model)
    wl = Workload(qps=args.qps, input_len=args.input_len,
                  output_len=args.output_len,
                  slo_ttft_s=1.0, slo_tpot_s=0.08)
    print(f"workload: {wl.label()}  SLO ttft≤{wl.slo_ttft_s}s "
          f"tpot≤{wl.slo_tpot_s*1e3:.0f}ms\n")

    print("— stage 1 (Eq. 1): prefill strategy on GPU B (512 TF, 32 GB)")
    print("— stage 2 (Eq. 4): decode strategy + instance count on GPU A "
          "(312 TF, 80 GB, 2 TB/s)")
    plan = plan_deployment(cfg, wl, p_hw=GPU_B, d_hw=GPU_A)
    print(f"\nplan: {plan.ratio()}")
    print(f"  P: {plan.n_prefill}× {plan.prefill.strategy.label()} "
          f"(l_p={plan.prefill.latency_s*1e3:.0f} ms, "
          f"{plan.prefill.vram_gb:.1f} GiB)  "
          f"[searched {plan.prefill.candidates_evaluated}, "
          f"rejected {plan.prefill.rejected_slo} SLO / "
          f"{plan.prefill.rejected_vram} VRAM]")
    print(f"  D: {plan.n_decode}× {plan.decode.strategy.label()} "
          f"batch={plan.decode.batch} "
          f"(l_d={plan.decode.latency_s*1e3:.1f} ms, "
          f"{plan.decode.vram_gb:.1f} GiB)")
    print(f"  cost {plan.cost_per_hour:.1f} $/h, "
          f"capacity {plan.qps_capacity:.2f} QPS")

    # validate in the event simulator at the planned ratio
    mP = InstanceModel(cfg, GPU_B, plan.prefill.strategy)
    mD = InstanceModel(cfg, GPU_A, plan.decode.strategy)
    r = simulate(cfg, wl, p_model=mP, d_model=mD,
                 n_prefill=plan.n_prefill, n_decode=plan.n_decode,
                 duration_s=90)
    print(f"\nsimulated at plan: ttft {r.ttft_mean()*1e3:.0f} ms "
          f"(SLO {wl.slo_ttft_s*1e3:.0f}), tpot {r.tpot_mean()*1e3:.1f} ms "
          f"(SLO {wl.slo_tpot_s*1e3:.0f}), "
          f"attainment {r.slo_attainment(wl)*100:.0f}%")

    # cross-check: same plan on a homogeneous TPU v5e pool
    plan_tpu = plan_deployment(cfg, wl, p_hw=TPU_V5E, d_hw=TPU_V5E)
    print(f"\nv5e reference: {plan_tpu.ratio()} "
          f"P={plan_tpu.prefill.strategy.label()} "
          f"D={plan_tpu.decode.strategy.label()} "
          f"cost {plan_tpu.cost_per_hour:.1f} $/h")


if __name__ == "__main__":
    main()
