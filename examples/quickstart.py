"""60-second tour: build a tiny model, serve one request through a
heterogeneous P→D handoff, and plan a deployment.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.configs.base import ModelConfig
from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler
from repro.serving.server import Server

# 1. a tiny dense LM (the same ModelConfig drives the 32B assigned archs)
cfg = ModelConfig(name="tiny", family="dense", num_layers=3, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, param_dtype="float32",
                  compute_dtype="float32")
params = M.init_params(jax.random.key(0), cfg)

# 2. two "vendors": P has block_size 8 / head-major layout / TP=2,
#    D has block_size 4 / token-major layout / TP=1 — the compat module
#    aligns them at handoff (paper §III-B).
p_inst = Engine("P0", cfg, params,
                VendorProfile("vendorB", block_size=8, layout="nhbd",
                              kv_dtype="float32", tp=2),
                num_blocks=64, max_batch=4, max_seq_len=64, role="prefill")
d_inst = Engine("D0", cfg, params,
                VendorProfile("vendorA", block_size=4, layout="nbhd",
                              kv_dtype="float32", tp=1),
                num_blocks=64, max_batch=4, max_seq_len=64, role="decode")

pipeline = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
sched = GlobalScheduler(pipeline)
sched.add_instance(p_inst)
sched.add_instance(d_inst)
server = Server(sched)

# 3. serve a request
req = Request(req_id="hello",
              prompt=np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32),
              max_new_tokens=8)
result = server.serve([req])
print("tokens:", req.output_tokens)
print("wire bytes through the compat module:",
      pipeline.transfer.stats.bytes_moved)
print("summary:", result.summary())

# 4. plan a deployment for the paper's GPU pair
from repro.configs.base import get_config
from repro.core.planner.hardware import GPU_A, GPU_B
from repro.core.planner.optimizer import plan_deployment
from repro.core.planner.workload import Workload

plan = plan_deployment(get_config("llama2-7b"),
                       Workload(qps=3.0, input_len=512, output_len=1024),
                       p_hw=GPU_B, d_hw=GPU_A)
print(f"plan: {plan.ratio()}  P={plan.prefill.strategy.label()} "
      f"D={plan.decode.strategy.label()} cost={plan.cost_per_hour:.1f}$/h")
