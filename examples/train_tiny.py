"""Train a ~100M-param LM for a few hundred steps on CPU with the full
training substrate: AdamW + schedule, microbatch accumulation, periodic
checkpoints with the async writer, and a restart-from-checkpoint proof.

  PYTHONPATH=src python examples/train_tiny.py [--steps 300]
"""
import argparse
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, SyntheticTokens
from repro.training.optim import AdamWConfig
from repro.training.train_step import make_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = ModelConfig(name="train-100m", family="dense", num_layers=8,
                      d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
                      d_ff=2048, vocab_size=16384, param_dtype="float32",
                      compute_dtype="float32")
    n = sum(int(np.prod(p.shape))
            for p in jax.tree.leaves(M.abstract_params(cfg)))
    print(f"model: {n/1e6:.0f}M params, batch {args.batch}×{args.seq}, "
          f"{args.steps} steps")

    opt = AdamWConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps)
    state = train_state_init(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, opt, n_micro=2))
    data = iter(SyntheticTokens(cfg, DataConfig(batch_size=args.batch,
                                                seq_len=args.seq, seed=0)))

    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_tiny")
    mgr = CheckpointManager(ckpt_dir, keep=2)
    t0 = time.time()
    first = None
    for i in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
        if i % 50 == 0 or i == 1:
            toks = i * args.batch * args.seq
            print(f"step {i:4d}  loss {float(m['loss']):.3f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{toks / (time.time() - t0):.0f} tok/s")
        if i % max(args.steps // 3, 1) == 0 or i == args.steps:
            mgr.save(i, state, meta={"loss": float(m['loss'])})
    mgr.wait()
    final = float(m["loss"])
    print(f"\nloss {first:.3f} → {final:.3f} "
          f"({'OK' if final < first - 0.5 else 'no descent?'})")

    # restart proof: restore the latest checkpoint and take a step
    last = mgr.latest_step()
    restored = mgr.restore(last, like=jax.eval_shape(lambda: state))
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    _, m2 = step(restored, batch)
    print(f"restored step_{last}: next-step loss {float(m2['loss']):.3f} "
          f"(checkpoints at {ckpt_dir})")


if __name__ == "__main__":
    main()
