"""TransferEngine (RDMA analogue): staging/read/complete lifecycle, pinned
pool accounting + exhaustion, latency modeling."""
import numpy as np
import pytest

from repro.core.kv_transfer import PinnedBufferPool, TransferEngine


def test_stage_read_complete_lifecycle():
    eng = TransferEngine(bandwidth_gbps=10.0)
    payload = {"k": np.ones((4, 2, 8), np.float32)}
    n = eng.stage("req1@P0", payload, {"seq": 4})
    assert n == 4 * 2 * 8 * 4
    assert eng.staged_keys() == ["req1@P0"]
    got, meta = eng.read("req1@P0")
    np.testing.assert_array_equal(got["k"], payload["k"])
    assert meta == {"seq": 4}
    eng.complete("req1@P0")
    assert eng.staged_keys() == []
    assert eng.pool.in_use == 0
    assert eng.stats.transfers == 1
    assert eng.stats.bytes_moved == n
    assert eng.stats.modeled_seconds == pytest.approx(n / 10e9)


def test_read_missing_key_raises():
    eng = TransferEngine()
    with pytest.raises(KeyError):
        eng.read("nope")


def test_pinned_pool_exhaustion_and_high_water():
    pool = PinnedBufferPool(100)
    pool.acquire(60)
    pool.acquire(30)
    assert pool.high_water == 90
    with pytest.raises(MemoryError):
        pool.acquire(20)
    pool.release(60)
    pool.acquire(20)
    assert pool.in_use == 50
    assert pool.high_water == 90


def test_engine_pool_exhaustion_surfaces():
    eng = TransferEngine(buffer_capacity_bytes=64)
    with pytest.raises(MemoryError):
        eng.stage("big", {"x": np.zeros(128, np.float32)})


def test_drop_frees_buffer():
    eng = TransferEngine()
    eng.stage("k1", {"x": np.zeros(8, np.float32)})
    eng.drop("k1")
    assert eng.pool.in_use == 0
    assert eng.staged_keys() == []


def test_buffer_reuse_no_growth():
    """Registered-once semantics: repeated stage/complete cycles must not
    grow the high-water mark (the paper's 'reduce temporary allocation')."""
    eng = TransferEngine()
    for i in range(20):
        eng.stage(f"k{i}", {"x": np.zeros(1024, np.float32)})
        eng.read(f"k{i}")
        eng.complete(f"k{i}")
    assert eng.pool.high_water == 4096
