"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward/train step on CPU — output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED
from repro.configs.base import (FrontendConfig, MLAConfig, ModelConfig,
                                MoEConfig, RecurrentConfig, SSMConfig,
                                get_config)
from repro.models import model as M


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink every dimension while preserving the family structure."""
    pat = len(cfg.recurrent.block_pattern) if cfg.recurrent else 1
    kw = dict(
        num_layers=max(2, pat + (1 if cfg.is_moe and cfg.moe.first_dense_layers
                                 else 0)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
        sliding_window=8 if cfg.sliding_window else 0,
    )
    if cfg.num_kv_heads == cfg.num_heads:          # keep MHA archs MHA
        kw["num_kv_heads"] = 4
    if cfg.is_moe:
        kw["moe"] = MoEConfig(
            num_experts=4, num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            top_k=2, d_ff_expert=32,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4,
                              chunk_size=4)
        kw["num_heads"] = 8
        kw["head_dim"] = 0
    if cfg.recurrent is not None:
        kw["recurrent"] = dataclasses.replace(cfg.recurrent, lru_width=64)
        kw["num_layers"] = pat + 1                 # pattern + remainder
    if cfg.is_enc_dec:
        kw["encoder_layers"] = 2
        kw["max_source_len"] = 10
    if cfg.frontend.kind == "vision":
        kw["frontend"] = FrontendConfig(kind="vision", num_patches=4)
    return cfg.with_(**kw)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_train_and_decode(arch):
    cfg = reduced(get_config(arch))
    rng = np.random.default_rng(hash(arch) % 2 ** 31)
    params = M.init_params(jax.random.key(0), cfg)
    b, s = 2, 12
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    inputs = {"tokens": jnp.asarray(toks[:, :-1])}
    offset = 0
    if cfg.is_enc_dec:
        fr = jnp.asarray(rng.normal(size=(b, 10, cfg.d_model)), jnp.float32)
        batch["frames"] = fr
        inputs["frames"] = fr
    if cfg.frontend.kind == "vision":
        pt = jnp.asarray(rng.normal(size=(b, 4, cfg.d_model)), jnp.float32)
        batch["patches"] = pt
        inputs["patches"] = pt
        offset = 4

    # one training step (loss + grads finite, params update)
    from repro.training.optim import AdamWConfig
    from repro.training.train_step import make_train_step, train_state_init
    state = train_state_init(jax.random.key(1), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert changed, "train step did not update params"

    # prefill + one decode step (shapes + no NaN)
    caches = M.init_caches(cfg, b, s + 4 + offset, jnp.float32, mem_len=10)
    last, caches = M.prefill(params, cfg, inputs, caches)
    assert last.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(last)).all()
    nxt = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((b, 1), offset + s, jnp.int32)
    logits, _ = M.decode_step(params, cfg, nxt, pos, caches)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_registered_and_counts(arch):
    """The FULL config exists with the assigned dimensions and an analytic
    param count in a sane band (no allocation here)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected_band = {
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "mixtral-8x7b": (44e9, 49e9),
        "qwen1.5-32b": (30e9, 36e9),   # assigned config is MHA (kv=40)
        "phi3-medium-14b": (13e9, 15.5e9),
        "qwen3-4b": (3.5e9, 4.8e9),
        "qwen2.5-32b": (31e9, 34.5e9),
        "whisper-large-v3": (1.4e9, 2.2e9),
        "recurrentgemma-9b": (8e9, 11e9),
        "internvl2-2b": (1.5e9, 2.5e9),
        "mamba2-370m": (0.3e9, 0.45e9),
    }[arch]
    assert expected_band[0] <= n <= expected_band[1], (arch, n)
    assert cfg.active_param_count() <= n
