"""Shared-prefix KV cache subsystem (repro.serving.prefix_cache).

The acceptance bar:

  1. *token parity*: serving with the cache on is bit-identical to
     serving cold — across raw/bf16/int8 wire formats, through the COW
     mid-block divergence path, and across OS processes.
  2. *real skipping*: a D-resident prefix keeps its chunks off the wire
     (``TransferStats.prefix_hit_tokens`` / ``bytes_saved``) and a
     P-resident prefix skips the prefill forward pass
     (``EngineStats.prefix_cached_tokens``).
  3. *safety*: eviction never frees a pinned block; the store's pages
     and the allocator's free list always partition the pool.
  4. *affinity*: the cluster router lands same-prefix requests on the D
     instance already holding their prefix.
"""
import numpy as np
import pytest

import jax

from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.models import model as M
from repro.serving import router
from repro.serving.engine import Engine, VendorProfile
from repro.serving.paged_cache import BlockAllocator
from repro.serving.prefix_cache import (STORE_OWNER, HostPrefixStore,
                                        PrefixStore, hashing)
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler
from tests.conftest import TINY_FAMILIES

CFG = TINY_FAMILIES["dense"]
VENDOR_P = VendorProfile("B", block_size=8, layout="nhbd",
                         kv_dtype="float32", tp=2)
VENDOR_D = VendorProfile("A", block_size=4, layout="nbhd",
                         kv_dtype="float32", tp=1)
SEED = 0
CHUNK = 8


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.key(SEED), CFG)


# --------------------------------------------------------------------- #
# 1. chained hashing
# --------------------------------------------------------------------- #
def test_chain_hashes_one_digest_per_full_block():
    toks = np.arange(20, dtype=np.int32)
    chain = hashing.chain_hashes(toks, 8)
    assert len(chain) == 2                     # 20 // 8 full blocks
    # stable across dtype of the same token values
    assert chain == hashing.chain_hashes(toks.astype(np.int64), 8)
    # the chain is positional: same block content, different parent
    other = hashing.chain_hashes(np.concatenate([toks[8:16], toks[:8]]), 8)
    assert chain[0] != other[0]
    # limit truncates before hashing
    assert hashing.chain_hashes(toks, 8, limit=15) == chain[:1]


def test_matched_prefix_tokens_counts_leading_run_only():
    toks = np.arange(32, dtype=np.int32)
    chain = hashing.chain_hashes(toks, 8)
    assert hashing.matched_prefix_tokens(chain, set(chain), 8) == 32
    assert hashing.matched_prefix_tokens(chain, set(chain[:2]), 8) == 16
    # a hole in the chain stops the run even if later digests are cached
    holed = {chain[0], chain[2], chain[3]}
    assert hashing.matched_prefix_tokens(chain, holed, 8) == 8
    assert hashing.matched_prefix_tokens(chain, set(), 8) == 0


# --------------------------------------------------------------------- #
# 2. PrefixStore: pinning, LRU eviction, COW detection, allocator
#    invariants
# --------------------------------------------------------------------- #
def _insert_prompt(store, alloc, seq_id, prompt):
    """Simulate adoption: allocate blocks for the full prompt blocks and
    insert them under their chained digests."""
    bs = store.block_size
    full = len(prompt) // bs
    blocks = alloc.allocate(seq_id, full)
    parent = hashing.ROOT
    for b in range(full):
        blk = prompt[b * bs:(b + 1) * bs]
        digest = hashing.block_hash(parent, blk)
        store.insert(seq_id, digest, parent, blk, blocks[b])
        parent = digest
    return blocks


def test_store_match_acquire_release_and_lru_eviction():
    alloc = BlockAllocator(8)
    store = PrefixStore(alloc, block_size=4)
    p1 = np.arange(8, dtype=np.int32)
    p2 = np.concatenate([p1[:4], np.arange(100, 104, dtype=np.int32)])
    _insert_prompt(store, alloc, "s1", p1)
    store.release_seq("s1")
    alloc.free("s1")                           # no-op: all blocks adopted
    _insert_prompt(store, alloc, "s2", p2)     # shares block 0's digest
    store.release_seq("s2")
    alloc.free("s2")                           # its duplicate head block
    assert len(store) == 3                     # shared head cached once
    assert alloc.blocks_of(STORE_OWNER) and alloc.free_blocks == 8 - 3

    m = store.match(p1, limit=8)
    assert m.tokens == 8 and len(m.block_ids) == 2
    store.acquire(m, "reader")
    # pinned blocks never evict; the unpinned third block does
    assert store.evict(10) == 1
    assert len(store) == 2
    assert store.match(p1, limit=8).tokens == 8
    store.release_seq("reader")
    assert store.evict(10) == 2
    assert alloc.free_blocks == 8              # everything back in the pool
    assert not alloc.blocks_of(STORE_OWNER)


def test_store_match_detects_mid_block_divergence_as_cow():
    alloc = BlockAllocator(8)
    store = PrefixStore(alloc, block_size=4)
    p1 = np.arange(8, dtype=np.int32)
    blocks = _insert_prompt(store, alloc, "s1", p1)
    store.release_seq("s1")
    # diverges inside the second block: full-block chain stops at 4,
    # the divergence block extends the match copy-on-write
    p2 = np.array([0, 1, 2, 3, 4, 5, 99, 98], dtype=np.int32)
    m = store.match(p2, limit=8)
    assert m.tokens == 6
    assert len(m.block_ids) == 1
    assert m.cow_src == blocks[1] and m.cow_len == 2
    # truncation below the matched depth drops blocks AND the COW
    # extension; a match that already fits is returned unchanged
    t = m.truncated(0, store.block_size)
    assert t.tokens == 0 and t.cow_src is None and not t.block_ids
    assert m.truncated(1, store.block_size) is m


def test_store_insert_is_refresh_noop_for_cached_digest():
    alloc = BlockAllocator(8)
    store = PrefixStore(alloc, block_size=4)
    p = np.arange(4, dtype=np.int32)
    _insert_prompt(store, alloc, "s1", p)
    store.release_seq("s1")
    # second sequence re-derives the same digest for its private block:
    # insert must refuse (no double-index), leaving ownership untouched
    mine = alloc.allocate("s2", 1)
    digest = hashing.block_hash(hashing.ROOT, p)
    assert store.insert("s2", digest, hashing.ROOT, p, mine[0]) is False
    assert alloc.blocks_of("s2") == mine
    assert len(store) == 1


# --------------------------------------------------------------------- #
# 3. single-process serving: parity + skipping across wire formats
# --------------------------------------------------------------------- #
def _sched(params, prefix_cache, wire, num_blocks=64):
    mk = lambda name, vendor, role: Engine(
        name, CFG, params, vendor, num_blocks=num_blocks, max_batch=4,
        max_seq_len=64, role=role, prefix_cache=prefix_cache)
    sched = GlobalScheduler(DisaggPipeline(TransferEngine(), wire),
                            prefill_chunk=CHUNK)
    sched.add_instance(mk("P0", VENDOR_P, "prefill"))
    sched.add_instance(mk("D0", VENDOR_D, "decode"))
    return sched


def _serve_sequentially(sched, reqs, max_ticks=400):
    for r in reqs:
        sched.submit(r)
        for _ in range(max_ticks):
            if r.state.name in ("FINISHED", "FAILED"):
                break
            sched.step()
        assert r.state.name == "FINISHED"
    return {r.req_id: list(r.output_tokens) for r in reqs}


def _shared_prefix_reqs(n=3, shared=40, tail=4, max_new=4, seed=11):
    rng = np.random.default_rng(seed)
    head = rng.integers(0, CFG.vocab_size, shared).astype(np.int32)
    return [Request(req_id=f"q{i}",
                    prompt=np.concatenate(
                        [head, rng.integers(0, CFG.vocab_size,
                                            tail).astype(np.int32)]),
                    max_new_tokens=max_new)
            for i in range(n)]


@pytest.mark.parametrize("wire", [WireFormat("raw", "float32"),
                                  WireFormat("raw", "bfloat16"),
                                  WireFormat("int8")],
                         ids=["raw-f32", "raw-bf16", "int8"])
def test_cached_vs_cold_token_parity_across_wire_formats(params, wire):
    """The cache must never change a token: the D store holds exactly the
    bits the wire delivered, so reuse is bit-stable per wire format."""
    ref = _serve_sequentially(_sched(params, False, wire),
                              _shared_prefix_reqs())
    sched = _sched(params, True, wire)
    got = _serve_sequentially(sched, _shared_prefix_reqs())
    assert got == ref
    # the shared 40 tokens of requests 2 and 3 skipped the wire, and the
    # P engine skipped their forward pass
    assert sched.pipeline.transfer.stats.prefix_hit_tokens >= 2 * 40
    assert sched.pipeline.transfer.stats.bytes_saved > 0
    assert sched.p_pool["P0"].stats.prefix_cached_tokens >= 2 * 40


def test_mid_block_divergence_takes_cow_path(params):
    """Prompts diverging inside a D block must reuse past the block
    boundary (COW page copy) and stay token-exact."""
    rng = np.random.default_rng(3)
    head = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)
    mk = lambda i, tail: Request(req_id=f"q{i}",
                                 prompt=np.concatenate([head, tail]),
                                 max_new_tokens=4)
    # both prompts share 18 tokens — not a multiple of D's block_size 4
    reqs = lambda: [mk(0, np.array([7, 9, 11, 13], np.int32)),
                    mk(1, np.array([7, 9, 20, 21], np.int32))]
    wire = WireFormat("raw", "float32")
    ref = _serve_sequentially(_sched(params, False, wire), reqs())
    sched = _sched(params, True, wire)
    got = _serve_sequentially(sched, reqs())
    assert got == ref
    # 18 shared tokens: 4 full blocks + a 2-token COW extension
    assert sched.pipeline.transfer.stats.prefix_hit_tokens == 18
    d = sched.d_pool["D0"]
    assert d.prefix_store.hit_tokens == 18


def test_eviction_under_pressure_never_breaks_serving(params):
    """A pool barely larger than one sequence forces the store to evict
    on every reservation; distinct prompts must all still finish and the
    allocator must stay consistent."""
    wire = WireFormat("raw", "float32")
    # 44 prompt + 4 new = 48 tokens → 12 D-blocks; 16-block pool leaves
    # almost nothing for the store without on-demand eviction
    sched = _sched(params, True, wire, num_blocks=16)
    rng = np.random.default_rng(5)
    reqs = [Request(req_id=f"q{i}",
                    prompt=rng.integers(0, CFG.vocab_size,
                                        44).astype(np.int32),
                    max_new_tokens=4)
            for i in range(4)]
    _serve_sequentially(sched, reqs)
    d = sched.d_pool["D0"]
    assert d.prefix_store.evicted_blocks > 0   # pressure really evicted
    # pool partition invariant: free + store-owned + scratch == all
    owned = len(d.allocator.blocks_of(STORE_OWNER))
    assert d.allocator.free_blocks + owned == 16 - 1


def test_requeue_resumes_from_cached_prefix(params):
    """The retry of a failed D stream extends the original prompt, so the
    P host store replays the original prefill instead of recomputing it."""
    wire = WireFormat("raw", "float32")
    sched = _sched(params, True, wire)
    reqs = _shared_prefix_reqs(n=2, max_new=6)
    _serve_sequentially(sched, [reqs[0]])
    p = sched.p_pool["P0"]
    replayed_before = p.stats.prefix_cached_tokens
    d = sched.d_pool["D0"]
    sched.submit(reqs[1])
    for _ in range(3):
        sched.step()
    d.fail()                                   # volatile KV gone mid-flight
    for _ in range(400):
        if sched.stats.finished >= 2:
            break
        sched.step()
    assert sched.stats.finished == 2
    assert sched.stats.requeues >= 1
    # the retry's prefill replayed ≥ the original's cached full blocks
    # instead of recomputing the whole (prompt + generated-prefix) prompt
    assert p.stats.prefix_cached_tokens > replayed_before


# --------------------------------------------------------------------- #
# 4. router affinity (pure, no processes)
# --------------------------------------------------------------------- #
def _dsnap(iid, prompt_blocks=0, prompt=None, active=0, free_blocks=15,
           block_size=4):
    hashes = frozenset()
    if prompt is not None and prompt_blocks:
        hashes = frozenset(
            hashing.chain_hashes(prompt, block_size)[:prompt_blocks])
    return router.DSnapshot(iid=iid, active=active, max_batch=4,
                            free_blocks=free_blocks, block_size=block_size,
                            max_blocks_per_seq=16, max_seq_len=64,
                            block_bytes=1024, prefix_hashes=hashes)


def test_pick_d_prefix_affinity_beats_load():
    prompt = np.arange(24, dtype=np.int32)
    warm_busy = _dsnap("D0", prompt_blocks=4, prompt=prompt, active=2)
    cold_idle = _dsnap("D1", active=0)
    got = router.pick_d([warm_busy, cold_idle], 24, 4, prompt=prompt)
    assert got[0] == "D0"                      # affinity beats occupancy
    # no prompt → affinity off → legacy load ordering is preserved
    assert router.pick_d([warm_busy, cold_idle], 24, 4)[0] == "D1"
    # foreign hashes score zero: legacy ordering again
    other = _dsnap("D0", prompt_blocks=4,
                   prompt=np.arange(100, 124, dtype=np.int32), active=2)
    assert router.pick_d([other, cold_idle], 24, 4, prompt=prompt)[0] == "D1"


def test_pick_d_affinity_tiebreaks_by_longest_prefix():
    prompt = np.arange(32, dtype=np.int32)
    short = _dsnap("D0", prompt_blocks=2, prompt=prompt)
    long = _dsnap("D1", prompt_blocks=6, prompt=prompt)
    assert router.pick_d([short, long], 32, 4, prompt=prompt)[0] == "D1"


# --------------------------------------------------------------------- #
# 5. cross-process: the cache through real worker processes
# --------------------------------------------------------------------- #
def _spec(name, vendor, role, prefix_cache=True):
    from repro.serving.multiproc import EngineSpec
    return EngineSpec(name, CFG, vendor, params_seed=SEED, num_blocks=64,
                      max_batch=4, max_seq_len=64, role=role,
                      prefix_cache=prefix_cache)


def test_cross_process_cached_vs_cold_token_parity_and_skipping(params):
    """Acceptance: over real OS processes, a shared 40-token prefix must
    (a) change no token vs the cold single-process loop, (b) keep ≥ the
    shared blocks off the wire (``prefix_hit_tokens``/``bytes_saved``),
    and (c) skip the P-side forward pass for the resident prefix."""
    from repro.serving.multiproc import TwoProcessRuntime
    wire = WireFormat("raw", "float32")
    ref = _serve_sequentially(_sched(params, False, wire),
                              _shared_prefix_reqs())

    reqs = _shared_prefix_reqs()
    rt = TwoProcessRuntime(_spec("P0", VENDOR_P, "prefill"),
                           _spec("D0", VENDOR_D, "decode"),
                           prefill_chunk=CHUNK)
    rt.start()
    try:
        for r in reqs:
            rt.serve([r], max_wall_s=120.0)
    finally:
        rt.shutdown()
    assert {r.req_id: list(r.output_tokens) for r in reqs} == ref
    # requests 2 and 3 share 40 leading tokens with request 1: at least
    # those 2 × 40 tokens' chunks never crossed the wire …
    assert rt.transfer_stats.prefix_hit_tokens >= 2 * 40
    assert rt.transfer_stats.bytes_saved > 0
    # … and the P process never recomputed them either
    assert rt.worker_stats["P0"]["prefix_cached_tokens"] >= 2 * 40


def test_cluster_2p2d_affinity_routes_same_prefix_to_same_d(params):
    """Once a D advertises a prefix (heartbeat digest summary), a new
    request sharing it must land there — even when plain load-ordering
    would pick the other D."""
    import time

    from repro.serving.multiproc import ClusterRuntime, ClusterSpec
    spec = ClusterSpec(
        p=tuple(_spec(f"P{i}", VENDOR_P, "prefill") for i in range(2)),
        d=tuple(_spec(f"D{i}", VENDOR_D, "decode") for i in range(2)))
    rng = np.random.default_rng(21)
    head_a = rng.integers(0, CFG.vocab_size, 40).astype(np.int32)
    head_b = rng.integers(0, CFG.vocab_size, 40).astype(np.int32)
    mk = lambda rid, head: Request(
        req_id=rid,
        prompt=np.concatenate(
            [head, rng.integers(0, CFG.vocab_size, 4).astype(np.int32)]),
        max_new_tokens=4)
    rt = ClusterRuntime(spec, prefill_chunk=CHUNK)
    rt.start()
    try:
        # rA → D0 (deterministic tiebreak), rB → D1 (load): each D now
        # holds one distinct prefix
        rt.serve([mk("rA", head_a), mk("rB", head_b)], max_wall_s=120.0)
        assert dict(rt.stats.d_dispatches) == {"D0": 1, "D1": 1}
        rB_d = "D1"                            # idle tiebreak sent rA to D0
        # wait until rB's D advertises its prefix digests via heartbeat
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            rt.step(timeout=0.05)
            inst = rt._instances.get(rB_d)
            if inst is not None and inst.prefix_hashes:
                break
        assert rt._instances[rB_d].prefix_hashes
        # a third request sharing rB's prefix must follow it to rB's D,
        # although both Ds are idle and load-ordering would pick D0
        rt.serve([mk("rA2", head_b)], max_wall_s=120.0)
        assert rt.stats.d_dispatches[rB_d] == 2
    finally:
        rt.shutdown()
    # the affinity hit was real: rA2's shared prefix skipped the wire
    assert rt.transfer_stats.prefix_hit_tokens >= 40


# --------------------------------------------------------------------- #
# 6. P-side host store
# --------------------------------------------------------------------- #
def test_host_store_byte_lru_evicts_under_capacity():
    entries = lambda: [("kv", 0, 0, {"k": np.ones((1, 8, 4), np.float32),
                                     "v": np.ones((1, 8, 4), np.float32),
                                     "start": 0})]
    one_block = 2 * 8 * 4 * 4                  # k+v bytes per 8-token block
    store = HostPrefixStore(block_size=8, capacity_bytes=2 * one_block)
    p1 = np.arange(8, dtype=np.int32)
    p2 = np.arange(8, 16, dtype=np.int32)
    p3 = np.arange(16, 24, dtype=np.int32)
    assert store.insert_prompt(p1, entries(), 8) == 1
    assert store.insert_prompt(p2, entries(), 8) == 1
    assert store.nbytes == 2 * one_block
    store.match(p2, 8)                         # touch p2: p1 becomes LRU
    assert store.insert_prompt(p3, entries(), 8) == 1
    hit, _ = store.match(p1, 8)
    assert hit == 0                            # p1 evicted
    hit, _ = store.match(p2, 8)
    assert hit == 8                            # p2 survived (recently used)


# --------------------------------------------------------------------- #
# 7. planner model honesty: assumed hit ratio must be a valid fraction
# --------------------------------------------------------------------- #
def test_framework_model_prefix_cache_hit_validated():
    """``prefix_cache_hit`` is a fraction of prompt tokens served from
    the cache; 1.0 would claim zero prefill compute (at least the final
    token is always computed), so the valid range is [0, 1)."""
    from repro.core.planner.simulator import FrameworkModel

    assert FrameworkModel().prefix_cache_hit == 0.0
    assert FrameworkModel(prefix_cache_hit=0.5).prefix_cache_hit == 0.5
    for bad in (1.0, -0.1, 2.0):
        with pytest.raises(ValueError, match="prefix_cache_hit"):
            FrameworkModel(prefix_cache_hit=bad)
