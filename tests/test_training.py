"""Training substrate: optimizer semantics, loss descent, microbatch
equivalence, checkpoint lifecycle, gradient compression properties."""
import os
import tempfile

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

import jax
import jax.numpy as jnp

from repro.training.checkpoint import CheckpointManager
from repro.training.compression import (dequantize_int8,
                                        error_feedback_compress,
                                        quantize_int8)
from repro.training.data import DataConfig, SyntheticTokens
from repro.training.optim import AdamWConfig, adamw_init, adamw_update, \
    global_norm, schedule
from repro.training.train_step import make_train_step, train_state_init
from tests.conftest import tiny

CFG = tiny("train", num_layers=2, vocab_size=256)
OPT = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)


def _batches(n, bs=8, seq=32, seed=1):
    it = iter(SyntheticTokens(CFG, DataConfig(batch_size=bs, seq_len=seq,
                                              seed=seed)))
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def test_loss_decreases_over_30_steps():
    state = train_state_init(jax.random.key(0), CFG)
    step = jax.jit(make_train_step(CFG, OPT))
    losses = []
    for batch in _batches(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])
    assert all(np.isfinite(losses))


def test_microbatched_step_matches_full_batch_grad_direction():
    """n_micro=4 must track n_micro=1 closely (bf16 accumulation noise)."""
    state0 = train_state_init(jax.random.key(0), CFG)
    batch = _batches(1, bs=8)[0]
    s1, m1 = jax.jit(make_train_step(CFG, OPT))(state0, batch)
    state0b = train_state_init(jax.random.key(0), CFG)
    s4, m4 = jax.jit(make_train_step(CFG, OPT, n_micro=4))(state0b, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-2)
    # updated params nearly identical
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_adamw_moves_against_gradient():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([1.0, -1.0, 0.0])}
    st_ = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                      grad_clip=0.0)
    p2, st2, m = adamw_update(cfg, params, grads, st_)
    assert p2["w"][0] < params["w"][0]
    assert p2["w"][1] > params["w"][1]
    assert p2["w"][2] == params["w"][2]
    assert int(st2["step"]) == 1


def test_grad_clip_bounds_update():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 1e6)}
    st_ = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, grad_clip=1.0,
                      weight_decay=0.0)
    _, _, m = adamw_update(cfg, params, grads, st_)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_checkpoint_roundtrip_gc_and_meta():
    state = train_state_init(jax.random.key(0), CFG)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3):
            mgr.save(s, state, meta={"step": s})
        mgr.wait()
        assert mgr.all_steps() == [2, 3]
        assert mgr.latest_step() == 3
        restored = mgr.restore(3, like=jax.eval_shape(lambda: state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert mgr.restore_meta(3) == {"step": 3}


def test_checkpoint_resume_training_continues():
    """Restart from a checkpoint: training continues without loss spike."""
    state = train_state_init(jax.random.key(0), CFG)
    step = jax.jit(make_train_step(CFG, OPT))
    batches = _batches(14)
    for b in batches[:10]:
        state, m = step(state, b)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_write=False)
        mgr.save(10, state)
        fresh = mgr.restore(10, like=jax.eval_shape(lambda: state))
    l_cont, l_restored = [], []
    s2 = fresh
    for b in batches[10:]:
        state, m1 = step(state, b)
        s2, m2 = step(s2, b)
        l_cont.append(float(m1["loss"]))
        l_restored.append(float(m2["loss"]))
    np.testing.assert_allclose(l_cont, l_restored, rtol=1e-6)


def test_data_pipeline_determinism_and_learnability():
    a = list(zip(range(3), SyntheticTokens(CFG, DataConfig(seed=3))))
    b = list(zip(range(3), SyntheticTokens(CFG, DataConfig(seed=3))))
    for (_, x), (_, y) in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])
    assert x["tokens"].max() < CFG.vocab_size


@given(scale=st.floats(1e-3, 1e3))
def test_int8_quantize_bound(scale):
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                    jnp.float32) * scale
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) * 0.5001 + 1e-9


def test_error_feedback_residual_shrinks_bias():
    """With EF, the accumulated compressed signal tracks the true sum."""
    rng = np.random.default_rng(5)
    true_sum = np.zeros(32, np.float32)
    ef_sum = np.zeros(32, np.float32)
    resid = jnp.zeros(32)
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=32), jnp.float32)
        true_sum += np.asarray(g)
        q, s, resid = error_feedback_compress(g, resid)
        ef_sum += np.asarray(dequantize_int8(q, s))
    # residual carries the error: total drift bounded by one quant step
    drift = np.abs(ef_sum + np.asarray(resid) - true_sum).max()
    assert drift < 1e-3
