"""Model-builder behaviour: train forward, prefill+decode parity with the
full forward, loss masking — for every cache family."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import model as M


def _batch(cfg, rng, b=2, s=12):
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    if cfg.is_enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, 10, cfg.d_model)), jnp.float32)
    if cfg.frontend.kind == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend.num_patches, cfg.d_model)),
            jnp.float32)
    return batch


def test_train_forward_and_loss(family_cfg, rng):
    cfg = family_cfg
    params = M.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, rng)
    logits = M.train_forward(params, cfg, batch, remat=False)
    s = batch["tokens"].shape[1]
    if cfg.frontend.kind == "vision":
        s += cfg.frontend.num_patches
    assert logits.shape == (2, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    loss = M.loss_fn(params, cfg, batch, remat=False)
    assert np.isfinite(float(loss))
    assert 0.0 < float(loss) < 2 * np.log(cfg.vocab_size)


def test_remat_does_not_change_loss(family_cfg, rng):
    cfg = family_cfg
    params = M.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, rng)
    l1 = float(M.loss_fn(params, cfg, batch, remat=False))
    l2 = float(M.loss_fn(params, cfg, batch, remat=True))
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_prefill_decode_matches_full_forward(family_cfg, rng):
    """Greedy decode through caches == slicing the teacher-forced forward."""
    cfg = family_cfg
    params = M.init_params(jax.random.key(0), cfg)
    b, s_prompt, n_new = 2, 9, 4
    toks = rng.integers(0, cfg.vocab_size, (b, s_prompt + n_new)
                        ).astype(np.int32)
    inputs = {"tokens": jnp.asarray(toks[:, :s_prompt])}
    full_batch = {"tokens": jnp.asarray(toks)}
    offset = 0
    if cfg.is_enc_dec:
        fr = jnp.asarray(rng.normal(size=(b, 10, cfg.d_model)), jnp.float32)
        inputs["frames"] = fr
        full_batch["frames"] = fr
    if cfg.frontend.kind == "vision":
        pt = jnp.asarray(rng.normal(
            size=(b, cfg.frontend.num_patches, cfg.d_model)), jnp.float32)
        inputs["patches"] = pt
        full_batch["patches"] = pt
        offset = cfg.frontend.num_patches
    full = M.train_forward(params, cfg, full_batch, remat=False)

    caches = M.init_caches(cfg, b, s_prompt + n_new + offset,
                           jnp.float32, mem_len=10)
    last, caches = M.prefill(params, cfg, inputs, caches)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, offset + s_prompt - 1]),
                               atol=2e-4)
    for t in range(n_new - 1):
        pos = jnp.full((b, 1), offset + s_prompt + t, jnp.int32)
        logits, caches = M.decode_step(params, cfg,
                                       jnp.asarray(toks[:, s_prompt + t:
                                                        s_prompt + t + 1]),
                                       pos, caches)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]),
            np.asarray(full[:, offset + s_prompt + t]), atol=2e-4)


def test_loss_ignores_negative_labels(rng):
    from tests.conftest import tiny
    cfg = tiny("mask")
    params = M.init_params(jax.random.key(0), cfg)
    toks = rng.integers(0, cfg.vocab_size, (2, 13)).astype(np.int32)
    lab = toks[:, 1:].copy()
    batch_full = {"tokens": jnp.asarray(toks[:, :-1]),
                  "labels": jnp.asarray(lab)}
    lab_mask = lab.copy()
    lab_mask[:, 8:] = -1
    batch_mask = {"tokens": jnp.asarray(toks[:, :-1]),
                  "labels": jnp.asarray(lab_mask)}
    l_full = float(M.loss_fn(params, cfg, batch_full, remat=False))
    l_mask = float(M.loss_fn(params, cfg, batch_mask, remat=False))
    assert l_full != pytest.approx(l_mask)
    # masked loss equals loss over the first 8 positions only
    lp = M.train_forward(params, cfg, batch_mask, remat=False)
    lp = jax.nn.log_softmax(lp.astype(jnp.float32), axis=-1)
    nll = -np.take_along_axis(np.asarray(lp[:, :8]),
                              lab[:, :8, None], axis=-1).mean()
    np.testing.assert_allclose(l_mask, nll, rtol=1e-5)


def test_abstract_params_match_real(family_cfg):
    cfg = family_cfg
    abs_p = M.abstract_params(cfg)
    real = M.init_params(jax.random.key(0), cfg)
    ab, rb = jax.tree.leaves(abs_p), jax.tree.leaves(real)
    assert len(ab) == len(rb)
    for a, r in zip(ab, rb):
        assert a.shape == r.shape and a.dtype == r.dtype
