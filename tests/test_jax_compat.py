"""The JAX version-compat shim: both symbol homes must resolve on the
installed JAX, and the shimmed ``shard_map`` must accept either name of the
replication-check kwarg."""
import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import _jax_compat as compat


def test_compiler_params_resolves_to_installed_class():
    expected = getattr(pltpu, "CompilerParams",
                       getattr(pltpu, "TPUCompilerParams", None))
    assert expected is not None
    assert compat.CompilerParams is expected
    # constructible with the field the kernels pass
    cp = compat.CompilerParams(
        dimension_semantics=("parallel", "arbitrary"))
    assert cp is not None


def test_kernels_import_and_carry_shim():
    from repro.kernels import flash_attention, paged_attention
    assert flash_attention.CompilerParams is compat.CompilerParams
    assert paged_attention.CompilerParams is compat.CompilerParams


def test_shard_map_resolves_and_normalizes_kwargs():
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
    x = jnp.arange(8, dtype=jnp.float32)

    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        f = compat.shard_map(lambda a: a * 2, mesh=mesh,
                             in_specs=(P(),), out_specs=P(), **kw)
        np.testing.assert_array_equal(np.asarray(f(x)),
                                      np.arange(8, dtype=np.float32) * 2)


def test_moe_shard_map_layer_uses_shim():
    """models.layers must route through the shim (the `from jax import
    shard_map` form breaks on JAX 0.4.x)."""
    import inspect

    from repro.models import layers as L
    src = inspect.getsource(L._moe_mlp_shard_map)
    assert "_jax_compat import shard_map" in src
