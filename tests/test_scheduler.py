"""Global scheduler: load-aware routing, fault tolerance (failed D →
re-prefill with prefix), straggler penalty, elastic scale-down, and the
no-lost-request invariant."""
import numpy as np
import pytest

import jax

from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request, State
from repro.serving.scheduler import GlobalScheduler
from tests.conftest import TINY_FAMILIES

CFG = TINY_FAMILIES["dense"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.key(1), CFG)


def _engine(name, params, role, **kw):
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq_len", 64)
    return Engine(name, CFG, params, VendorProfile("A", block_size=8),
                  role=role, **kw)


def _sched(*engines):
    sched = GlobalScheduler(DisaggPipeline(TransferEngine(),
                                           WireFormat("raw", "float32")))
    for e in engines:
        sched.add_instance(e)
    return sched


def _reqs(n, seed=0, max_new=5):
    rng = np.random.default_rng(seed)
    return [Request(req_id=f"q{i}",
                    prompt=rng.integers(0, CFG.vocab_size, 8).astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def test_all_requests_finish_and_route_across_pool(params):
    p = [_engine(f"P{i}", params, "prefill") for i in range(2)]
    d = [_engine(f"D{i}", params, "decode") for i in range(3)]
    sched = _sched(*(p + d))
    reqs = _reqs(12)
    done = sched.run(reqs, max_ticks=500)
    assert len(done) == 12
    assert all(r.state == State.FINISHED for r in reqs)
    assert sum(sched.stats.p_dispatches.values()) == 12
    # load-aware routing should spread decode work
    assert len([k for k, v in sched.stats.d_dispatches.items() if v > 0]) >= 2


def test_decode_failure_requeues_and_finishes(params):
    """Kill a D instance mid-decode: its KV is lost; the scheduler must
    re-prefill (prefix preserved) and still deliver max_new_tokens."""
    p = _engine("P0", params, "prefill")
    d = _engine("D0", params, "decode")
    sched = _sched(p, d)
    reqs = _reqs(3, max_new=8)
    for r in reqs:
        sched.submit(r)
    for _ in range(3):
        sched.step()
    d.fail()                                  # node dies, volatile KV gone
    for _ in range(200):
        if sched.stats.finished >= 3:
            break
        sched.step()
    assert sched.stats.finished == 3
    assert sched.stats.requeues >= 1
    for r in reqs:
        assert len(r.output_tokens) == 8
        assert r.retries >= 0


def test_prefill_failure_falls_back(params):
    p0 = _engine("P0", params, "prefill")
    p1 = _engine("P1", params, "prefill")
    d = _engine("D0", params, "decode")
    sched = _sched(p0, p1, d)
    p0.fail()
    reqs = _reqs(4)
    done = sched.run(reqs, max_ticks=400)
    assert len(done) == 4
    assert sched.stats.p_dispatches.get("P0", 0) == 0
    assert sched.stats.p_dispatches["P1"] == 4


def test_elastic_drain_stops_new_work(params):
    p = _engine("P0", params, "prefill")
    d0 = _engine("D0", params, "decode")
    d1 = _engine("D1", params, "decode")
    sched = _sched(p, d0, d1)
    sched.remove_instance("D1")               # drain: no new routing
    reqs = _reqs(6)
    done = sched.run(reqs, max_ticks=500)
    assert len(done) == 6
    assert sched.stats.d_dispatches.get("D1", 0) == 0


def test_straggler_penalty_prefers_fast_instance(params):
    p = _engine("P0", params, "prefill")
    d0 = _engine("D0", params, "decode")
    d1 = _engine("D1", params, "decode")
    sched = _sched(p, d0, d1)
    # mark D0 as a 100× straggler via the latency EMA
    sched._ema["D0"] = 1.0
    sched._ema["D1"] = 0.01
    reqs = _reqs(4)
    sched.run(reqs, max_ticks=400)
    assert sched.stats.d_dispatches.get("D1", 0) \
        > sched.stats.d_dispatches.get("D0", 0)


def test_admission_respects_capacity(params):
    """A D pool too small for the request must not admit it."""
    d = _engine("D0", params, "decode", num_blocks=4, max_seq_len=16)
    assert not d.can_admit(seq_len=12, new_tokens=30)
    assert d.can_admit(seq_len=4, new_tokens=4)


def test_engine_stats_accumulate(params):
    p = _engine("P0", params, "prefill")
    d = _engine("D0", params, "decode")
    sched = _sched(p, d)
    sched.run(_reqs(2), max_ticks=200)
    assert p.stats.prefill_tokens > 0
    assert d.stats.decode_tokens > 0
    assert d.stats.decode_seconds > 0
