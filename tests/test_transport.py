"""Pluggable KV-transport connectors: shared conformance suite across all
three backends (inproc / shm / rdma), connector failure paths, async
multi-tick completion, and bit-identical streamed handoff per backend.

Every backend must honor the same contract:

  stage → issue_read → (poll | wait) → complete      happy path
  stage → issue_read → drop → wait                   raises TransferError
  issue_read of an unknown key                       raises KeyError
  pool exhaustion under concurrent flights           raises MemoryError,
                                                     recoverable after
                                                     complete()
"""
import pickle
from multiprocessing import shared_memory

import numpy as np
import pytest

import jax

from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.transport import (CONNECTORS, InProcessConnector,
                                  ModeledRDMAConnector, PinnedBufferPool,
                                  SharedMemoryConnector, TransferError,
                                  make_connector)
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler
from tests.conftest import TINY_FAMILIES

BACKENDS = sorted(CONNECTORS)          # ["inproc", "rdma", "shm"]


def _mk(kind: str, **kw):
    return make_connector(kind, **kw)


@pytest.fixture(params=BACKENDS)
def conn(request):
    c = _mk(request.param)
    yield c
    c.close()


def _payload(n=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"k": rng.normal(size=(n, 2, 4)).astype(np.float32),
            "scales": rng.normal(size=(n,)).astype(np.float32)}


# --------------------------------------------------------------------- #
# conformance: lifecycle
# --------------------------------------------------------------------- #
def test_lifecycle_stage_issue_poll_wait_complete(conn):
    pay = _payload()
    n = conn.stage("r0@P0", pay, {"seq": 8})
    assert n > 0
    assert conn.staged_keys() == ["r0@P0"]
    h = conn.issue_read("r0@P0")
    assert h.nbytes == n
    assert conn.inflight_reads() == 1
    # wait() force-completes even when poll() is still False (modeled wire)
    got, meta = h.wait()
    assert h.poll()
    assert conn.inflight_reads() == 0
    np.testing.assert_array_equal(got["k"], pay["k"])
    np.testing.assert_array_equal(got["scales"], pay["scales"])
    assert meta == {"seq": 8}
    # wait() is idempotent — cached result, no double accounting
    got2, _ = h.wait()
    np.testing.assert_array_equal(got2["k"], pay["k"])
    assert conn.stats.transfers == 1
    assert conn.stats.bytes_moved == n
    conn.complete("r0@P0")
    assert conn.staged_keys() == []
    assert conn.pool.in_use == 0
    conn.complete("r0@P0")             # idempotent: no over-release


def test_capabilities_descriptor(conn):
    caps = conn.capabilities()
    assert caps.transport == conn.transport
    assert caps.bandwidth_gbps > 0
    assert caps.fixed_latency_s >= 0
    assert caps.max_inflight >= 1
    assert caps.chunk_bytes >= 0
    assert caps.wire_seconds(0) == 0.0
    assert caps.wire_seconds(2 * 10 ** 6) > caps.wire_seconds(10 ** 6)
    assert conn.modeled_latency(10 ** 6) == caps.wire_seconds(10 ** 6)


def test_register_peers(conn):
    conn.register("P0", role="prefill")
    conn.register("D0", role="decode")
    conn.register("P0", role="prefill")       # idempotent
    assert conn.peers() == ["D0", "P0"]


def test_issue_read_unknown_key_raises(conn):
    with pytest.raises(KeyError):
        conn.issue_read("nope")


def test_duplicate_stage_raises(conn):
    conn.stage("k", _payload())
    with pytest.raises(ValueError, match="already staged"):
        conn.stage("k", _payload())


# --------------------------------------------------------------------- #
# conformance: failure paths
# --------------------------------------------------------------------- #
def test_wait_after_drop_raises(conn):
    conn.stage("k1", _payload())
    h = conn.issue_read("k1")
    conn.drop("k1")
    assert conn.pool.in_use == 0              # buffer freed on drop
    with pytest.raises(TransferError, match="lost mid-stream"):
        h.wait()
    assert conn.inflight_reads() == 0         # failed read frees the channel


def test_key_lost_mid_stream_second_reader(conn):
    """A key dropped while another handle is in flight fails that handle
    but leaves the connector healthy for the next transfer."""
    conn.stage("gone", _payload(seed=1))
    h = conn.issue_read("gone")
    conn.drop("gone")
    with pytest.raises(TransferError):
        h.wait()
    n = conn.stage("next", _payload(seed=2))
    got, _ = conn.issue_read("next").wait()
    np.testing.assert_array_equal(got["k"], _payload(seed=2)["k"])
    conn.complete("next")
    assert conn.pool.in_use == 0
    assert n > 0


def test_cancel_frees_channel_slot(conn):
    conn.stage("c", _payload())
    h = conn.issue_read("c")
    assert conn.inflight_reads() == 1
    h.cancel()
    assert conn.inflight_reads() == 0
    with pytest.raises(TransferError):
        h.wait()
    conn.drop("c")
    # stats account delivered reads only — a cancelled read moved nothing
    assert conn.stats.transfers == 0
    assert conn.stats.bytes_moved == 0
    assert conn.stats.modeled_seconds == 0.0


def test_max_inflight_enforced():
    for kind in BACKENDS:
        c = _mk(kind, max_inflight=2)
        for i in range(2):
            c.stage(f"k{i}", _payload(seed=i))
        h0 = c.issue_read("k0")
        c.issue_read("k1")
        with pytest.raises(TransferError, match="channel full"):
            c.issue_read("k0")
        h0.wait()                              # settles → slot frees
        c.issue_read("k0")
        c.close()


@pytest.mark.parametrize("kind", BACKENDS)
def test_pool_exhaustion_under_concurrent_flights(kind):
    """Staging footprints of concurrent flights share one pinned pool:
    enough flights exhaust it (MemoryError), completing one admits the
    next — and accounting returns to zero at the end."""
    probe = _mk(kind)
    per_entry = probe.stage("probe", _payload())
    probe.close()

    conn = _mk(kind, buffer_capacity_bytes=int(per_entry * 2.5))
    conn.stage("f0", _payload(seed=0))
    conn.stage("f1", _payload(seed=1))
    h0 = conn.issue_read("f0")
    h1 = conn.issue_read("f1")
    with pytest.raises(MemoryError):
        conn.stage("f2", _payload(seed=2))     # third concurrent flight
    # drain one flight → capacity for the third
    h0.wait()
    conn.complete("f0")
    conn.stage("f2", _payload(seed=2))
    h1.wait()
    conn.issue_read("f2").wait()
    conn.complete("f1")
    conn.complete("f2")
    assert conn.pool.in_use == 0
    assert conn.pool.high_water <= per_entry * 2.5
    conn.close()


def test_pinned_pool_over_release_raises():
    pool = PinnedBufferPool(100)
    pool.acquire(40)
    pool.release(40)
    with pytest.raises(ValueError, match="over-release"):
        pool.release(1)
    pool.acquire(30)
    with pytest.raises(ValueError, match="over-release"):
        pool.release(31)
    assert pool.in_use == 30                  # failed release left state


# --------------------------------------------------------------------- #
# backend specifics
# --------------------------------------------------------------------- #
def test_shm_segment_readable_by_name():
    """The staged entry really lives in an OS shared-memory segment: a
    fresh attach by name (what another process would do) deserializes to
    the staged payload."""
    conn = SharedMemoryConnector()
    pay = _payload(seed=7)
    n = conn.stage("x", pay, {"m": 3})
    seg = shared_memory.SharedMemory(name=conn.segment_name("x"))
    try:
        got, meta = pickle.loads(bytes(seg.buf[:n]))
    finally:
        seg.close()
    np.testing.assert_array_equal(got["k"], pay["k"])
    assert meta == {"m": 3}
    conn.complete("x")
    conn.close()


def test_rdma_handle_completes_over_multiple_ticks():
    """fixed_latency 1s, 0.6s of wire progress per tick → ready on the
    second tick; wait() before that fast-forwards instead of hanging."""
    conn = ModeledRDMAConnector(fixed_latency_s=1.0, tick_seconds=0.6,
                                bandwidth_gbps=1e9)
    conn.stage("a", _payload())
    h = conn.issue_read("a")
    assert not h.poll()
    conn.tick()
    assert not h.poll()
    conn.tick()
    assert h.poll()
    h.wait()
    conn.complete("a")

    # forced-sync path: no ticks at all — wait() fast-forwards the clock
    conn.stage("b", _payload(seed=1))
    h2 = conn.issue_read("b")
    assert not h2.poll()
    h2.wait()
    assert h2.poll()
    conn.complete("b")
    conn.close()


def test_rdma_serializes_reads_on_the_link():
    """In ``link_sharing="serial"`` mode two reads issued back-to-back
    share the wire exclusively: the second becomes ready only after the
    first's wire time has elapsed."""
    conn = ModeledRDMAConnector(fixed_latency_s=0.5, tick_seconds=0.6,
                                bandwidth_gbps=1e9, link_sharing="serial")
    conn.stage("a", _payload(seed=0))
    conn.stage("b", _payload(seed=1))
    ha = conn.issue_read("a")
    hb = conn.issue_read("b")
    conn.tick()                                # t=0.6: a ready, b not
    assert ha.poll() and not hb.poll()
    conn.tick()                                # t=1.2: b ready (0.5+0.5)
    assert hb.poll()
    conn.close()


def test_inproc_zero_copy_and_instant():
    conn = InProcessConnector(bandwidth_gbps=10.0)
    pay = _payload()
    n = conn.stage("z", pay)
    h = conn.issue_read("z")
    assert h.poll()                            # instant completion
    got, _ = h.wait()
    assert got["k"] is pay["k"]                # zero-copy: same buffer
    assert conn.stats.modeled_seconds == pytest.approx(n / 10e9)
    conn.close()


# --------------------------------------------------------------------- #
# streamed handoff conformance: bit-identical D pools per backend × wire
# --------------------------------------------------------------------- #
WIRES = [WireFormat("raw", "float32"), WireFormat("raw", "bfloat16"),
         WireFormat("int8")]


def _req(cfg, plen, rid="r0", max_new=4, seed=3):
    rng = np.random.default_rng(seed)
    return Request(req_id=rid,
                   prompt=rng.integers(0, cfg.vocab_size,
                                       plen).astype(np.int32),
                   max_new_tokens=max_new)


def _pair(cfg, params, vd):
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    p = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
               max_seq_len=64, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, role="decode")
    return p, d


@pytest.mark.parametrize("kind", BACKENDS)
@pytest.mark.parametrize("wire", WIRES, ids=lambda w: f"{w.kind}-{w.dtype}")
def test_streamed_handoff_bitwise_equals_monolithic_per_backend(kind, wire):
    """Acceptance: over every connector backend, the streamed chunked wire
    (chunk 5, straddling the D vendor's 4-token blocks → RMW re-paging)
    lands D pools bit-identical to the monolithic wire."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    req = _req(cfg, plen=13)

    p1, d_mono = _pair(cfg, params, vd)
    pipe1 = DisaggPipeline(_mk(kind), wire)
    pipe1.handoff(req, p1, d_mono)

    p2, d_stream = _pair(cfg, params, vd)
    pipe2 = DisaggPipeline(_mk(kind), wire)
    meta = pipe2.handoff_streamed(req, p2, d_stream, chunk_tokens=5,
                                  chunked_compute=False)
    assert meta["chunks"] == 3                     # ceil(13 / 5)
    assert meta["first_token"] == int(d_mono.last_token[0])

    for a, b in zip(jax.tree.leaves(d_mono.caches),
                    jax.tree.leaves(d_stream.caches)):
        assert a.dtype == b.dtype
        assert bool(jax.numpy.array_equal(a, b)), kind
    np.testing.assert_array_equal(d_mono.block_tables, d_stream.block_tables)
    np.testing.assert_array_equal(d_mono.seq_lens, d_stream.seq_lens)
    assert d_mono.decode_step()[0][2] == d_stream.decode_step()[0][2]
    assert pipe1.transfer.pool.in_use == 0
    assert pipe2.transfer.pool.in_use == 0
    pipe1.transfer.close()
    pipe2.transfer.close()


# --------------------------------------------------------------------- #
# scheduler: decode runs while a chunk's wire transfer is in flight
# --------------------------------------------------------------------- #
def test_decode_step_runs_while_chunk_wire_in_flight():
    """Acceptance: with ModeledRDMAConnector, handles span scheduler ticks
    (fixed latency 1s, 0.45s of wire progress per tick → ~3 ticks per
    chunk). A short request decodes in ticks where the long request's
    chunk read is still on the wire — wire time and D-side re-page live in
    separate tick budgets."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    p0 = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    p1 = Engine("P1", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, role="decode")
    conn = ModeledRDMAConnector(fixed_latency_s=1.0, tick_seconds=0.45,
                                bandwidth_gbps=1e9)
    pipe = DisaggPipeline(conn, WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1,
                            repage_budget=1)
    for e in (p0, p1, d):
        sched.add_instance(e)

    short_req = _req(cfg, plen=8, rid="short", max_new=10, seed=12)
    long_req = _req(cfg, plen=24, rid="long", max_new=3, seed=11)
    sched.submit(short_req)
    sched.submit(long_req)

    decoded_during_inflight_wire = 0
    for _ in range(200):
        emitted = sched.step()
        short_decoded = any(r is short_req for r, _tok in emitted)
        wire_busy = any(fl.handoff.pending_reads() > 0
                        for fl in sched.inflight)
        if short_decoded and wire_busy:
            decoded_during_inflight_wire += 1
        if sched.stats.finished == 2:
            break

    assert sched.stats.finished == 2
    assert len(short_req.output_tokens) == 10
    assert len(long_req.output_tokens) == 3
    # the async wire kept chunks in flight across ticks while decode ran
    assert decoded_during_inflight_wire >= 3
    assert conn.stats.chunks == 2 + 6          # ceil(8/4) + ceil(24/4)
    conn.close()


def test_concurrent_flights_throttle_on_shared_channel():
    """max_inflight=1 on a slow modeled wire: two flights share the single
    read slot. can_send() checks the connector's *global* in-flight count,
    so the second flight throttles (waits its turn) instead of hitting the
    channel-full error and aborting — every request finishes with zero
    requeues."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    p0 = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    p1 = Engine("P1", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, role="decode")
    conn = ModeledRDMAConnector(fixed_latency_s=1.0, tick_seconds=0.6,
                                bandwidth_gbps=1e9, max_inflight=1)
    pipe = DisaggPipeline(conn, WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1)
    for e in (p0, p1, d):
        sched.add_instance(e)
    reqs = [_req(cfg, plen=12, rid=f"q{i}", max_new=3, seed=i)
            for i in range(2)]
    done = sched.run(reqs, max_ticks=400)
    assert len(done) == 2
    assert sched.stats.requeues == 0
    assert all(len(r.output_tokens) == 3 for r in reqs)
    conn.close()


def test_planner_sources_wire_model_from_capabilities():
    """The planner's communication operator library consumes the
    connector's capabilities() descriptor instead of a bare bandwidth
    constant: fixed per-read latency is additive, and streaming chunk size
    honors the declared wire granularity."""
    from repro.core.planner.simulator import (connector_chunk_tokens,
                                              connector_wire_time)
    flat = InProcessConnector(bandwidth_gbps=25.0).capabilities()
    nbytes = 1e9
    assert connector_wire_time(nbytes, flat) == pytest.approx(nbytes / 25e9)

    rdma = ModeledRDMAConnector(bandwidth_gbps=25.0, fixed_latency_s=1e-3,
                                chunk_bytes=1 << 20).capabilities()
    assert connector_wire_time(nbytes, rdma) == \
        pytest.approx(nbytes / 25e9 + 1e-3)
    assert connector_wire_time(0, rdma) == 0.0

    # granularity: 1 MiB preferred chunks at 2 KiB/token → 512-token chunks
    assert connector_chunk_tokens(rdma, 2048) == 512
    # no preference declared → caller's default stands
    assert connector_chunk_tokens(flat, 2048, default=128) == 128
    assert connector_chunk_tokens(None, 2048, default=128) == 128
    # granularity below one token's wire bytes must not degenerate to
    # 1-token chunks — fall back to the default regime
    assert connector_chunk_tokens(rdma, (1 << 20) + 1, default=64) == 64


def test_scheduler_requeue_increments_transfer_retries():
    """Failure accounting is wire-visible: every scheduler requeue charges
    TransferStats.retries (satellite: the field existed but was never
    incremented)."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    p, d = _pair(cfg, params, vd)
    conn = InProcessConnector(buffer_capacity_bytes=64)   # chunk never fits
    pipe = DisaggPipeline(conn, WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, max_retries=3)
    sched.add_instance(p)
    sched.add_instance(d)
    sched.submit(_req(cfg, plen=16, rid="big", max_new=2))
    for _ in range(10):
        sched.step()
    assert sched.stats.requeues == 3
    assert conn.stats.retries == 3
    assert conn.stats.retries == sched.stats.requeues
