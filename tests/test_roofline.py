"""Roofline analysis unit tests: HLO collective parsing, ring-model wire
bytes, probe-plan algebra, MODEL_FLOPS."""
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.configs.base import get_config
from repro.roofline import analysis as RA

HLO = """
HloModule jit_step

%fused_computation.1 (p0: f32[128,256]) -> f32[128,256] {
  %c = f32[128,256]{1,0} convert(%p0)
  ROOT %r = f32[128,256]{1,0} add(%c, %c)
}

ENTRY %main () -> f32[16,1024] {
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[16,1024]{1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%sum
  %rs = f32[4,1024]{1,0} reduce-scatter(%z), replica_groups=[64,4]<=[256], dimensions={0}
  %cv = f32[1024,512]{1,0} convert(%w)
  %tup = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b), replica_groups=[32,8]<=[256]
  ROOT %out = f32[16,1024]{1,0} copy(%ar)
}
"""


def test_collective_bytes_parses_all_kinds():
    total, by_kind = RA.collective_bytes(HLO, default_group=16)
    # all-gather: out 16*1024*2 B, group 16 → 15/16 × 32768
    ag = 15 / 16 * 16 * 1024 * 2
    # all-reduce: out 16*1024*4, group 4 → 2×3/4 × 65536
    ar = 2 * 3 / 4 * 16 * 1024 * 4
    # reduce-scatter: out 4*1024*4, group 4 → 3 × 16384
    rs = 3 * 4 * 1024 * 4
    # all-to-all: tuple outputs 2×(8*8*4), group 8 → 7/8 × 512
    a2a = 7 / 8 * 2 * 8 * 8 * 4
    assert by_kind["all-gather"] == pytest.approx(ag)
    assert by_kind["all-reduce"] == pytest.approx(ar)
    assert by_kind["reduce-scatter"] == pytest.approx(rs)
    assert by_kind["all-to-all"] == pytest.approx(a2a)
    assert total == pytest.approx(ag + ar + rs + a2a)


def test_convert_bytes_skips_fusions():
    # only the ENTRY-level convert counts: 1024*512 elems × 6 B
    assert RA.convert_emulation_bytes(HLO) == 1024 * 512 * 6


def test_terms_seconds_and_dominant():
    t = RA.RooflineTerms(flops=197e12, hbm_bytes=819e9 * 3,
                         wire_bytes=50e9 * 0.5, convert_bytes=819e9)
    s = t.seconds()
    assert s["compute"] == pytest.approx(1.0)
    assert s["memory"] == pytest.approx(2.0)       # corrected: 3-1
    assert s["memory_raw"] == pytest.approx(3.0)
    assert s["collective"] == pytest.approx(0.5)
    assert t.dominant() == "memory"
    assert t.step_time() == pytest.approx(2.0)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_probe_plan_reconstructs_layer_counts(arch):
    """Σ coeff·layers(probe) must equal the full model's layer count —
    the linear-extrapolation identity the roofline rests on."""
    cfg = get_config(arch)
    plan = RA.probe_plan(arch)
    total_dec = sum(c * o.get("num_layers", cfg.num_layers)
                    for o, c in plan)
    assert total_dec == pytest.approx(cfg.num_layers), arch
    if cfg.is_enc_dec:
        total_enc = sum(c * o.get("encoder_layers", cfg.encoder_layers)
                        for o, c in plan)
        assert total_enc == pytest.approx(cfg.encoder_layers)
    # the fixed (non-layer) cost must appear exactly once
    assert sum(c for _, c in plan) == pytest.approx(1.0)


def test_model_flops_moe_uses_active_params():
    dense = RA.model_flops("qwen3-4b", "train", 1000)
    cfg = get_config("qwen3-4b")
    assert dense == pytest.approx(6.0 * cfg.param_count() * 1000)
    moe_cfg = get_config("mixtral-8x7b")
    moe = RA.model_flops("mixtral-8x7b", "decode", 10)
    assert moe == pytest.approx(2.0 * moe_cfg.active_param_count() * 10)
    assert moe_cfg.active_param_count() < 0.4 * moe_cfg.param_count()


def test_combine_linearity():
    a = RA.RooflineTerms(flops=1.0, hbm_bytes=2.0, wire_bytes=3.0,
                         convert_bytes=0.5, by_kind={"all-reduce": 3.0})
    z = RA.RooflineTerms()
    z = z.combine(a, 2.0).combine(a, -0.5)
    assert z.flops == pytest.approx(1.5)
    assert z.hbm_bytes == pytest.approx(3.0)
    assert z.by_kind["all-reduce"] == pytest.approx(4.5)
