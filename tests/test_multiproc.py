"""Multi-instance P/D serving runtime (repro.serving.multiproc).

The acceptance bar for genuine disaggregation:

  1. *parity*: the multi-process runtime (P and D engines in separate OS
     processes, control plane over queues, KV data plane over shared
     memory) produces token-exact output vs the single-process
     ``GlobalScheduler`` serving loop — for the degenerate 1P+1D cluster
     AND a routed 2P×2D cluster.
  2. *failure surfacing*: the P process dying hard (``os._exit``)
     mid-stream must strand no shared-memory segments, the D process must
     surface a transfer failure, and the launcher must requeue — with the
     retry visible in ``TransferStats.retries`` across the process
     boundary — and still finish every request after the respawn. A D
     instance dying in a pool with a *surviving* D must fail over (all
     streams finish on the survivor, no respawn).
  3. *no leaks*: no named shared-memory segments survive a connector
     ``close()``, nor a connector that is dropped without ``close()``
     (the ``weakref.finalize`` guard).
  4. *planner round trip*: ``plan_deployment``'s chosen instance counts
     launch unmodified through ``DeploymentPlan.to_cluster_spec``.
"""
import gc
import os

import numpy as np
import pytest

import jax

from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.transport import SharedMemoryConnector
from repro.core.transport.base import TransferStats
from repro.models import model as M
from repro.serving import router
from repro.serving.engine import Engine, VendorProfile
from repro.serving.multiproc import (ClusterRuntime, ClusterSpec, EngineSpec,
                                     TwoProcessRuntime, serve_cluster,
                                     serve_two_process)
from repro.serving.multiproc.launcher import _interval_overlap, _union
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler
from repro.serving.server import Server
from tests.conftest import TINY_FAMILIES

CFG = TINY_FAMILIES["dense"]
# heterogeneous pair: different block size, layout, and TP degree per side
VENDOR_P = VendorProfile("B", block_size=8, layout="nhbd",
                         kv_dtype="float32", tp=2)
VENDOR_D = VendorProfile("A", block_size=4, layout="nbhd",
                         kv_dtype="float32", tp=1)
SEED = 0
CHUNK = 8


def _requests(n=3, max_new=4):
    rng = np.random.default_rng(7)
    return [Request(req_id=f"req-{i}",
                    prompt=rng.integers(0, CFG.vocab_size,
                                        int(rng.integers(14, 30))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _spec(name, vendor, role):
    return EngineSpec(name, CFG, vendor, params_seed=SEED, num_blocks=64,
                      max_batch=4, max_seq_len=64, role=role)


def _serve_single(reqs):
    """Single-process reference: same engines, same connector kind."""
    params = M.init_params(jax.random.key(SEED), CFG)
    mk = lambda name, vendor, role: Engine(
        name, CFG, params, vendor, num_blocks=64, max_batch=4,
        max_seq_len=64, role=role)
    connector = SharedMemoryConnector()
    sched = GlobalScheduler(DisaggPipeline(connector,
                                           WireFormat("raw", "float32")),
                            prefill_chunk=CHUNK)
    sched.add_instance(mk("P0", VENDOR_P, "prefill"))
    sched.add_instance(mk("D0", VENDOR_D, "decode"))
    server = Server(sched)
    for r in reqs:
        server.submit(r)
    ticks = 0
    while sched.stats.finished < len(reqs) and ticks < 2000:
        sched.step()
        ticks += 1
    assert sched.stats.finished == len(reqs)
    connector.close()
    return {r.req_id: list(r.output_tokens) for r in reqs}


def _shm_files():
    """Named shared-memory data segments (``psm_*`` is CPython's
    ``SharedMemory`` name prefix — queue semaphores etc. don't count)."""
    if not os.path.isdir("/dev/shm"):
        return None
    return {f for f in os.listdir("/dev/shm") if f.startswith("psm_")}


# --------------------------------------------------------------------- #
# 1. parity: two OS processes, token-exact vs single-process
# --------------------------------------------------------------------- #
def test_two_process_token_exact_vs_single_process():
    before = _shm_files()
    ref = _serve_single(_requests())

    reqs = _requests()
    tokens, rt = serve_two_process(_spec("P0", VENDOR_P, "prefill"),
                                   _spec("D0", VENDOR_D, "decode"),
                                   reqs, prefill_chunk=CHUNK,
                                   max_wall_s=300.0)
    # really two other OS processes, instance-addressed
    assert set(rt.worker_pids) == {"P0", "D0"}
    assert len({os.getpid(), *rt.worker_pids.values()}) == 3
    assert rt.stats.finished == len(reqs)
    assert tokens == ref

    # KV moved through shared memory (both sides' stats merged home) and
    # the launcher measured real wall-clock handoff intervals
    assert rt.transfer_stats.transfers > 0
    assert rt.transfer_stats.bytes_moved > 0
    assert rt.transfer_stats.wall_handoff_seconds > 0
    assert 0 <= rt.transfer_stats.wall_overlap_seconds \
        <= rt.transfer_stats.wall_handoff_seconds
    # no stranded segments after shutdown
    after = _shm_files()
    if before is not None:
        assert after - before == set()


def test_two_process_backpressure_on_one_slot_channel():
    """A burst of ChunkReady messages must back-pressure on the
    connector's ``max_inflight``, not overrun the channel and fail
    streams: with a 1-read channel every request still completes."""
    reqs = _requests(n=3)
    tokens, rt = serve_two_process(_spec("P0", VENDOR_P, "prefill"),
                                   _spec("D0", VENDOR_D, "decode"),
                                   reqs, prefill_chunk=CHUNK,
                                   connector_kwargs={"max_inflight": 1},
                                   max_wall_s=300.0)
    assert rt.stats.finished == len(reqs)
    assert rt.stats.failed == 0
    assert not rt.stream_failures
    for r in reqs:
        assert len(tokens[r.req_id]) == r.max_new_tokens


# --------------------------------------------------------------------- #
# 2. P dies hard mid-stream → D surfaces it, launcher requeues, recovers
# --------------------------------------------------------------------- #
def test_p_crash_mid_stream_surfaces_failure_and_requeues():
    before = _shm_files()
    reqs = _requests(n=2)
    rt = TwoProcessRuntime(_spec("P0", VENDOR_P, "prefill"),
                           _spec("D0", VENDOR_D, "decode"),
                           prefill_chunk=CHUNK,
                           fault_exit_after_chunks=2)
    rt.start()
    try:
        tokens = rt.serve(reqs, max_wall_s=300.0)
    finally:
        rt.shutdown()

    assert rt.crashes["P"] == 1                # died once, was respawned
    # the D side surfaced the broken stream (abort / lost segment), and the
    # retry crossed the process boundary into the wire's accounting
    assert rt.stream_failures
    assert rt.stats.requeues >= 1
    assert rt.transfer_stats.retries >= 1
    # serving still completed, and the re-prefill was from scratch (the
    # crash hit during prefill, before any generated prefix existed)
    assert rt.stats.finished == len(reqs)
    assert rt.stats.failed == 0
    for r in reqs:
        assert len(tokens[r.req_id]) == r.max_new_tokens
    # the dead attempt's segments were unlinked, not stranded
    after = _shm_files()
    if before is not None:
        assert after - before == set()


# --------------------------------------------------------------------- #
# 2b. N×M cluster: routed 2P×2D parity, D-crash failover onto a survivor
# --------------------------------------------------------------------- #
def _cluster(n_p, n_d):
    return ClusterSpec(
        p=tuple(_spec(f"P{i}", VENDOR_P, "prefill") for i in range(n_p)),
        d=tuple(_spec(f"D{i}", VENDOR_D, "decode") for i in range(n_d)))


def test_cluster_2p2d_token_exact_vs_single_process():
    """Routing across 2 P and 2 D instances (same seed everywhere) must
    not change a single token vs the single-process loop."""
    before = _shm_files()
    reqs = _requests(n=6)
    ref = _serve_single(_requests(n=6))
    tokens, rt = serve_cluster(_cluster(2, 2), reqs, prefill_chunk=CHUNK,
                               max_wall_s=300.0)
    # four real worker processes, all instance-addressed
    assert set(rt.worker_pids) == {"P0", "P1", "D0", "D1"}
    assert len({os.getpid(), *rt.worker_pids.values()}) == 5
    assert rt.stats.finished == len(reqs)
    assert tokens == ref
    # the router actually used the pool: every dispatch is attributed to
    # an instance, and with 6 requests × 2 instances both roles spread
    assert sum(rt.stats.p_dispatches.values()) == len(reqs)
    assert sum(rt.stats.d_dispatches.values()) == len(reqs)
    assert len(rt.stats.d_dispatches) == 2      # both Ds served work
    after = _shm_files()
    if before is not None:
        assert after - before == set()


def test_d_crash_fails_over_to_surviving_d_without_respawn():
    """One of two D instances dies hard mid-decode: its streams must
    re-prefill onto the *surviving* D (generated prefix appended — still
    token-exact) with no respawn, and every request must finish."""
    before = _shm_files()
    reqs = _requests(n=4, max_new=4)
    ref = _serve_single(_requests(n=4, max_new=4))
    rt = ClusterRuntime(_cluster(1, 2), prefill_chunk=CHUNK,
                        fault_exit_after_tokens=3)    # lands on D0
    rt.start()
    try:
        tokens = rt.serve(reqs, max_wall_s=300.0)
    finally:
        rt.shutdown()
    assert rt.crashes["D"] == 1
    assert rt.respawns["D"] == 0               # survivor took over instead
    assert "D0" not in rt._instances           # dead member left the pool
    assert rt.stats.finished == len(reqs)
    assert rt.stats.failed == 0
    assert rt.stats.requeues >= 1              # the failover re-prefill
    for r in reqs:
        assert len(tokens[r.req_id]) == r.max_new_tokens
    assert tokens == ref                       # greedy: failover is exact
    # everything finished on the survivor after the crash
    after = _shm_files()
    if before is not None:
        assert after - before == set()


def test_d_crash_failover_retry_resumes_from_prefix_cache():
    """With the prefix cache on, a failover retry must not pay for the
    whole prompt again: the re-prefill replays from P's prefix store
    (at least one full block skipped) and stays token-exact."""
    reqs = _requests(n=4, max_new=4)
    ref = _serve_single(_requests(n=4, max_new=4))
    pspec = lambda name, vendor, role: EngineSpec(
        name, CFG, vendor, params_seed=SEED, num_blocks=64, max_batch=4,
        max_seq_len=64, role=role, prefix_cache=True)
    spec = ClusterSpec(
        p=(pspec("P0", VENDOR_P, "prefill"),),
        d=tuple(pspec(f"D{i}", VENDOR_D, "decode") for i in range(2)))
    rt = ClusterRuntime(spec, prefill_chunk=CHUNK,
                        fault_exit_after_tokens=3)
    rt.start()
    try:
        tokens = rt.serve(reqs, max_wall_s=300.0)
    finally:
        rt.shutdown()
    assert rt.crashes["D"] == 1
    assert rt.respawns["D"] == 0               # survivor took over
    assert rt.stats.finished == len(reqs)
    assert rt.stats.failed == 0
    assert rt.stats.requeues >= 1
    assert tokens == ref                       # cached replay is exact
    # the retry resumed from the longest cached prefix instead of
    # recomputing the prompt from scratch
    assert rt.worker_stats["P0"]["prefix_cached_tokens"] \
        >= VENDOR_P.block_size


# --------------------------------------------------------------------- #
# 2c. planner → runtime round trip
# --------------------------------------------------------------------- #
def test_plan_to_cluster_spec_launches_planned_topology():
    from repro.core.planner.hardware import GPU_A, GPU_B
    from repro.core.planner.optimizer import plan_deployment
    from repro.core.planner.workload import Workload

    # loose SLOs so the tiny config is feasible on the modeled hardware
    wl = Workload(qps=0.1, input_len=32, output_len=8,
                  slo_ttft_s=1e3, slo_tpot_s=1e3)
    plan = plan_deployment(CFG, wl, GPU_B, GPU_A)
    spec = plan.to_cluster_spec(CFG, p_vendor=VENDOR_P, d_vendor=VENDOR_D,
                                params_seed=SEED, num_blocks=64,
                                max_batch=4, max_seq_len=64)
    # the planner's instance allocation is what actually launches
    assert len(spec.p) == plan.n_prefill
    assert len(spec.d) == plan.n_decode
    # default vendors: KV-shard TP must divide the model's KV heads even
    # when the planned compute TP does not
    auto = plan.to_cluster_spec(CFG)
    assert CFG.num_kv_heads % auto.p[0].vendor.tp == 0
    assert CFG.num_kv_heads % auto.d[0].vendor.tp == 0
    # --num-p/--num-d style override
    assert len(plan.to_cluster_spec(CFG, num_p=2, num_d=3).p) == 2
    assert len(plan.to_cluster_spec(CFG, num_p=2, num_d=3).d) == 3

    reqs = _requests(n=3)
    ref = _serve_single(_requests(n=3))
    tokens, rt = serve_cluster(spec, reqs, prefill_chunk=CHUNK,
                               max_wall_s=300.0)
    assert rt.stats.finished == len(reqs)
    assert tokens == ref


# --------------------------------------------------------------------- #
# 2d. routing policy (pure, no processes)
# --------------------------------------------------------------------- #
def test_pick_p_least_outstanding_tokens():
    snaps = [router.PSnapshot("P0", queue_reqs=1, queue_tokens=100),
             router.PSnapshot("P1", queue_reqs=3, queue_tokens=40)]
    assert router.pick_p(snaps) == "P1"        # tokens beat request count
    assert router.pick_p([]) is None
    tie = [router.PSnapshot("P1", 1, 10), router.PSnapshot("P0", 1, 10)]
    assert router.pick_p(tie) == "P0"          # deterministic tiebreak


def _dsnap(iid, active=0, free_blocks=15, max_batch=4, block_size=4,
           max_seq_len=64, block_bytes=1024):
    return router.DSnapshot(iid=iid, active=active, max_batch=max_batch,
                            free_blocks=free_blocks, block_size=block_size,
                            max_blocks_per_seq=-(-max_seq_len // block_size),
                            max_seq_len=max_seq_len, block_bytes=block_bytes)


def test_pick_d_admission_and_load_order():
    # seq 20 + 4 new = 24 tokens → 6 blocks of 4
    assert router.pick_d([_dsnap("D0")], 20, 4) == ("D0", 6)
    # full batch and too-long sequences are inadmissible
    assert router.pick_d([_dsnap("D0", active=4)], 20, 4) is None
    assert router.pick_d([_dsnap("D0")], 80, 4) is None
    assert router.pick_d([_dsnap("D0", free_blocks=5)], 20, 4) is None
    # least occupied wins; free KV-pool bytes breaks occupancy ties
    snaps = [_dsnap("D0", active=2), _dsnap("D1", active=1)]
    assert router.pick_d(snaps, 20, 4)[0] == "D1"
    tie = [_dsnap("D0", active=1, free_blocks=6),
           _dsnap("D1", active=1, free_blocks=12)]
    assert router.pick_d(tie, 20, 4)[0] == "D1"


def test_blocks_needed_mirrors_engine_reservation():
    eng_spec = _spec("Dx", VENDOR_D, "decode")
    eng = eng_spec.build()
    req = Request(req_id="probe",
                  prompt=np.arange(18, dtype=np.int32) % CFG.vocab_size,
                  max_new_tokens=5)
    slot, block_ids = eng.reserve_sequence(req, req.prompt_len)
    want = router.blocks_needed(req.prompt_len + req.max_new_tokens,
                                eng.block_size, eng.max_blocks_per_seq)
    assert len(block_ids) == want


# --------------------------------------------------------------------- #
# 3. segment-leak guard on the connector itself
# --------------------------------------------------------------------- #
def _stage_some(conn, n=3):
    names = []
    for i in range(n):
        key = f"leak-{i}"
        conn.stage(key, {"k": np.arange(64, dtype=np.float32)}, {"i": i})
        names.append(conn.segment_name(key))
    return names


def _assert_unlinked(names):
    from multiprocessing import shared_memory
    for name in names:
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=name)


def test_shm_close_unlinks_every_segment():
    conn = SharedMemoryConnector()
    names = _stage_some(conn)
    conn.close()
    _assert_unlinked(names)


def test_shm_finalizer_unlinks_on_drop_without_close():
    conn = SharedMemoryConnector()
    names = _stage_some(conn)
    del conn                               # no drop(), no close()
    gc.collect()
    _assert_unlinked(names)


def test_shm_adopted_segment_not_unlinked_by_reader():
    """The reader detaches on complete(); only the creator unlinks."""
    creator = SharedMemoryConnector()
    reader = SharedMemoryConnector()
    creator.stage("x", {"k": np.ones(8, np.float32)}, {})
    desc = creator.export_descriptor("x")
    reader.adopt_segment(desc["key"], desc["segment"], desc["nbytes"])
    payload, _meta = reader.issue_read("x").wait()
    np.testing.assert_array_equal(payload["k"], np.ones(8, np.float32))
    reader.complete("x")                   # detach only
    from multiprocessing import shared_memory
    seg = shared_memory.SharedMemory(name=desc["segment"])   # still alive
    seg.close()
    creator.complete("x")                  # creator unlinks
    _assert_unlinked([desc["segment"]])
    reader.close()
    creator.close()


# --------------------------------------------------------------------- #
# 4. launcher accounting helpers
# --------------------------------------------------------------------- #
def test_transfer_stats_merge_sums_counters_and_maxes_peak():
    a = TransferStats(transfers=2, bytes_moved=100, retries=1,
                      peak_buffer_bytes=50, wall_handoff_seconds=1.0)
    b = TransferStats(transfers=3, bytes_moved=10, retries=0,
                      peak_buffer_bytes=80, wall_overlap_seconds=0.5)
    a.merge(b)
    assert (a.transfers, a.bytes_moved, a.retries) == (5, 110, 1)
    assert a.peak_buffer_bytes == 80       # high-water, not a sum
    assert a.wall_handoff_seconds == 1.0
    assert a.wall_overlap_seconds == 0.5


def test_interval_overlap():
    spans = [(0.0, 1.0), (2.0, 3.0)]
    assert _interval_overlap((0.5, 2.5), spans) == pytest.approx(1.0)
    assert _interval_overlap((1.0, 2.0), spans) == 0.0
    assert _interval_overlap((-1.0, 4.0), spans) == pytest.approx(2.0)


def test_union_merges_overlapping_and_drops_empty():
    assert _union([(2.0, 3.0), (0.0, 1.5), (1.0, 2.5), (5.0, 5.0)]) \
        == [(0.0, 3.0)]
    # concurrent in-flight chunks must not double-count overlap: the
    # union of their wire intervals is what gets intersected with compute
    wire = _union([(0.0, 2.0), (1.0, 3.0)])
    assert sum(_interval_overlap(w, [(0.0, 10.0)]) for w in wire) \
        == pytest.approx(3.0)
