"""Paged-cache substrate: allocator invariants (hypothesis state machine),
pool ops, reference paged attention vs dense."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                 precondition, rule)

import jax
import jax.numpy as jnp

from repro.serving import paged_cache as PC


class AllocatorMachine(RuleBasedStateMachine):
    """A live block is owned by exactly one sequence; free+owned partitions
    the pool; freeing returns every owned block."""

    def __init__(self):
        super().__init__()
        self.alloc = PC.BlockAllocator(32)
        self.live = {}
        self.counter = 0

    @rule(n=st.integers(1, 6))
    def allocate(self, n):
        sid = f"s{self.counter}"
        self.counter += 1
        if self.alloc.can_allocate(n):
            blocks = self.alloc.allocate(sid, n)
            assert len(blocks) == n
            self.live[sid] = blocks
        else:
            with pytest.raises(MemoryError):
                self.alloc.allocate(sid, n)

    @precondition(lambda self: self.live)
    @rule(data=st.data())
    def free(self, data):
        sid = data.draw(st.sampled_from(sorted(self.live)))
        n = self.alloc.free(sid)
        assert n == len(self.live.pop(sid))

    @rule(n=st.integers(1, 4))
    def grow(self, n):
        if self.live:
            sid = sorted(self.live)[0]
            if self.alloc.can_allocate(n):
                self.live[sid] += self.alloc.allocate(sid, n)

    @invariant()
    def check(self):
        self.alloc.check_invariants()
        owned = sum(len(v) for v in self.live.values())
        assert owned + self.alloc.free_blocks == 32


TestAllocator = AllocatorMachine.TestCase


@given(seq=st.integers(1, 50), bs=st.sampled_from([4, 8, 16]))
def test_blocks_for(seq, bs):
    spec = PC.KVPageSpec(bs, "nbhd", "float32", 1, 8)
    nb = spec.blocks_for(seq)
    assert (nb - 1) * bs < seq <= nb * bs


def test_append_token_every_layout():
    for layout in PC.LAYOUTS:
        spec = PC.KVPageSpec(4, layout, "float32", 2, 8)
        pool = PC.init_pool(spec, 6)
        kv_tok = jnp.asarray(np.random.default_rng(0).normal(size=(3, 2, 8)),
                             jnp.float32)
        blocks = jnp.asarray([1, 2, 5], jnp.int32)
        slots = jnp.asarray([0, 3, 2], jnp.int32)
        pool = PC.append_token(spec, pool, blocks, slots, kv_tok)
        canon = PC.pages_to_canonical(spec, pool)
        for i in range(3):
            np.testing.assert_array_equal(
                np.asarray(canon[blocks[i], slots[i]]),
                np.asarray(kv_tok[i]))


def test_paged_attention_ref_matches_dense():
    b, h, kv, hd, bs, pages = 2, 4, 2, 16, 4, 3
    spec = PC.KVPageSpec(bs, "nbhd", "float32", kv, hd)
    rng = np.random.default_rng(1)
    seq_lens = jnp.asarray([7, 11], jnp.int32)
    k = rng.normal(size=(b, bs * pages, kv, hd)).astype(np.float32)
    v = rng.normal(size=(b, bs * pages, kv, hd)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)
    k_pool = PC.init_pool(spec, b * pages + 1)
    v_pool = PC.init_pool(spec, b * pages + 1)
    table = np.arange(1, b * pages + 1).reshape(b, pages)
    for i in range(b):
        k_pool = PC.scatter_sequence(spec, k_pool, jnp.asarray(table[i]),
                                     jnp.asarray(k[i]))
        v_pool = PC.scatter_sequence(spec, v_pool, jnp.asarray(table[i]),
                                     jnp.asarray(v[i]))
    got = PC.paged_attention_ref(q, k_pool, v_pool, jnp.asarray(table),
                                 seq_lens, spec)
    from repro.models import layers as L
    mask = L.length_mask(seq_lens, bs * pages)
    want = L.sdpa(q, jnp.asarray(k), jnp.asarray(v), mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
