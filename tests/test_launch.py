"""Launch-layer integration: every cell's step program TRACES (jit.lower)
on a 1×1 mesh with reduced depth — exercises input_specs, sharding-rule
construction, deploy transforms, and the step builders without the
512-device environment (which dryrun.py owns)."""
import numpy as np
import pytest

import jax

from repro.configs import ASSIGNED
from repro.launch.cells import get_cell, make_cells
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_artifacts

REDUCED = {"num_layers": 2}
REDUCED_ENCDEC = {"num_layers": 2, "encoder_layers": 2}
REDUCED_RG = {"num_layers": 3}


def _override(arch):
    if arch == "whisper-large-v3":
        return dict(REDUCED_ENCDEC)
    if arch == "recurrentgemma-9b":
        return dict(REDUCED_RG)
    return dict(REDUCED)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-4b", "train_4k"),
    ("qwen3-4b", "decode_32k"),
    ("deepseek-v2-lite-16b", "prefill_32k"),
    ("mixtral-8x7b", "long_500k"),
    ("whisper-large-v3", "decode_32k"),
    ("recurrentgemma-9b", "long_500k"),
    ("mamba2-370m", "decode_32k"),
    ("internvl2-2b", "prefill_32k"),
])
def test_cell_traces_on_unit_mesh(arch, shape, mesh):
    cell = get_cell(arch, shape)
    assert cell.skip is None
    art = make_artifacts(cell, mesh, layer_override=_override(arch))
    lowered = art.lower()                     # trace + StableHLO, no alloc
    assert lowered is not None
    text = lowered.as_text()
    assert len(text) > 1000


def test_skipped_cells_never_built(mesh):
    for cell in make_cells():
        if cell.skip:
            assert cell.shape == "long_500k"


def test_deploy_padding_at_production_axis():
    from repro.launch.sharding import deploy_config
    from repro.configs.base import get_config
    cfg = get_config("qwen1.5-32b")
    t = deploy_config(cfg, 16, "train")
    assert t.num_heads == 48 and t.num_kv_heads == 48
    d = deploy_config(cfg, 16, "decode")
    assert d.num_heads == 40                   # decode stays unpadded
    assert d.vocab_size % 16 == 0


@pytest.mark.parametrize("arch", ["qwen1.5-32b", "deepseek-v2-lite-16b",
                                  "whisper-large-v3", "mamba2-370m"])
def test_handoff_program_traces(arch, mesh):
    """P→D cache realignment (head slice + cap pad + dtype cast + reshard)
    must trace with matching tree structures for every cache family."""
    from repro.launch.steps import make_handoff_artifacts
    art = make_handoff_artifacts(arch, mesh,
                                 layer_override=_override(arch))
    lowered = art.lower()
    assert lowered is not None


def test_fp8_cache_threaded_through_artifacts(mesh):
    cell_d = get_cell("qwen1.5-32b", "decode_32k")
    art_d = make_artifacts(cell_d, mesh,
                           layer_override=_override("qwen1.5-32b"))
    leaves = jax.tree.leaves(art_d.abstract_args[1])
    assert any(l.dtype == jax.numpy.float8_e4m3fn for l in leaves
               if hasattr(l, "dtype"))
