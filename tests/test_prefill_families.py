"""Family × capability prefill guarantees (the capability-declared
prefill API).

  1. *wire sweep*: incremental (chunk-at-a-time) compute is token-exact
     vs monolithic compute over the SAME wire format for state-carrying
     and multimodal families, on raw/bf16/int8 wires.
  2. *process boundary*: the same chunked parity holds through the
     multi-process runtime (P and D in separate OS processes).
  3. *resume*: a D failure mid-stream on a state-carrying family retries
     from the flight's layer-state snapshot — measured in
     ``EngineStats.resumed_tokens`` — and still emits exact tokens.
  4. *honest integrated baseline*: a ``role="both"`` engine under mixed
     load measures nonzero decode-stall seconds; the disaggregated
     topology measures zero. The planner's event sim models the same
     quantity for the plan-vs-measured report.
"""
import numpy as np
import pytest

import jax

from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.models import model as M
from repro.serving.engine import Engine, PrefillMode, VendorProfile
from repro.serving.request import Request, State
from repro.serving.scheduler import GlobalScheduler
from tests.conftest import TINY_FAMILIES

WIRES = [WireFormat("raw", "float32"), WireFormat("raw", "bfloat16"),
         WireFormat("int8")]

_PARAMS = {}


def _params(family):
    if family not in _PARAMS:
        _PARAMS[family] = M.init_params(jax.random.key(1),
                                        TINY_FAMILIES[family])
    return _PARAMS[family]


def _req(cfg, plen, rid="r0", max_new=6, seed=3):
    rng = np.random.default_rng(seed)
    r = Request(req_id=rid,
                prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                max_new_tokens=max_new)
    if cfg.is_enc_dec:
        r.frames = rng.normal(size=(10, cfg.d_model)).astype(np.float32)
    if cfg.frontend.kind == "vision":
        r.patches = rng.normal(size=(cfg.frontend.num_patches,
                                     cfg.d_model)).astype(np.float32)
    return r


def _mem(cfg):
    return 10 if cfg.is_enc_dec else 0


def _pair(cfg, params, mem_len=0):
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    p = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
               max_seq_len=64, mem_len=mem_len, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, mem_len=mem_len, role="decode")
    return p, d


# --------------------------------------------------------------------- #
# 1. wire sweep: incremental == monolithic on every wire format
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("family", ["sliding", "hybrid", "encdec"])
@pytest.mark.parametrize("wire", WIRES, ids=lambda w: f"{w.kind}-{w.dtype}")
def test_incremental_equals_monolithic_on_same_wire(family, wire):
    """The tentpole claim, per wire format: chunk-at-a-time compute (with
    window masking / carried layer state / encoder preamble) must emit
    the tokens the one-pass compute emits over the identical wire."""
    cfg = TINY_FAMILIES[family]
    params = _params(family)

    def run(mode):
        p, d = _pair(cfg, params, mem_len=_mem(cfg))
        pipe = DisaggPipeline(TransferEngine(), wire)
        meta = pipe.handoff_streamed(_req(cfg, plen=21), p, d,
                                     chunk_tokens=5, mode=mode)
        toks = [meta["first_token"]]
        for _ in range(4):
            toks.append(int(d.decode_step()[0][2]))
        return toks, p.stats.prefill_chunks

    mono, mono_chunks = run(PrefillMode.MONOLITHIC)
    inc, inc_chunks = run(PrefillMode.INCREMENTAL)
    assert inc == mono, (family, wire.kind, wire.dtype)
    assert mono_chunks == 1 and inc_chunks == 5      # ceil(21/5) vs one pass


# --------------------------------------------------------------------- #
# 2. process boundary: chunked parity through the multiproc runtime
# --------------------------------------------------------------------- #
def _serve_single(cfg, params, reqs, mem_len=0, prefill_chunk=4):
    p, d = _pair(cfg, params, mem_len=mem_len)
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=prefill_chunk)
    sched.add_instance(p)
    sched.add_instance(d)
    done = sched.run(reqs, max_ticks=800)
    assert len(done) == len(reqs)
    return {r.req_id: list(r.output_tokens) for r in reqs}


@pytest.mark.parametrize("family", ["sliding", "ssm", "encdec"])
def test_multiproc_chunked_parity(family):
    """State-carrying and encoder-preamble families through real OS
    processes (chunked compute, staged shared-memory wire, tail package
    with states/cross rows) match the single-process scheduler."""
    from repro.serving.multiproc import EngineSpec, serve_two_process
    cfg = TINY_FAMILIES[family]
    params = _params(family)
    mem = _mem(cfg)
    mk = lambda: [_req(cfg, plen=(21, 9, 14)[i], rid=f"q{i}", seed=i)
                  for i in range(3)]
    ref = _serve_single(cfg, params, mk(), mem_len=mem)

    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    common = dict(cfg=cfg, params_seed=1, num_blocks=64, max_batch=4,
                  max_seq_len=64, mem_len=mem)
    reqs = mk()
    tokens, rt = serve_two_process(
        EngineSpec(name="P0", vendor=vp, role="prefill", **common),
        EngineSpec(name="D0", vendor=vd, role="decode", **common),
        reqs, prefill_chunk=4, max_wall_s=300.0)
    assert rt.stats.finished == len(reqs)
    assert tokens == ref, family


# --------------------------------------------------------------------- #
# 3. resume: mid-stream failure retries from the layer-state snapshot
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("family", ["hybrid", "sliding"])
def test_d_failure_resumes_from_snapshot(family):
    """Kill the D mid-prefill: the retry on the surviving D reuses the
    aborted flight's snapshot (carried rglru/window state) instead of
    recomputing from token 0 — and still finishes token-exact."""
    cfg = TINY_FAMILIES[family]
    params = _params(family)
    ref = _serve_single(cfg, params, [_req(cfg, plen=24, rid="rq",
                                           max_new=4, seed=5)])["rq"]

    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    p = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
               max_seq_len=64, role="prefill")
    d0 = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
                max_seq_len=64, role="decode")
    d1 = Engine("D1", cfg, params, vd, num_blocks=64, max_batch=4,
                max_seq_len=64, role="decode")
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1)
    for e in (p, d0, d1):
        sched.add_instance(e)

    req = _req(cfg, plen=24, rid="rq", max_new=4, seed=5)
    sched.submit(req)
    sched.step()
    sched.step()                        # two 4-token chunks computed
    assert len(sched.inflight) == 1
    sched.inflight[0].d.fail()          # decode node dies mid-stream
    for _ in range(100):
        if sched.stats.finished >= 1:
            break
        sched.step()
    assert sched.stats.finished == 1 and sched.stats.requeues == 1
    assert req.state == State.FINISHED
    assert list(req.output_tokens) == ref, family
    # the retry really resumed: computed tokens were skipped, and the
    # resumed stream recomputed less than a from-scratch second pass
    assert p.stats.resumed_tokens > 0
    assert p.stats.prefill_tokens < 2 * 24


def test_p_failure_discards_snapshot_for_other_p():
    """A snapshot is engine-local state: when the *P* dies, the retry on
    a different P must start clean (no resumed tokens), not adopt a
    snapshot whose device arrays died with the failed engine."""
    cfg = TINY_FAMILIES["hybrid"]
    params = _params("hybrid")
    vp = VendorProfile("B", block_size=8, layout="nhbd",
                       kv_dtype="float32", tp=2)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    p0 = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    p1 = Engine("P1", cfg, params, vp, num_blocks=64, max_batch=4,
                max_seq_len=64, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, role="decode")
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1)
    for e in (p0, p1, d):
        sched.add_instance(e)
    req = _req(cfg, plen=24, rid="rq", max_new=4, seed=5)
    sched.submit(req)
    sched.step()
    sched.step()
    victim = sched.inflight[0].p
    victim.fail()
    for _ in range(100):
        if sched.stats.finished >= 1:
            break
        sched.step()
    assert sched.stats.finished == 1
    survivor = p1 if victim is p0 else p0
    assert survivor.stats.resumed_tokens == 0
    assert len(req.output_tokens) == 4


# --------------------------------------------------------------------- #
# 4. honest integrated baseline: measured decode-stall
# --------------------------------------------------------------------- #
def test_integrated_measures_contention_disagg_measures_zero():
    """Mixed load on one role="both" engine: prefill-priority ticks defer
    ready decode steps, and that interference lands in
    ``contention_stall_seconds``. The same workload disaggregated
    measures exactly zero — the paper's motivating asymmetry."""
    cfg = TINY_FAMILIES["dense"]
    params = _params("dense")
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")

    def workload():
        first = _req(cfg, plen=8, rid="warm", max_new=12, seed=1)
        rest = [_req(cfg, plen=20, rid=f"p{i}", max_new=2, seed=10 + i)
                for i in range(3)]
        return first, rest

    # integrated: one engine plays P and D
    both = Engine("I0", cfg, params, vd, num_blocks=64, max_batch=4,
                  max_seq_len=64, role="both")
    pipe = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe, prefill_chunk=4, chunk_budget=1)
    sched.add_instance(both)
    first, rest = workload()
    sched.submit(first)
    for _ in range(4):                  # warm request reaches decode
        sched.step()
    for r in rest:                      # prefills arrive mid-decode
        sched.submit(r)
    for _ in range(300):
        if sched.stats.finished == 4:
            break
        sched.step()
    assert sched.stats.finished == 4
    assert both.stats.contention_stall_seconds > 0.0

    # disaggregated: same workload, separate P and D timelines
    p, d = _pair(cfg, params)
    pipe2 = DisaggPipeline(TransferEngine(), WireFormat("raw", "float32"))
    sched2 = GlobalScheduler(pipe2, prefill_chunk=4, chunk_budget=1)
    sched2.add_instance(p)
    sched2.add_instance(d)
    first, rest = workload()
    sched2.submit(first)
    for _ in range(4):
        sched2.step()
    for r in rest:
        sched2.submit(r)
    for _ in range(300):
        if sched2.stats.finished == 4:
            break
        sched2.step()
    assert sched2.stats.finished == 4
    assert p.stats.contention_stall_seconds == 0.0
    assert d.stats.contention_stall_seconds == 0.0


def test_event_sim_models_contention_for_integrated_only():
    """The planner's event sim exposes the same decode-stall quantity the
    runtime measures: nonzero for the integrated baseline under load,
    zero for disagg, and present in ``SimResult.summary()`` so the
    plan-vs-measured report can diff them."""
    from repro.core.planner.events import simulate
    from repro.core.planner.hardware import GPU_A
    from repro.core.planner.simulator import (FrameworkModel, InstanceModel,
                                              ParallelStrategy)
    from repro.core.planner.workload import Workload
    cfg = TINY_FAMILIES["dense"]
    m = InstanceModel(cfg, GPU_A, ParallelStrategy(), FrameworkModel())
    wl = Workload(qps=3000, input_len=64, output_len=32)
    r_int = simulate(cfg, wl, p_model=m, d_model=m, mode="integrated",
                     duration_s=1.0)
    r_dis = simulate(cfg, wl, p_model=m, d_model=m, mode="disagg",
                     duration_s=1.0)
    assert r_int.contention_stall_s > 0.0
    assert r_dis.contention_stall_s == 0.0
    assert r_int.summary()["contention_stall_s"] == r_int.contention_stall_s


def test_report_aggregates_contention_and_resume():
    """The plan-vs-measured report surfaces the new honesty metrics:
    per-worker contention/resume stats summed in the measured section,
    and the modeled-vs-measured stall delta when a sim summary rides
    along."""
    from types import SimpleNamespace
    from repro.core.transport.base import TransferStats
    from repro.serving.multiproc.report import plan_vs_measured
    runtime = SimpleNamespace(
        stats=SimpleNamespace(p_dispatches={"P0": 2}, d_dispatches={"D0": 2},
                              submitted=2, finished=2, failed=0, shed=0,
                              requeues=1),
        worker_stats={
            "P0": {"contention_stall_seconds": 0.0, "resume_unsupported": 1,
                   "resumed_tokens": 8},
            "I0": {"contention_stall_seconds": 0.25, "resume_unsupported": 0,
                   "resumed_tokens": 0},
        },
        transfer_stats=TransferStats(), crashes={}, respawns={})
    rep = plan_vs_measured(runtime, [], wall_s=1.0,
                           sim_summary={"contention_stall_s": 0.10})
    m = rep["measured"]
    assert m["contention_stall_seconds"] == 0.25
    assert m["resume_unsupported"] == 1
    assert m["resumed_tokens"] == 8
    assert rep["deltas"]["contention_stall_vs_modeled_s"] == \
        pytest.approx(0.15)


def test_planner_encoder_tokens_term():
    """The cost model charges for the encoder preamble: enc-dec pays the
    encoder stack over the source length, vision pays the patch rows as
    prefill tokens, and text-only families ignore the term entirely."""
    from repro.core.planner.events import kv_wire_bytes_per_token
    from repro.core.planner.hardware import GPU_A
    from repro.core.planner.simulator import InstanceModel, ParallelStrategy
    enc = InstanceModel(TINY_FAMILIES["encdec"], GPU_A, ParallelStrategy())
    assert enc.prefill_latency(16, encoder_tokens=128) > \
        enc.prefill_latency(16)
    vlm = InstanceModel(TINY_FAMILIES["vlm"], GPU_A, ParallelStrategy())
    assert vlm.prefill_latency(16, encoder_tokens=64) > \
        vlm.prefill_latency(16)
    txt = InstanceModel(TINY_FAMILIES["dense"], GPU_A, ParallelStrategy())
    assert txt.prefill_latency(16, encoder_tokens=64) == \
        txt.prefill_latency(16)
    # wire bytes route through the capability descriptor
    assert kv_wire_bytes_per_token(TINY_FAMILIES["ssm"]) == 0
    assert kv_wire_bytes_per_token(TINY_FAMILIES["mla"]) < \
        kv_wire_bytes_per_token(TINY_FAMILIES["dense"])
