"""Elastic P:D autoscaler: grows under SLO pressure, drains when idle,
never shrinks below the planner baseline, requests always finish."""
import numpy as np
import pytest

import jax

from repro.core.autoscale import AutoscalerConfig, PDAutoscaler
from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler
from tests.conftest import TINY_FAMILIES

CFG = TINY_FAMILIES["dense"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.key(1), CFG)


def _factory(params, role):
    def make(name):
        return Engine(name, CFG, params, VendorProfile("A", block_size=8),
                      num_blocks=64, max_batch=2, max_seq_len=64, role=role)
    return make


def _setup(params, **cfg_kw):
    sched = GlobalScheduler(DisaggPipeline(TransferEngine(),
                                           WireFormat("raw", "float32")))
    mk_p = _factory(params, "prefill")
    mk_d = _factory(params, "decode")
    sched.add_instance(mk_p("P0"))
    sched.add_instance(mk_d("D0"))
    # huge SLOs: CPU wall-clock latencies must not trigger SLO pressure —
    # these tests exercise the queue/slot-utilization signals
    cfg_kw.setdefault("slo_ttft_s", 1e9)
    cfg_kw.setdefault("slo_tpot_s", 1e9)
    auto = PDAutoscaler(sched, mk_p, mk_d, baseline_p=1, baseline_d=1,
                        config=AutoscalerConfig(cooldown_ticks=2, **cfg_kw))
    return sched, auto


def _reqs(n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(req_id=f"a{i}",
                    prompt=rng.integers(0, CFG.vocab_size, 8)
                    .astype(np.int32), max_new_tokens=6)
            for i in range(n)]


def test_grows_d_under_slot_pressure(params):
    """A burst beyond the decode slots must trigger scale-up and finish."""
    sched, auto = _setup(params, d_util_high=0.7)
    reqs = _reqs(10)
    for r in reqs:
        sched.submit(r)
    actions = []
    for _ in range(300):
        if sched.stats.finished >= len(reqs):
            break
        sched.step()
        a = auto.tick()
        if a:
            actions.append(a)
    assert sched.stats.finished == len(reqs)
    assert auto.stats.grew_d >= 1, actions
    # the new instance actually served work
    served = {k for k, v in sched.stats.d_dispatches.items() if v > 0}
    assert any(k.startswith("D-auto") for k in served)


def test_drains_when_idle_but_keeps_baseline(params):
    sched, auto = _setup(params, d_util_high=0.7)
    reqs = _reqs(10)
    for r in reqs:
        sched.submit(r)
    for _ in range(300):
        sched.step()
        auto.tick()
        if sched.stats.finished >= len(reqs):
            break
    grew = auto.stats.grew_d + auto.stats.grew_p
    # idle phase: drain surplus down to the planner baseline
    for _ in range(10 * (grew + 1)):
        sched.step()
        auto.tick()
    assert auto.stats.drained >= min(grew, 1)
    routable_d = sched._routable(sched.d_pool)
    assert len(routable_d) >= auto.baseline_d
    assert "D0" in sched.d_pool and "D0" not in sched._draining


def test_no_growth_without_pressure(params):
    sched, auto = _setup(params)
    reqs = _reqs(1)
    for r in reqs:
        sched.submit(r)
    for _ in range(100):
        sched.step()
        auto.tick()
        if sched.stats.finished >= 1:
            break
    assert auto.stats.grew_p == 0 and auto.stats.grew_d == 0


def test_cluster_load_source_grows_live_d_process():
    """Point the same controller at a live multi-process ClusterRuntime:
    decode-slot pressure (1 D, max_batch=2, 8 requests) must make it spawn
    a real extra D worker via add_instance — *without* stalling serving
    while it boots (non-blocking grow; the member turns routable when its
    Hello lands) — everything still finishes, and once the cluster goes
    idle the surplus member drains back down to the baseline."""
    import time

    from repro.core.autoscale import ClusterLoadSource
    from repro.serving.multiproc import (ClusterRuntime, ClusterSpec,
                                         EngineSpec)

    vendor = VendorProfile("A", block_size=8)
    mk = lambda name, role: EngineSpec(name, CFG, vendor, params_seed=0,
                                       num_blocks=64, max_batch=2,
                                       max_seq_len=64, role=role)
    rt = ClusterRuntime(ClusterSpec(p=(mk("P0", "prefill"),),
                                    d=(mk("D0", "decode"),)),
                        prefill_chunk=8)
    try:
        rt.start()
        auto = PDAutoscaler(
            ClusterLoadSource(rt),
            p_factory=lambda n: mk(n, "prefill"),
            d_factory=lambda n: mk(n, "decode"),
            baseline_p=1, baseline_d=1,
            config=AutoscalerConfig(cooldown_ticks=2, d_util_high=0.5,
                                    slo_ttft_s=1e9, slo_tpot_s=1e9,
                                    max_p=1, max_d=2))
        reqs = _reqs(8)
        for r in reqs:
            rt.submit(r)
        deadline = time.monotonic() + 300.0
        while rt._unresolved() and time.monotonic() < deadline:
            rt.step(timeout=0.02)
            auto.tick()
        assert rt._unresolved() == 0
        assert rt.stats.finished == len(reqs) and rt.stats.failed == 0
        assert auto.stats.grew_d >= 1
        assert all(len(r.output_tokens) == r.max_new_tokens for r in reqs)
        # grow was non-blocking: pump until the new member's Hello lands,
        # then it must be a real routable worker process
        deadline = time.monotonic() + 120.0
        while "D1" not in {i.iid for i in rt._routable("D")} \
                and time.monotonic() < deadline:
            rt.step(timeout=0.05)
        assert "D1" in {i.iid for i in rt._routable("D")}
        assert rt.worker_pids.get("D1")
        # idle cluster: sustained low utilization drains the surplus D
        # (never the baseline member)
        deadline = time.monotonic() + 120.0
        while "D1" in rt._instances and time.monotonic() < deadline:
            rt.step(timeout=0.02)
            auto.tick()
        assert "D1" not in rt._instances
        assert auto.stats.drained >= 1
        assert "D0" in {i.iid for i in rt._routable("D")}
    finally:
        rt.shutdown()
