"""Shared tiny-model fixtures. Tests run on the plain 1-device CPU backend —
the 512-device dry-run is exercised only via repro.launch.dryrun."""
import numpy as np
import pytest

import jax

from repro.configs.base import (ATTN, RECURRENT, FrontendConfig, MLAConfig,
                                ModelConfig, MoEConfig, RecurrentConfig,
                                SSMConfig)

try:                      # property-based modules importorskip hypothesis
    from hypothesis import settings
except ImportError:       # suite must still collect without it
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


def tiny(name, **kw) -> ModelConfig:
    base = dict(name=name, family="dense", num_layers=3, d_model=64,
                num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                vocab_size=128, param_dtype="float32",
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


TINY_FAMILIES = {
    "dense": tiny("dense"),
    "dense-bias-qknorm": tiny("dense-bias-qknorm", qkv_bias=True,
                              qk_norm=True, num_kv_heads=2),
    "sliding": tiny("sliding", attention_kind="sliding", sliding_window=8),
    "mla": tiny("mla", attention_kind="mla",
                mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)),
    "moe": tiny("moe", family="moe",
                moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2,
                              d_ff_expert=32, first_dense_layers=1)),
    "hybrid": tiny("hybrid", family="hybrid", attention_kind="sliding",
                   sliding_window=8, num_layers=5,
                   recurrent=RecurrentConfig(
                       lru_width=64, d_conv=4,
                       block_pattern=(RECURRENT, RECURRENT, ATTN))),
    "ssm": tiny("ssm", family="ssm", attention_kind="none", num_kv_heads=0,
                d_ff=0, num_heads=8,
                ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4,
                              chunk_size=4)),
    "encdec": tiny("encdec", family="audio", encoder_layers=2,
                   frontend=FrontendConfig(kind="audio")),
    "vlm": tiny("vlm", family="vlm", num_kv_heads=2,
                frontend=FrontendConfig(kind="vision", num_patches=4)),
}


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(params=sorted(TINY_FAMILIES))
def family_cfg(request):
    return TINY_FAMILIES[request.param]
