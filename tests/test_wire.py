"""Zero-copy fixed-layout KV wire + shared-link congestion arbitration.

Covers the wirefmt codec end to end:

  * planned-vs-bound ``WireChunk`` round trip is bit-exact, and the host
    numpy encode path matches the legacy jnp ``precision.encode_wire``
    bit for bit (payloads AND int8 scales, per shard);
  * the fixed codec lands D pools bit-identical to the legacy pickle
    codec across wire formats × D vendor layouts × mismatched P/D block
    sizes (chunk boundaries straddling block edges → overlay re-page);
  * a chunk adopted in *another OS process* reads back the exact staged
    bytes through zero-copy views (and the two-process runtime is
    token-exact across codecs);
  * later chunks never clobber earlier ones (boundary-only overlay RMW,
    jnp and Pallas-kernel paths);
  * fair-share link arbitration: two concurrent flights on one modeled
    link each finish later than either alone, within tolerance of the
    processor-sharing prediction, and the extra time is accounted to
    ``congested_seconds``;
  * ``SharedMemoryConnector._get`` reuses its held mapping (no
    attach-by-name per read), and ``TransferStats`` splits wire bytes
    from raw payload bytes.
"""
import multiprocessing as mp

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.compat import precision
from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.transport import (InProcessConnector, ModeledRDMAConnector,
                                  SharedMemoryConnector, WireChunk,
                                  make_connector)
from repro.core.transport import wirefmt
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.paged_cache import (LAYOUTS, KVPageSpec, gather_sequence,
                                       scatter_sequence)
from repro.serving.request import Request
from tests.conftest import TINY_FAMILIES

WIRES = [WireFormat("raw", "float32"), WireFormat("raw", "bfloat16"),
         WireFormat("int8")]
WIRE_IDS = [f"{w.kind}-{w.dtype}" for w in WIRES]


def _entries(seed=0, tp_p=2, with_mla=True):
    """Synthetic normalized chunk entries (what ``prefill_stream`` emits)."""
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(3, 13, 4, 8)).astype(np.float32)
    v = rng.normal(size=(3, 13, 4, 8)).astype(np.float32)
    ents = [("kv", 0, 0, {"k": k, "v": v, "start": 5})]
    if with_mla:
        ckv = rng.normal(size=(2, 13, 16)).astype(np.float32)
        kpe = rng.normal(size=(2, 13, 8)).astype(np.float32)
        ents.append(("mla", 1, 0, {"ckv": ckv, "kpe": kpe, "start": 5}))
    return ents


def _entry_bytes(chunk):
    """Flat (payload_bytes, scales_bytes) per entry — dtype-agnostic."""
    out = []
    for e in chunk.entries():
        if e["kind"] == "mla":
            pay = b"".join(p.tobytes() for p in e["payloads"])
            sc = b"".join(s.tobytes() for s in e["scales"]
                          if s is not None)
        else:
            pay = e["payload"].tobytes()
            sc = b"" if e["scales"] is None else e["scales"].tobytes()
        out.append((e["kind"], e["gi"], e["start"], pay, sc))
    return out


# --------------------------------------------------------------------- #
# codec: planned vs bound round trip, legacy bit-parity
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("wire", WIRES, ids=WIRE_IDS)
def test_wirechunk_planned_vs_bound_bit_exact(wire):
    chunk = WireChunk.from_entries(_entries(), wire, tp_p=2, seq_len=13)
    # payload_nbytes counts *raw* source KV bytes (pre-cast/quantize);
    # nbytes is what actually crosses the wire
    assert chunk.nbytes > chunk.header_nbytes
    assert chunk.header_nbytes >= wirefmt.nominal_header_bytes(0)
    if wire.kind == "raw" and wire.dtype == "float32":
        # identity wire: only headers + slab alignment on top of payload
        assert chunk.header_nbytes + chunk.payload_nbytes <= chunk.nbytes \
            <= chunk.header_nbytes + chunk.payload_nbytes + 64 * 4
    else:
        assert chunk.nbytes < chunk.payload_nbytes   # compressed wire
    buf = bytearray(chunk.nbytes)
    chunk.write_into(buf)
    assert bytes(buf[:8]) == wirefmt.MAGIC
    bound = WireChunk.from_buffer(buf)
    assert bound.wire.kind == wire.kind
    assert bound.tp_p == 2 and bound.seq_len == 13
    assert bound.nbytes == chunk.nbytes
    assert bound.payload_nbytes == chunk.payload_nbytes
    assert _entry_bytes(bound) == _entry_bytes(chunk)
    bound.release()


@pytest.mark.parametrize("wire", WIRES, ids=WIRE_IDS)
def test_wirechunk_encode_matches_legacy_jnp(wire):
    """The single-pass numpy encode (cast / absmax-quantize through buffer
    views) is bit-identical to the legacy per-shard jnp encode — payloads
    and int8 scales both, so fixed-codec pools can equal pickle pools."""
    ents = _entries(seed=1, with_mla=False)
    _, _, _, ent = ents[0]
    k, v = ent["k"], ent["v"]
    count, s, kv_heads, hd = k.shape
    tp_p = 2
    chunk = WireChunk.from_entries(ents, wire, tp_p=tp_p, seq_len=s)
    (e,) = chunk.entries()
    pay, sc = e["payload"], e["scales"]          # (2·tp, count, s, kvs, hd)
    if sc is not None:
        sc = sc.reshape(2 * tp_p, count, s, kv_heads // tp_p, 1)
    shards = np.split(k, tp_p, axis=2) + np.split(v, tp_p, axis=2)
    for i, sh in enumerate(shards):
        lp, ls = precision.encode_wire(
            jnp.asarray(sh).reshape(-1, sh.shape[2], hd), wire)
        got = pay[i].reshape(count * s, kv_heads // tp_p, hd)
        assert np.asarray(lp).tobytes() == np.asarray(got).tobytes(), i
        if ls is not None:
            got_s = sc[i].reshape(count * s, kv_heads // tp_p, 1)
            assert np.asarray(ls).tobytes() == got_s.tobytes(), i
    chunk.release()


def test_wirechunk_header_overhead_is_fixed_and_small():
    wire = WireFormat("raw", "float32")
    one = WireChunk.from_entries(_entries(with_mla=False), wire, 2, 13)
    assert one.header_nbytes <= wirefmt.nominal_header_bytes(2, 2)
    # headers don't scale with tokens — only with entry count
    big_ents = _entries(seed=2, with_mla=False)
    big_ents[0][3]["k"] = np.repeat(big_ents[0][3]["k"], 4, axis=1)
    big_ents[0][3]["v"] = np.repeat(big_ents[0][3]["v"], 4, axis=1)
    big = WireChunk.from_entries(big_ents, wire, 2, 52)
    assert big.header_nbytes == one.header_nbytes


# --------------------------------------------------------------------- #
# fixed vs pickle codec: bit-identical D pools (in-process)
# --------------------------------------------------------------------- #
def _pd_pair(cfg, params, vd, bs_p=8):
    vp = VendorProfile("B", block_size=bs_p, layout="nhbd",
                       kv_dtype="float32", tp=2)
    p = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
               max_seq_len=64, role="prefill")
    d = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
               max_seq_len=64, role="decode")
    return p, d


def _req(cfg, plen, rid="r0", seed=3):
    rng = np.random.default_rng(seed)
    return Request(req_id=rid,
                   prompt=rng.integers(0, cfg.vocab_size,
                                       plen).astype(np.int32),
                   max_new_tokens=4)


def _stream_pools(cfg, params, vd, wire, codec, backend="inproc",
                  chunk_tokens=5, repage_kernel=False):
    p, d = _pd_pair(cfg, params, vd)
    conn = make_connector(backend)
    pipe = DisaggPipeline(conn, wire, codec=codec,
                          repage_kernel=repage_kernel)
    pipe.handoff_streamed(_req(cfg, plen=13), p, d, chunk_tokens=chunk_tokens,
                          chunked_compute=False)
    assert conn.pool.in_use == 0
    if hasattr(conn, "_deferred_close"):
        assert conn._deferred_close == []      # all views released
    conn.close()
    return d


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("wire", WIRES, ids=WIRE_IDS)
def test_fixed_codec_pools_equal_pickle_codec(wire, layout):
    """Acceptance: across wire formats × D vendor layouts (with D blocks
    of 4 vs 5-token chunks vs P blocks of 8 — boundaries straddle block
    edges on both sides), the zero-copy fixed codec lands D pools
    bit-identical to the legacy pickled wire."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout=layout,
                       kv_dtype="float32")
    d_fix = _stream_pools(cfg, params, vd, wire, "fixed", backend="shm")
    d_leg = _stream_pools(cfg, params, vd, wire, "pickle")
    for a, b in zip(jax.tree.leaves(d_fix.caches),
                    jax.tree.leaves(d_leg.caches)):
        assert a.dtype == b.dtype
        assert bool(jnp.array_equal(a, b)), (wire.kind, layout)
    assert d_fix.decode_step()[0][2] == d_leg.decode_step()[0][2]


@pytest.mark.parametrize("family", ["mla", "hybrid"])
def test_fixed_codec_pools_equal_pickle_codec_other_families(family):
    """mla (latent-KV entries, 2 parts/entry) and hybrid (KV + recurrent
    tail states through the pickle side channel) stream bit-identically
    under the fixed codec."""
    cfg = TINY_FAMILIES[family]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nbhd",
                       kv_dtype="float32")
    wire = WireFormat("int8")
    d_fix = _stream_pools(cfg, params, vd, wire, "fixed", backend="shm")
    d_leg = _stream_pools(cfg, params, vd, wire, "pickle")
    for a, b in zip(jax.tree.leaves(d_fix.caches),
                    jax.tree.leaves(d_leg.caches)):
        assert bool(jnp.array_equal(a, b)), family
    assert d_fix.decode_step()[0][2] == d_leg.decode_step()[0][2]


def test_repage_kernel_path_matches_jnp_path():
    """The Pallas overlay-scatter re-page (partial blocks merged inside
    the kernel) lands the same pools as the jnp boundary-RMW path."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vd = VendorProfile("A", block_size=4, layout="nhbd",
                       kv_dtype="float32")
    wire = WireFormat("raw", "float32")
    d_jnp = _stream_pools(cfg, params, vd, wire, "fixed")
    d_ker = _stream_pools(cfg, params, vd, wire, "fixed",
                          repage_kernel=True)
    for a, b in zip(jax.tree.leaves(d_jnp.caches),
                    jax.tree.leaves(d_ker.caches)):
        assert bool(jnp.array_equal(a, b))


# --------------------------------------------------------------------- #
# overlay re-page: later chunks never clobber earlier ones
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("bs,chunk", [(4, 5), (8, 6), (4, 3)])
def test_overlay_chunk_sequence_never_clobbers(layout, bs, chunk):
    """Stream S=13 tokens in ``chunk``-token pieces into ``bs``-token
    blocks (boundaries straddle): after every chunk the previously landed
    prefix is bit-intact, and the final pool equals a one-shot scatter."""
    rng = np.random.default_rng(0)
    spec = KVPageSpec(block_size=bs, layout=layout, dtype="float32",
                      kv_heads=2, head_dim=4)
    S, L, N = 13, 3, 16
    nb = spec.blocks_for(S)
    pool = jnp.asarray(rng.normal(size=(L,) + spec.pool_shape(N))
                       .astype(np.float32))
    ids = np.asarray([3, 9, 1, 6][:nb], np.int32)
    stream = jnp.asarray(rng.normal(size=(L, S, 2, 4)).astype(np.float32))

    for kernel in (False, True):
        cur = pool
        for st in range(0, S, chunk):
            cn = stream[:, st:st + chunk]
            cur = DisaggPipeline._write_pages_vec(spec, cur, ids, cn, st,
                                                  rmw=True, kernel=kernel)
            got = jax.vmap(lambda pl: gather_sequence(spec, pl, ids,
                                                      min(st + chunk, S))
                           )(cur)
            assert bool(jnp.array_equal(got,
                                        stream[:, :st + chunk])), \
                (layout, bs, chunk, st, kernel)
        ref = jax.vmap(lambda pl, cn: scatter_sequence(
            spec, pl, jnp.asarray(ids), cn))(pool, stream)
        # the overlay stream and the one-shot scatter agree on every row
        # the stream covered (the one-shot zero-fills tail padding)
        got = jax.vmap(lambda pl: gather_sequence(spec, pl, ids, S))(cur)
        want = jax.vmap(lambda pl: gather_sequence(spec, pl, ids, S))(ref)
        assert bool(jnp.array_equal(got, want)), (layout, bs, chunk, kernel)
        # untouched pool pages are preserved
        mask = np.ones(N, bool)
        mask[ids] = False
        assert bool(jnp.array_equal(cur[:, mask], pool[:, mask]))


@pytest.mark.parametrize("start", [0, 3, 5])
def test_write_pages_vec_matches_legacy_write_pages(start):
    rng = np.random.default_rng(1)
    spec = KVPageSpec(block_size=4, layout="nhdb", dtype="bfloat16",
                      kv_heads=2, head_dim=4)
    L, N, S = 2, 12, 7
    pool = jnp.asarray(rng.normal(size=(L,) + spec.pool_shape(N))
                       .astype(np.float32)).astype(spec.jdtype)
    ids = jnp.asarray(range(spec.blocks_for(start + S)), jnp.int32)
    canon = jnp.asarray(rng.normal(size=(L, S, 2, 4)).astype(np.float32))
    legacy = DisaggPipeline._write_pages(spec, pool, ids, canon, start,
                                         rmw=True)
    vec = DisaggPipeline._write_pages_vec(spec, pool, ids, canon, start,
                                          rmw=True)
    ker = DisaggPipeline._write_pages_vec(spec, pool, ids, canon, start,
                                          rmw=True, kernel=True)
    assert bool(jnp.array_equal(legacy, vec))
    assert bool(jnp.array_equal(legacy, ker))


# --------------------------------------------------------------------- #
# cross-process: adopted segment reads the exact staged bytes, zero-copy
# --------------------------------------------------------------------- #
def _adopt_and_dump(desc, q):
    """Child: adopt the staged segment, read it, ship the bytes home."""
    from repro.core.transport import SharedMemoryConnector
    conn = SharedMemoryConnector()
    try:
        conn.adopt_segment(desc["key"], desc["segment"], desc["nbytes"])
        payload, meta = conn.issue_read(desc["key"]).wait()
        ents = [(k, gi, st, pay, sc)
                for k, gi, st, pay, sc in _entry_bytes(payload)]
        m = (meta["wire"].kind, meta["tp_p"], meta["seq_len"])
        payload.release()
        conn.complete(desc["key"])
        q.put(("ok", ents, m))
    except Exception as e:                     # noqa: BLE001 — report home
        q.put(("err", repr(e), None))
    finally:
        conn.close()


@pytest.mark.parametrize("wire", WIRES, ids=WIRE_IDS)
def test_cross_process_adopted_chunk_is_bit_exact(wire):
    conn = SharedMemoryConnector()
    chunk = WireChunk.from_entries(_entries(seed=4), wire, tp_p=2,
                                   seq_len=13)
    want = _entry_bytes(chunk)                 # planned-side reference
    conn.stage("x@P0#c0", chunk, chunk.meta())
    ctx = mp.get_context("spawn")
    q = ctx.Queue()
    proc = ctx.Process(target=_adopt_and_dump,
                       args=(conn.export_descriptor("x@P0#c0"), q))
    proc.start()
    status, ents, m = q.get(timeout=120)
    proc.join(timeout=30)
    assert status == "ok", ents
    assert m == (wire.kind, 2, 13)
    assert ents == [(k, gi, st, pay, sc) for k, gi, st, pay, sc in want]
    conn.complete("x@P0#c0")
    assert conn.pool.in_use == 0
    conn.close()


def test_cross_process_runtime_codec_parity():
    """The real 1P+1D runtime (separate OS processes, KV over adopted shm
    segments) is token-exact between the zero-copy fixed codec and the
    legacy pickle codec, and the fixed wire's stats split survives the
    trip home through the workers' merged TransferStats."""
    from tests.test_multiproc import (CHUNK, VENDOR_D, VENDOR_P, _requests,
                                      _shm_files, _spec)
    from repro.serving.multiproc.launcher import serve_two_process
    before = _shm_files()
    tokens = {}
    stats = {}
    for codec in ("fixed", "pickle"):
        tokens[codec], rt = serve_two_process(
            _spec("P0", VENDOR_P, "prefill"), _spec("D0", VENDOR_D, "decode"),
            _requests(n=2), prefill_chunk=CHUNK, codec=codec,
            max_wall_s=300.0)
        assert rt.stats.finished == 2
        stats[codec] = rt.transfer_stats
    assert tokens["fixed"] == tokens["pickle"]
    assert stats["fixed"].payload_bytes > 0          # wire/raw split home
    assert stats["fixed"].bytes_moved > 0
    after = _shm_files()
    if before is not None:
        assert after - before == set()


# --------------------------------------------------------------------- #
# link congestion: fair-share arbitration + measured attribution
# --------------------------------------------------------------------- #
def test_fair_share_two_flights_slower_than_alone_but_equal():
    """Two equal concurrent reads on one fair-share link: each finishes
    later than it would alone (the link is genuinely shared), both finish
    together within tolerance, and the extra time is accounted."""
    B = 10_000_000
    conn = ModeledRDMAConnector(bandwidth_gbps=0.01, fixed_latency_s=0.1,
                                tick_seconds=0.05)
    assert conn.capabilities().link_sharing == "fair"
    conn.stage("a", {"x": np.zeros(B, np.uint8)})
    conn.stage("b", {"x": np.zeros(B, np.uint8)})
    ha = conn.issue_read("a")
    hb = conn.issue_read("b")
    alone = 0.1 + B / 0.01e9                   # 1.1 s
    shared = 0.1 + 2 * B / 0.01e9              # 2.1 s (processor sharing)
    t, t_a = 0.0, None
    while not (ha.poll() and hb.poll()):
        conn.tick()
        t += conn.tick_seconds
        if t_a is None and ha.poll():
            t_a = t
        assert t < 10.0, "fair-share link never delivered"
    # neither flight finished in its alone-on-the-link time
    assert t_a is not None and t_a > alone + 0.5
    # fair: both flights completed on the same tick (equal progress)
    assert t_a == pytest.approx(t)
    assert t == pytest.approx(shared, abs=2 * conn.tick_seconds)
    ha.wait()
    hb.wait()
    assert conn.stats.congested_seconds == \
        pytest.approx(2 * (shared - alone), abs=0.01)
    assert conn.stats.concurrent_reads_peak == 2
    conn.complete("a")
    conn.complete("b")
    conn.close()


def test_fair_share_wait_fast_forwards_through_contention():
    B = 10_000_000
    conn = ModeledRDMAConnector(bandwidth_gbps=0.01, fixed_latency_s=0.1)
    conn.stage("a", {"x": np.zeros(B, np.uint8)})
    conn.stage("b", {"x": np.zeros(B, np.uint8)})
    ha = conn.issue_read("a")
    hb = conn.issue_read("b")
    ha.wait()
    assert conn._now == pytest.approx(0.1 + 2 * B / 0.01e9)
    hb.wait()                                  # already done: no advance
    assert conn._now == pytest.approx(0.1 + 2 * B / 0.01e9)
    assert conn.stats.contended_read_seconds > 0   # measured attribution
    conn.complete("a")
    conn.complete("b")
    conn.close()


def test_cancelled_flight_stops_charging_the_link():
    """A cancelled read leaves the fair-share link: the survivor drains at
    full bandwidth afterwards."""
    B = 10_000_000
    conn = ModeledRDMAConnector(bandwidth_gbps=0.01, fixed_latency_s=0.0)
    conn.stage("a", {"x": np.zeros(B, np.uint8)})
    conn.stage("b", {"x": np.zeros(B, np.uint8)})
    ha = conn.issue_read("a")
    hb = conn.issue_read("b")
    hb.cancel()
    ha.wait()
    assert conn._now == pytest.approx(B / 0.01e9)  # alone time, no sharing
    conn.close()


# --------------------------------------------------------------------- #
# shm: held-mapping reuse, zero-copy stage, stats split
# --------------------------------------------------------------------- #
def test_shm_get_reuses_held_mapping(monkeypatch):
    """A read never re-attaches the segment by name: staging (P) and
    adoption (D) each attach once, and ``_get`` reuses that mapping."""
    import repro.core.transport.shared_memory as shm_mod
    conn = SharedMemoryConnector()
    chunk = WireChunk.from_entries(_entries(with_mla=False),
                                   WireFormat("raw", "float32"), 2, 13)
    conn.stage("zc", chunk, chunk.meta())
    conn.stage("legacy", {"x": np.arange(8)}, {})
    attaches = []
    real = shm_mod.shared_memory.SharedMemory

    class Counting(real):
        def __init__(self, *a, **kw):
            attaches.append((a, kw))
            super().__init__(*a, **kw)

    monkeypatch.setattr(shm_mod.shared_memory, "SharedMemory", Counting)
    pay, _ = conn.issue_read("zc").wait()
    assert isinstance(pay, WireChunk)
    pay.release()
    conn.issue_read("legacy").wait()
    assert attaches == []                      # no attach-by-name per read
    conn.complete("zc")
    conn.complete("legacy")
    conn.close()


def test_shm_stages_wirechunk_zero_copy_and_splits_stats():
    conn = SharedMemoryConnector()
    for key, wire in (("raw", WireFormat("raw", "float32")),
                      ("int8", WireFormat("int8"))):
        chunk = WireChunk.from_entries(_entries(with_mla=False), wire, 2, 13)
        n = conn.stage(key, chunk, chunk.meta())
        assert n == chunk.nbytes               # segment == wire layout
        pay, meta = conn.issue_read(key).wait()
        assert isinstance(pay, WireChunk) and meta["wire"].kind == wire.kind
        pay.release()
        conn.complete(key)
    # raw f32 over f32 source: wire ≈ payload + headers (ratio slightly >1)
    # int8: wire ≈ payload/4 + scales — the split exposes the compression
    assert conn.stats.payload_bytes > conn.stats.bytes_moved
    assert conn.stats.wire_compression < 1.0
    assert conn.stats.transfers == 2
    assert conn.pool.in_use == 0 and conn._deferred_close == []
    conn.close()


def test_capabilities_declare_codec_and_sharing():
    inproc = InProcessConnector().capabilities()
    shm = SharedMemoryConnector().capabilities()
    fair = ModeledRDMAConnector().capabilities()
    serial = ModeledRDMAConnector(link_sharing="serial").capabilities()
    for caps in (inproc, shm, fair):
        assert caps.wire_codec == "fixed"
        assert caps.header_bytes == wirefmt.nominal_header_bytes()
    assert shm.zero_copy and shm.cross_process
    assert fair.link_sharing == "fair"
    assert serial.link_sharing == "exclusive"


# --------------------------------------------------------------------- #
# planner: connector-sourced wire model knows headers and link sharing
# --------------------------------------------------------------------- #
def test_connector_wire_time_headers_and_concurrency():
    from repro.core.planner.simulator import connector_wire_time
    nbytes = 1e6
    flat = InProcessConnector(bandwidth_gbps=25.0).capabilities()
    hdr = flat.header_bytes
    assert hdr > 0
    assert connector_wire_time(nbytes, flat) == \
        pytest.approx((nbytes + hdr) / 25e9)
    fair = ModeledRDMAConnector(bandwidth_gbps=25.0,
                                fixed_latency_s=1e-3).capabilities()
    serial = ModeledRDMAConnector(bandwidth_gbps=25.0, fixed_latency_s=1e-3,
                                  link_sharing="serial").capabilities()
    one = 1e-3 + (nbytes + hdr) / 25e9
    # fair share: n flights divide bandwidth, one setup latency each
    assert connector_wire_time(nbytes, fair, concurrent=3) == \
        pytest.approx(1e-3 + 3 * (nbytes + hdr) / 25e9)
    # exclusive link: the last read waits out the queue
    assert connector_wire_time(nbytes, serial, concurrent=3) == \
        pytest.approx(3 * one)
    assert connector_wire_time(nbytes, fair, concurrent=1) == \
        pytest.approx(one)
    assert connector_wire_time(0, fair, concurrent=4) == 0.0
