"""Planner: simulator monotonicity properties (hypothesis), two-stage
optimizer constraint satisfaction, and the paper's qualitative claims."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.configs.base import get_config
from repro.core.planner import events
from repro.core.planner.hardware import GPU_A, GPU_B, REGISTRY, get
from repro.core.planner.optimizer import (optimize_decode, optimize_prefill,
                                          plan_deployment)
from repro.core.planner.simulator import InstanceModel, ParallelStrategy
from repro.core.planner.workload import FIG8, Workload

LLAMA = get_config("llama2-7b")


# --------------------------------------------------------------------------- #
# Simulator monotonicity (the properties the optimizer relies on)
# --------------------------------------------------------------------------- #
@given(s1=st.integers(64, 2048), s2=st.integers(64, 2048))
def test_prefill_latency_monotone_in_seq(s1, s2):
    m = InstanceModel(LLAMA, GPU_A, ParallelStrategy())
    lo, hi = min(s1, s2), max(s1, s2)
    assert m.prefill_latency(lo) <= m.prefill_latency(hi) + 1e-9


@given(b1=st.integers(1, 128), b2=st.integers(1, 128))
def test_decode_latency_monotone_in_batch(b1, b2):
    m = InstanceModel(LLAMA, GPU_A, ParallelStrategy())
    lo, hi = min(b1, b2), max(b1, b2)
    assert m.decode_latency(lo, 512) <= m.decode_latency(hi, 512) + 1e-9


@given(tp=st.sampled_from([1, 2, 4, 8]))
def test_tp_shards_weights(tp):
    m = InstanceModel(LLAMA, GPU_A, ParallelStrategy(tp=tp))
    base = InstanceModel(LLAMA, GPU_A, ParallelStrategy())
    np.testing.assert_allclose(m.weight_bytes_per_gpu(),
                               base.weight_bytes_per_gpu() / tp, rtol=1e-6)


@given(seq=st.integers(128, 4096))
def test_vram_decode_grows_with_batch(seq):
    m = InstanceModel(LLAMA, GPU_A, ParallelStrategy())
    assert m.vram_decode(1, seq) < m.vram_decode(16, seq)


def test_faster_hbm_decodes_faster():
    fast = InstanceModel(LLAMA, GPU_A, ParallelStrategy())   # 2 TB/s HBM
    slow = InstanceModel(LLAMA, GPU_B, ParallelStrategy())   # 1 TB/s HBM
    assert fast.decode_latency(16, 1024) < slow.decode_latency(16, 1024)


def test_more_tflops_prefills_faster():
    a = InstanceModel(LLAMA, GPU_A, ParallelStrategy())      # 312 TF
    b = InstanceModel(LLAMA, GPU_B, ParallelStrategy())      # 512 TF
    assert b.prefill_latency(1024) < a.prefill_latency(1024)


# --------------------------------------------------------------------------- #
# Two-stage optimizer (paper Eqs. 1 & 4)
# --------------------------------------------------------------------------- #
def test_stage1_respects_constraints():
    wl = Workload(qps=2.0, input_len=1024, output_len=1024,
                  slo_ttft_s=0.5, slo_tpot_s=0.05)
    res = optimize_prefill(LLAMA, GPU_B, wl)
    m = InstanceModel(LLAMA, GPU_B, res.strategy)
    assert m.prefill_latency(wl.input_len) <= wl.slo_ttft_s       # (c1)
    assert m.fits(m.vram_prefill(wl.input_len))                   # (c2)
    assert res.candidates_evaluated > 10


def test_stage2_respects_constraints_and_covers_qps():
    wl = Workload(qps=2.0, input_len=1024, output_len=1024,
                  slo_ttft_s=0.5, slo_tpot_s=0.05)
    res, y = optimize_decode(LLAMA, GPU_A, wl, required_qps=2.0)
    assert res.latency_s <= wl.slo_tpot_s                         # (c1)
    assert y * res.instance_capacity >= 2.0 * 0.999               # coverage


def test_infeasible_slo_raises():
    wl = Workload(qps=2.0, input_len=4096, output_len=64,
                  slo_ttft_s=1e-4)
    with pytest.raises(ValueError):
        optimize_prefill(LLAMA, GPU_B, wl)


def test_plan_deployment_end_to_end():
    plan = plan_deployment(LLAMA, FIG8, p_hw=GPU_B, d_hw=GPU_A)
    assert plan.n_prefill >= 1 and plan.n_decode >= 1
    assert plan.qps_capacity >= FIG8.qps * 0.99
    assert plan.cost_per_hour > 0
    assert "P" in plan.ratio() and "D" in plan.ratio()


def test_tighter_slo_needs_no_fewer_instances():
    loose = Workload(qps=3.0, input_len=1024, output_len=512,
                     slo_ttft_s=2.0, slo_tpot_s=0.2)
    tight = Workload(qps=3.0, input_len=1024, output_len=512,
                     slo_ttft_s=0.2, slo_tpot_s=0.03)
    pl = plan_deployment(LLAMA, loose, GPU_B, GPU_A)
    pt = plan_deployment(LLAMA, tight, GPU_B, GPU_A)
    assert pt.n_prefill * pt.prefill.strategy.gpus \
        + pt.n_decode * pt.decode.strategy.gpus \
        >= pl.n_prefill * pl.prefill.strategy.gpus \
        + pl.n_decode * pl.decode.strategy.gpus


# --------------------------------------------------------------------------- #
# Event simulator reproduces the paper's qualitative results
# --------------------------------------------------------------------------- #
def _models():
    return (InstanceModel(LLAMA, GPU_B, ParallelStrategy()),
            InstanceModel(LLAMA, GPU_A, ParallelStrategy()))


def test_disagg_beats_integrated_at_long_context():
    """Paper Figs. 9-10: cost-fair (same hardware pair), long context."""
    wl = Workload(qps=2.0, input_len=1024, output_len=1024)
    mP, mD = _models()
    r_dis = events.simulate(LLAMA, wl, p_model=mP, d_model=mD,
                            n_prefill=1, n_decode=1, duration_s=60)
    r_int = events.simulate(LLAMA, wl, p_model=mP, d_model=mD,
                            n_prefill=1, n_decode=1, mode="integrated",
                            duration_s=60)
    assert r_dis.throughput_tok_s() > r_int.throughput_tok_s()
    assert r_dis.tpot_mean() < r_int.tpot_mean()


def test_pd_ratio_saturates_short_context():
    """Paper Fig. 7: 2P1D ≈ 3P1D at 256+256 QPS2."""
    wl = Workload(qps=2.0, input_len=256, output_len=256)
    mP, mD = _models()
    tput = {}
    for n_p in (2, 3):
        r = events.simulate(LLAMA, wl, p_model=mP, d_model=mD,
                            n_prefill=n_p, n_decode=1, duration_s=60)
        tput[n_p] = r.throughput_tok_s()
    assert abs(tput[2] - tput[3]) / tput[2] < 0.05


def test_ttft_grows_with_input_flat_in_output():
    """Paper Fig. 6(a)."""
    mP, mD = _models()
    base = events.simulate(LLAMA, Workload(2, 256, 256), p_model=mP,
                           d_model=mD, duration_s=40)
    long_in = events.simulate(LLAMA, Workload(2, 1024, 256), p_model=mP,
                              d_model=mD, duration_s=40)
    long_out = events.simulate(LLAMA, Workload(2, 256, 1024), p_model=mP,
                               d_model=mD, duration_s=40)
    assert long_in.ttft_mean() > base.ttft_mean() * 1.5
    assert abs(long_out.ttft_mean() - base.ttft_mean()) \
        < 0.3 * base.ttft_mean()
