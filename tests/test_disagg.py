"""System-level disaggregation tests: the heterogeneous P→D handoff must be
token-exact vs the integrated baseline across vendor mismatches — the
strongest correctness check of the paper's compatible-transmission module."""
import numpy as np
import pytest

import jax

from repro.core.compat.precision import WireFormat
from repro.core.disagg import DisaggPipeline
from repro.core.kv_transfer import TransferEngine
from repro.models import model as M
from repro.serving.engine import Engine, VendorProfile
from repro.serving.request import Request
from repro.serving.scheduler import GlobalScheduler
from repro.serving.server import Server
from tests.conftest import TINY_FAMILIES


def _mk_requests(cfg, n=3, mem_len=10, seed=7):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(5, 12))
        r = Request(req_id=f"r{i}",
                    prompt=rng.integers(0, cfg.vocab_size,
                                        plen).astype(np.int32),
                    max_new_tokens=6)
        if cfg.is_enc_dec:
            r.frames = rng.normal(size=(mem_len, cfg.d_model)
                                  ).astype(np.float32)
        if cfg.frontend.kind == "vision":
            r.patches = rng.normal(size=(cfg.frontend.num_patches,
                                         cfg.d_model)).astype(np.float32)
        reqs.append(r)
    return reqs


def _serve(cfg, params, instances, wire=None, n=3, mem_len=10):
    pipe = DisaggPipeline(TransferEngine(),
                          wire or WireFormat("raw", "float32"))
    sched = GlobalScheduler(pipe)
    for e in instances:
        sched.add_instance(e)
    reqs = _mk_requests(cfg, n=n, mem_len=mem_len)
    Server(sched).serve(reqs, max_ticks=300)
    assert all(r.done for r in reqs), "scheduler lost a request"
    return {r.req_id: list(r.output_tokens) for r in reqs}, pipe


@pytest.mark.parametrize("family,vp,vd", [
    ("dense",
     VendorProfile("B", block_size=8, layout="nhbd", kv_dtype="float32", tp=4),
     VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32", tp=1)),
    ("sliding",
     VendorProfile("B", block_size=4, layout="nhdb", kv_dtype="float32", tp=2),
     VendorProfile("A", block_size=8, layout="nbhd", kv_dtype="float32", tp=4)),
    ("mla",
     VendorProfile("B", block_size=8, layout="nhbd", kv_dtype="float32", tp=2),
     VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32", tp=1)),
    ("hybrid",
     VendorProfile("B", block_size=8, layout="nbhd", kv_dtype="float32", tp=1),
     VendorProfile("A", block_size=4, layout="nhbd", kv_dtype="float32", tp=1)),
    ("ssm",
     VendorProfile("B", block_size=8, layout="nbhd", kv_dtype="float32", tp=1),
     VendorProfile("A", block_size=8, layout="nbhd", kv_dtype="float32", tp=1)),
    ("encdec",
     VendorProfile("B", block_size=8, layout="nhbd", kv_dtype="float32", tp=2),
     VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32", tp=1)),
    ("vlm",
     VendorProfile("B", block_size=8, layout="nbhd", kv_dtype="float32", tp=2),
     VendorProfile("A", block_size=4, layout="nhdb", kv_dtype="float32", tp=1)),
])
def test_disagg_equals_integrated(family, vp, vd):
    cfg = TINY_FAMILIES[family]
    params = M.init_params(jax.random.key(1), cfg)
    mem_len = 10 if cfg.is_enc_dec else 0
    p_eng = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
                   max_seq_len=64, mem_len=mem_len, role="prefill")
    d_eng = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
                   max_seq_len=64, mem_len=mem_len, role="decode")
    out_d, pipe = _serve(cfg, params, [p_eng, d_eng], mem_len=mem_len)
    assert pipe.transfer.stats.transfers == 3
    assert pipe.transfer.stats.bytes_moved > 0

    both = Engine("I0", cfg, params,
                  VendorProfile("A", block_size=8, layout="nbhd",
                                kv_dtype="float32", tp=1),
                  num_blocks=64, max_batch=4, max_seq_len=64,
                  mem_len=mem_len, role="both")
    out_i, _ = _serve(cfg, params, [both], mem_len=mem_len)
    assert out_d == out_i


def test_int8_wire_greedy_tokens_survive():
    """Beyond-paper int8 wire: greedy decode should almost always match —
    require ≥80% token agreement on a tiny model (quantization noise)."""
    cfg = TINY_FAMILIES["dense"]
    params = M.init_params(jax.random.key(1), cfg)
    vp = VendorProfile("B", block_size=8, layout="nhbd", kv_dtype="float32",
                       tp=2)
    vd = VendorProfile("A", block_size=4, layout="nbhd", kv_dtype="float32")
    p_eng = Engine("P0", cfg, params, vp, num_blocks=64, max_batch=4,
                   max_seq_len=64, role="prefill")
    d_eng = Engine("D0", cfg, params, vd, num_blocks=64, max_batch=4,
                   max_seq_len=64, role="decode")
    out_q, pipe_q = _serve(cfg, params, [p_eng, d_eng],
                           wire=WireFormat("int8"))
    both = Engine("I0", cfg, params, vd, num_blocks=64, max_batch=4,
                  max_seq_len=64, role="both")
    out_r, pipe_r = _serve(cfg, params, [both])
    agree = total = 0
    for rid in out_q:
        for a, b in zip(out_q[rid], out_r[rid]):
            agree += int(a == b)
            total += 1
    assert agree / total >= 0.8, (agree, total)


def test_wire_bytes_smaller_for_mla_than_dense():
    """MLA's latent cache must ship far fewer bytes than dense GQA — the
    transfer-volume ordering the planner relies on."""
    results = {}
    for fam in ("dense", "mla"):
        cfg = TINY_FAMILIES[fam]
        params = M.init_params(jax.random.key(1), cfg)
        p_eng = Engine("P0", cfg, params,
                       VendorProfile("B", block_size=8), num_blocks=64,
                       max_batch=4, max_seq_len=64, role="prefill")
        d_eng = Engine("D0", cfg, params,
                       VendorProfile("A", block_size=8), num_blocks=64,
                       max_batch=4, max_seq_len=64, role="decode")
        _, pipe = _serve(cfg, params, [p_eng, d_eng])
        results[fam] = pipe.transfer.stats.bytes_moved
    assert results["mla"] < results["dense"]
