"""Layer-level unit tests: norms, RoPE, attention equivalences, recurrent
blocks — the numerics the whole system rests on."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import dist
from repro.models import layers as L
from tests.conftest import tiny


def test_rms_norm_matches_numpy():
    x = np.random.default_rng(0).normal(size=(4, 8)).astype(np.float32)
    w = np.linspace(0.5, 1.5, 8).astype(np.float32)
    got = L.rms_norm(jnp.asarray(w), jnp.asarray(x), 1e-6)
    want = x / np.sqrt((x ** 2).mean(-1, keepdims=True) + 1e-6) * w
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


def test_rope_rotation_preserves_norm_and_relative_angle():
    q = jax.random.normal(jax.random.key(0), (1, 6, 2, 8))
    pos = jnp.arange(6)[None]
    r = L.apply_rope(q, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1),
                               rtol=1e-5)
    # for FIXED content vectors, dot(rope(q,i), rope(k,j)) depends on i-j only
    q1 = jnp.broadcast_to(jax.random.normal(jax.random.key(2), (1, 1, 2, 8)),
                          (1, 6, 2, 8))
    k1 = jnp.broadcast_to(jax.random.normal(jax.random.key(3), (1, 1, 2, 8)),
                          (1, 6, 2, 8))
    s = jnp.einsum("bqhd,bkhd->bhqk", L.apply_rope(q1, pos, 10_000.0),
                   L.apply_rope(k1, pos, 10_000.0))
    s = np.asarray(s)[0, 0]
    np.testing.assert_allclose(s[2, 1], s[3, 2], atol=1e-4)
    np.testing.assert_allclose(s[4, 1], s[5, 2], atol=1e-4)


def test_sdpa_gqa_matches_repeated_heads():
    b, s, h, kv, hd = 2, 5, 4, 2, 8
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    mask = L.causal_mask(s, s)
    got = L.sdpa(q, k, v, mask)
    krep = jnp.repeat(k, h // kv, axis=2)
    vrep = jnp.repeat(v, h // kv, axis=2)
    want = L.sdpa(q, krep, vrep, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_chunked_sdpa_equals_masked_sdpa(window, chunk):
    b, s, h, kv, hd = 2, 33, 4, 2, 16
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    lengths = jnp.asarray([33, 18])
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos = jnp.where(pos < lengths[:, None], pos, -1)
    ref = L.sdpa(q, k, v, L.causal_mask(s, s, 0, window)
                 + L.length_mask(lengths, s))
    out = L.chunked_sdpa(q, k, v, pos, pos, causal=True, window=window,
                         chunk=chunk)
    for i, n in enumerate([33, 18]):
        np.testing.assert_allclose(np.asarray(out)[i, :n],
                                   np.asarray(ref)[i, :n], atol=2e-5)


def test_kv_cache_ring_buffer_sliding_window():
    """Writes past capacity wrap; decode equals full-context reference."""
    cfg = tiny("swa", attention_kind="sliding", sliding_window=4,
               num_layers=1)
    p = L.init_attention(jax.random.key(0), cfg)
    b, steps = 1, 10
    xs = jax.random.normal(jax.random.key(1), (b, steps, cfg.d_model))
    # reference: full forward
    pos_full = jnp.arange(steps)[None]
    ref, _ = L.attention_block(p, cfg, xs, pos_full, causal=True,
                               window=cfg.sliding_window)
    # decode: step one token at a time through a window-sized ring cache
    cache = L.kv_cache_init(b, cfg.sliding_window, cfg.num_kv_heads,
                            cfg.hd, jnp.float32)
    outs = []
    for t in range(steps):
        o, cache = L.attention_decode(p, cfg, xs[:, t:t + 1],
                                      jnp.asarray([[t]]),
                                      cache, window=cfg.sliding_window)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_mla_decode_matches_prefill_logits():
    cfg = tiny("mla", attention_kind="mla",
               mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                             qk_rope_head_dim=8, v_head_dim=16),
               num_layers=1)
    p = L.init_mla(jax.random.key(0), cfg)
    b, s = 1, 7
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model))
    pos = jnp.arange(s)[None]
    full, (ckv, kpe) = L.mla_block(p, cfg, x, pos)
    cache = L.mla_cache_init(b, s, cfg, jnp.float32)
    cache = L.mla_cache_write(cache, ckv[:, :s - 1], kpe[:, :s - 1],
                              pos[:, :s - 1])
    dec, _ = L.mla_decode(p, cfg, x[:, -1:], pos[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)


def test_ssd_decode_matches_block():
    cfg = tiny("ssm", family="ssm", attention_kind="none", num_kv_heads=0,
               d_ff=0, num_heads=8, num_layers=1)
    from repro.configs.base import SSMConfig
    cfg = cfg.with_(ssm=SSMConfig(d_state=16, head_dim=16, expand=2,
                                  d_conv=4, chunk_size=4))
    p = L.init_ssd(jax.random.key(0), cfg)
    b, s = 2, 9
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model))
    full, _ = L.ssd_block(p, cfg, x, L.ssm_state_init(b, cfg, jnp.float32))
    st = L.ssm_state_init(b, cfg, jnp.float32)
    outs = []
    for t in range(s):
        o, st = L.ssd_decode(p, cfg, x[:, t:t + 1], st)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=2e-4)


def test_rglru_decode_matches_block():
    from repro.configs.base import RecurrentConfig, RECURRENT, ATTN
    cfg = tiny("hy", family="hybrid", num_layers=1,
               recurrent=RecurrentConfig(lru_width=32, d_conv=4,
                                         block_pattern=(RECURRENT,)))
    p = L.init_rglru(jax.random.key(0), cfg)
    b, s = 2, 8
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model))
    full, _ = L.rglru_block(p, cfg, x, L.rglru_state_init(b, cfg, jnp.float32))
    st = L.rglru_state_init(b, cfg, jnp.float32)
    outs = []
    for t in range(s):
        o, st = L.rglru_decode(p, cfg, x[:, t:t + 1], st)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=3e-5)


def test_moe_shard_map_equals_local():
    """At a generous capacity factor (no drops) the distributed
    capacity-MoE must match the exact sort/ragged path bit-for-bit."""
    from repro.configs.base import MoEConfig
    cfg = tiny("moe", family="moe",
               moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2,
                             d_ff_expert=32))
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 6, cfg.d_model))
    ref = L.moe_mlp(p, cfg, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with dist.use(dist.DistContext(mesh=mesh, dp_axes=("data",),
                                   model_axis="model", moe_shard_map=True,
                                   moe_capacity_factor=8.0)):
        got = jax.jit(lambda pp, xx: L.moe_mlp(pp, cfg, xx))(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_moe_capacity_drop_semantics():
    """At capacity factor 1.0, over-capacity tokens lose that expert's
    contribution but outputs stay finite and within the convex hull scale."""
    from repro.configs.base import MoEConfig
    cfg = tiny("moe", family="moe",
               moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32))
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 24, cfg.d_model))
    tight = L._moe_mlp_capacity(p, cfg, x, capacity_factor=1.0)
    loose = L._moe_mlp_capacity(p, cfg, x, capacity_factor=8.0)
    assert np.isfinite(np.asarray(tight)).all()
    assert np.abs(np.asarray(tight)).max() \
        <= np.abs(np.asarray(loose)).max() * 1.5 + 1e-3


def test_moe_routing_no_token_drop():
    """Every token reaches exactly top_k experts (sort-based, no capacity)."""
    from repro.configs.base import MoEConfig
    cfg = tiny("moe", family="moe",
               moe=MoEConfig(num_experts=8, top_k=3, d_ff_expert=16))
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
    w, idx = L.moe_route(p, cfg, x)
    assert idx.shape == (64, 3)
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, atol=1e-5)
    counts = np.bincount(np.asarray(idx).reshape(-1), minlength=8)
    assert counts.sum() == 64 * 3
