"""Open-loop load harness: seeded generators are deterministic, the
driver stamps scheduled (not submit-time) arrivals, admission sheds
exactly at the headroom watermark and never mid-stream, and the
arrival-time submit path honours explicit 0.0 timestamps."""
import time

import numpy as np
import pytest

from repro.serving.loadgen import (build_workload, bursty_arrivals,
                                   poisson_arrivals, run_open_loop,
                                   WorkloadConfig)
from repro.serving.request import Request, State
from repro.serving.router import (AdmissionConfig, should_admit,
                                  update_ttft_ema)
from repro.serving.scheduler import RuntimeStats


# --------------------------------------------------------------------- #
# generators
# --------------------------------------------------------------------- #
def test_poisson_arrivals_deterministic_and_bounded():
    a = poisson_arrivals(4.0, 12.0, seed=11)
    assert a == poisson_arrivals(4.0, 12.0, seed=11)
    assert a != poisson_arrivals(4.0, 12.0, seed=12)
    assert a == sorted(a)
    assert all(0.0 <= t < 12.0 for t in a)
    # law of large numbers sanity: 48 expected, allow wide slack
    assert 15 < len(a) < 110


def test_bursty_arrivals_deterministic_and_bursty():
    x = bursty_arrivals(4.0, 30.0, seed=3)
    assert x == bursty_arrivals(4.0, 30.0, seed=3)
    assert x == sorted(x) and all(0.0 <= t < 30.0 for t in x)
    # burstiness: inter-arrival squared coefficient of variation above
    # the Poisson baseline of 1 (MMPP has strictly higher dispersion)
    gaps = np.diff(np.asarray(x))
    scv = gaps.var() / gaps.mean() ** 2
    assert scv > 1.2, scv


def test_workload_deterministic_under_seed():
    offs = poisson_arrivals(5.0, 5.0, seed=1)
    w1 = build_workload(offs, WorkloadConfig(), seed=9)
    w2 = build_workload(offs, WorkloadConfig(), seed=9)
    assert len(w1) == len(offs)
    for a, b in zip(w1, w2):
        assert a.offset_s == b.offset_s
        assert np.array_equal(a.request.prompt, b.request.prompt)
        assert a.request.max_new_tokens == b.request.max_new_tokens
    cfg = WorkloadConfig()
    for it in w1:
        assert cfg.prompt_min <= it.request.prompt_len <= cfg.prompt_max
        assert cfg.output_min <= it.request.max_new_tokens <= cfg.output_max


def test_multimodal_workload_synthesis():
    """The encoder-tokens term the planner learns needs a workload that
    actually carries encoder input: audio requests get fixed-length frame
    embeddings, vision requests a variable patch count, deterministically
    under the seed; text-only (fraction 0) stays payload-free."""
    offs = poisson_arrivals(5.0, 5.0, seed=1)
    audio = WorkloadConfig(multimodal_fraction=1.0, modality="audio",
                           encoder_d=64, frame_len=10)
    w1 = build_workload(offs, audio, seed=9)
    w2 = build_workload(offs, audio, seed=9)
    for a, b in zip(w1, w2):
        assert a.request.frames.shape == (10, 64)
        assert a.request.frames.dtype == np.float32
        assert a.request.patches is None
        assert np.array_equal(a.request.frames, b.request.frames)

    vision = WorkloadConfig(multimodal_fraction=1.0, modality="vision",
                            encoder_d=32, patch_min=2, patch_max=8)
    counts = {it.request.patches.shape[0]
              for it in build_workload(offs, vision, seed=9)}
    assert counts <= set(range(2, 9)) and len(counts) > 1
    for it in build_workload(offs, vision, seed=9):
        assert it.request.frames is None
        assert it.request.patches.shape[1] == 32

    mixed = WorkloadConfig(multimodal_fraction=0.5, modality="audio")
    n_mm = sum(it.request.frames is not None
               for it in build_workload(offs, mixed, seed=9))
    assert 0 < n_mm < len(offs)

    for it in build_workload(offs, WorkloadConfig(), seed=9):
        assert it.request.frames is None and it.request.patches is None


# --------------------------------------------------------------------- #
# admission policy (pure)
# --------------------------------------------------------------------- #
def test_should_admit_queue_watermark_is_exact():
    cfg = AdmissionConfig(max_queue_depth=3)
    assert should_admit(cfg, 2, None)
    assert not should_admit(cfg, 3, None)      # at watermark: shed
    assert not should_admit(cfg, 4, None)
    assert should_admit(None, 10**6, None)     # no config: always admit


def test_should_admit_ttft_gate_needs_queued_work():
    cfg = AdmissionConfig(slo_ttft_s=1.0, headroom=1.0)
    assert not should_admit(cfg, 1, 2.0)       # over budget, work queued
    # over budget but idle: the EMA is stale history — admitting is the
    # only way to refresh it (shedding here would lock out forever)
    assert should_admit(cfg, 0, 2.0)
    assert should_admit(cfg, 1, 0.5)           # within budget
    assert should_admit(cfg, 1, None)          # no signal yet


def test_update_ttft_ema():
    assert update_ttft_ema(None, 2.0, 0.3) == 2.0
    assert update_ttft_ema(1.0, 2.0, 0.5) == pytest.approx(1.5)


# --------------------------------------------------------------------- #
# submit-path arrival stamping (the `or` → `is None` regression)
# --------------------------------------------------------------------- #
def _tiny_cluster_runtime(**kw):
    from repro.serving.engine import VendorProfile
    from repro.serving.multiproc import (ClusterRuntime, ClusterSpec,
                                         EngineSpec)
    from tests.conftest import TINY_FAMILIES
    cfg = TINY_FAMILIES["dense"]
    mk = lambda name, role: EngineSpec(name, cfg,
                                       VendorProfile("A", block_size=8),
                                       params_seed=0, num_blocks=64,
                                       max_batch=2, max_seq_len=64,
                                       role=role)
    # never started: submit/try_submit are parent-side bookkeeping only
    return ClusterRuntime(ClusterSpec(p=(mk("P0", "prefill"),),
                                      d=(mk("D0", "decode"),)), **kw)


def _req(rid, arrival=None):
    return Request(req_id=rid, prompt=np.arange(8, dtype=np.int32),
                   max_new_tokens=4, arrival_time=arrival)


def test_submit_preserves_explicit_zero_arrival_time():
    rt = _tiny_cluster_runtime()
    r0 = _req("zero", arrival=0.0)
    rt.submit(r0)
    # regression: `arrival_time or time.monotonic()` treated an explicit
    # 0.0 (virtual-clock epoch) as "unset" and overwrote the schedule
    assert r0.arrival_time == 0.0
    r1 = _req("unset", arrival=None)
    before = time.monotonic()
    rt.submit(r1)
    assert r1.arrival_time is not None and r1.arrival_time >= before


def test_try_submit_sheds_at_watermark_and_counts():
    rt = _tiny_cluster_runtime(
        admission=AdmissionConfig(max_queue_depth=2))
    rs = [_req(f"s{i}") for i in range(5)]
    admitted = [rt.try_submit(r) for r in rs]
    assert admitted == [True, True, False, False, False]
    assert rt.stats.shed == 3 and rt.stats.submitted == 2
    assert all(r.state == State.SHED for r, ok in zip(rs, admitted)
               if not ok)


# --------------------------------------------------------------------- #
# open-loop driver over a stub runtime
# --------------------------------------------------------------------- #
class _StubRuntime:
    """Minimal try_submit/step surface: finishes ``per_step`` queued
    requests per step, records submit wall times."""

    def __init__(self, admission=None, per_step=1):
        self.admission = admission
        self.ttft_ema = None
        self.stats = RuntimeStats()
        self.queue = []
        self.per_step = per_step
        self.submit_walls = {}

    def queue_depth(self):
        return len(self.queue)

    def try_submit(self, req):
        if not should_admit(self.admission, self.queue_depth(),
                            self.ttft_ema):
            req.state = State.SHED
            self.stats.shed += 1
            return False
        self.submit_walls[req.req_id] = time.monotonic()
        self.stats.submitted += 1
        self.queue.append(req)
        return True

    def step(self, timeout=0.0):
        for r in self.queue[:self.per_step]:
            now = time.monotonic()
            r.first_token_time = r.first_token_time or now
            r.last_token_time = now
            r.output_tokens = list(range(r.max_new_tokens))
            r.finish_time = now
            r.state = State.FINISHED
            self.stats.finished += 1
        del self.queue[:self.per_step]


def _workload(offsets):
    return build_workload(list(offsets), WorkloadConfig(), seed=5)


def test_driver_stamps_scheduled_arrival_not_submit_wall():
    rt = _StubRuntime()
    wl = _workload([0.0, 0.12])
    res = run_open_loop(rt, wl, max_wall_s=30.0)
    assert res.finished == 2 and res.shed == 0
    r0, r1 = wl[0].request, wl[1].request
    # arrivals are the *schedule* rebased onto one epoch: exact spacing
    assert r1.arrival_time - r0.arrival_time == pytest.approx(0.12,
                                                              abs=1e-9)
    # scheduled arrival never postdates the actual submit: queueing and
    # driver lag land on TTFT, as an external client would measure
    for it in wl:
        assert it.request.arrival_time <= \
            rt.submit_walls[it.request.req_id]
        assert it.request.ttft() is not None and it.request.ttft() >= 0.0


def test_driver_sheds_exactly_at_headroom_never_mid_stream():
    # everything due at t=0 and nothing drains until after admission:
    # with a watermark of 2 the third arrival onward is shed at the door
    rt = _StubRuntime(admission=AdmissionConfig(max_queue_depth=2),
                      per_step=1)
    wl = _workload([0.0] * 5)
    res = run_open_loop(rt, wl, max_wall_s=30.0)
    assert res.offered == 5
    assert res.admitted == 2 and res.shed == 3
    assert rt.stats.shed == 3
    states = [it.request.state for it in wl]
    assert states.count(State.SHED) == 3
    # an admitted request is never shed later: it runs to completion
    assert states.count(State.FINISHED) == 2
    assert res.finished == 2 and res.failed == 0


def test_driver_ticks_autoscaler_and_collects_actions():
    class _Scaler:
        def __init__(self):
            self.ticks = 0

        def tick(self):
            self.ticks += 1
            return "grow-d:D-auto0" if self.ticks == 1 else None

    rt = _StubRuntime(per_step=1)
    sc = _Scaler()
    res = run_open_loop(rt, _workload([0.0, 0.05, 0.30]), autoscaler=sc,
                        autoscale_every_s=0.05, max_wall_s=30.0)
    assert sc.ticks >= 2
    assert res.autoscale_actions == ["grow-d:D-auto0"]
