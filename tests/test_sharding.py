"""Launch-layer sharding rules: divisibility safety (hypothesis over every
assigned arch), head/vocab padding properties, ZeRO spec construction,
cell-grid shape."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED
from repro.configs.base import get_config
from repro.launch import sharding as SH
from repro.launch.cells import LONG_OK, make_cells
from repro.models import model as M


def _axis_ok(shape, spec, sizes):
    """Every sharded dim must be divisible by the product of its axes."""
    for d, ax in enumerate(spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= sizes[a]
        if shape[d] % n:
            return False
    return True


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_param_specs_divisible(arch, mode):
    sizes = {"data": 16, "model": 16}
    cfg = SH.deploy_config(get_config(arch), 16, mode)
    abs_p = M.abstract_params(cfg)
    specs = SH.param_pspecs(abs_p, cfg, "model", 16)
    flat_p = jax.tree.leaves(abs_p)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    n_sharded = 0
    for leaf, spec in zip(flat_p, flat_s):
        assert _axis_ok(leaf.shape, spec, sizes), (leaf.shape, spec)
        if any(s is not None for s in spec):
            n_sharded += 1
    # the bulk of the parameters must actually shard
    big = [leaf for leaf in flat_p if np.prod(leaf.shape) > 1e6]
    big_sharded = [
        (leaf, spec) for leaf, spec in zip(flat_p, flat_s)
        if np.prod(leaf.shape) > 1e6 and any(s is not None for s in spec)]
    if arch != "mamba2-370m":          # SSD params deliberately replicated
        assert len(big_sharded) >= 0.8 * len(big), arch


@given(h=st.integers(1, 128), kv=st.integers(1, 64),
       axis=st.sampled_from([8, 16]))
def test_pad_heads_properties(h, kv, axis):
    if kv > h or h % kv:
        return
    cfg = get_config("qwen3-4b").with_(num_heads=h, num_kv_heads=kv,
                                       head_dim=64)
    out = SH.pad_heads(cfg, axis)
    assert out.num_heads % axis == 0
    assert out.num_heads % out.num_kv_heads == 0       # integral GQA groups
    assert out.num_heads >= h and out.num_kv_heads >= kv
    assert out.hd == 64                                # head_dim unchanged
    if h % axis == 0 and h % kv == 0:
        assert out.num_heads == h                      # identity when aligned


@given(v=st.integers(1, 300000), axis=st.sampled_from([8, 16]))
def test_pad_vocab(v, axis):
    cfg = get_config("qwen3-4b").with_(vocab_size=v)
    out = SH.pad_vocab(cfg, axis)
    assert out.vocab_size % axis == 0
    assert 0 <= out.vocab_size - v < axis


def test_zero1_spec_adds_data_axis_once():
    sp = SH.zero1_pspec(P(None, "model"), (1024, 512), ("data",), 16)
    assert sp == P("data", "model")
    # idempotent
    sp2 = SH.zero1_pspec(sp, (1024, 512), ("data",), 16)
    assert sp2 == sp
    # indivisible dims stay unsharded
    sp3 = SH.zero1_pspec(P(None,), (7,), ("data",), 16)
    assert sp3 == P(None)


def test_cell_grid_is_40_with_documented_skips():
    cells = make_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c.skip]
    assert {c.arch for c in skips} == set(a for a in ASSIGNED
                                          if a not in LONG_OK)
    assert all(c.shape == "long_500k" for c in skips)
    # decode capacity shards on a 16-way axis
    for c in cells:
        if c.mode == "decode" and not c.skip:
            assert c.decode_capacity() % 16 == 0
    # fp8 KV override is exactly the documented cell
    fp8 = [(c.arch, c.shape) for c in cells
           if c.cache_dtype != "bfloat16"]
    assert fp8 == [("qwen1.5-32b", "decode_32k")]


def test_batch_pspecs_respect_divisibility():
    abs_b = {"tokens": jax.ShapeDtypeStruct((7, 128), np.int32),
             "labels": jax.ShapeDtypeStruct((32, 128), np.int32)}
    specs = SH.batch_pspecs(abs_b, ("data",), 16)
    assert specs["tokens"] == P(None, None)      # 7 % 16 != 0 → replicated
    assert specs["labels"] == P("data", None)
